"""Core transformer layers in pure JAX (no flax): norms, RoPE, GQA attention
(+ qk_norm / QKV bias / sliding window), dense MLPs.

Conventions
-----------
* Params are nested dicts of jnp arrays.  Layer-stack params carry a leading
  ``[G]`` group dim and are consumed by ``lax.scan`` — the per-layer functions
  here take the *unstacked* slice.
* Initializers take explicit ``rng``; compute accumulates in f32 where it
  matters (norm stats, softmax) and casts back to the param dtype.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, d_in: int, d_out: int, dtype) -> jax.Array:
    std = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ArchConfig, d: int, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = jnp.mean(xf * xf, -1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headwise(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    """qk_norm: RMSNorm over the head_dim axis of [..., hd]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, hd]; positions: [S] or broadcastable to x[..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(cfg: ArchConfig, rng) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 6)
    p = {
        "wq": dense_init(ks[0], D, H * hd, dt),
        "wk": dense_init(ks[1], D, K * hd, dt),
        "wv": dense_init(ks[2], D, K * hd, dt),
        "wo": dense_init(ks[3], H * hd, D, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((K * hd,), dt)
        p["bv"] = jnp.zeros((K * hd,), dt)
    if cfg.attn_bias:
        p["bo"] = jnp.zeros((cfg.d_model,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def qkv_project(cfg: ArchConfig, p: dict, x: jax.Array, positions: jax.Array):
    """x: [B, S, D] -> q [B,H,S,hd], k/v [B,K,S,hd] (RoPE + qk_norm applied)."""
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, K, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, K, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm_headwise(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm_headwise(p["k_norm"], k, cfg.norm_eps)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(cfg: ArchConfig, p: dict, o: jax.Array) -> jax.Array:
    """o: [B,H,S,hd] -> [B,S,D]."""
    B, H, S, hd = o.shape
    y = o.transpose(0, 2, 1, 3).reshape(B, S, H * hd) @ p["wo"]
    if cfg.attn_bias:
        y = y + p["bo"]
    return y


def self_attention(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,  # [B, S, D]
    positions: jax.Array,  # [S]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    impl: str = "auto",
) -> jax.Array:
    q, k, v = qkv_project(cfg, p, x, positions)
    w = cfg.sliding_window if window is None else window
    o = ops.attention(q, k, v, causal=causal, window=w, impl=impl)
    return attn_out(cfg, p, o)


def init_cross_attention(cfg: ArchConfig, rng) -> dict:
    # whisper-style MHA over encoder output (no rope)
    return init_attention(cfg, rng)


def cross_attention(
    cfg: ArchConfig, p: dict, x: jax.Array, enc: jax.Array, impl: str = "auto"
) -> jax.Array:
    """x: [B,S,D] queries; enc: [B,Se,D] encoder keys/values."""
    B, S, _ = x.shape
    Se = enc.shape[1]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (enc @ p["wk"]).reshape(B, Se, K, hd).transpose(0, 2, 1, 3)
    v = (enc @ p["wv"]).reshape(B, Se, K, hd).transpose(0, 2, 1, 3)
    o = ops.attention(q, k, v, causal=False, window=0, impl=impl)
    return attn_out(cfg, p, o)


# ---------------------------------------------------------------------------
# dense MLPs
# ---------------------------------------------------------------------------


def init_mlp(cfg: ArchConfig, rng, d_ff: Optional[int] = None) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.act == "swiglu":
        p = {
            "w_gate": dense_init(ks[0], D, F, dt),
            "w_up": dense_init(ks[1], D, F, dt),
            "w_down": dense_init(ks[2], F, D, dt),
        }
    else:  # gelu
        p = {
            "w_up": dense_init(ks[0], D, F, dt),
            "w_down": dense_init(ks[1], F, D, dt),
        }
        if cfg.mlp_bias:
            p["b_up"] = jnp.zeros((F,), dt)
            p["b_down"] = jnp.zeros((D,), dt)
    return p


def apply_mlp(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    h = x @ p["w_up"]
    if "b_up" in p:
        h = h + p["b_up"]
    h = jax.nn.gelu(h)
    y = h @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return y
