"""Decoder stacks assembled from the repeating group pattern, plus the
whisper encoder tower and the stubbed modality frontends.

Layout: ``params['layers']`` is a LIST with one entry per pattern slot; every
leaf in a slot carries a leading ``[G]`` (= n_groups) dim.  The stack is
``lax.scan``ned over G, so HLO size is O(len(pattern)), and ProFL block
slicing is a leading-dim slice (see core/blocks.py).

Three execution modes per slot kind:
  * full-sequence forward  (training / the shrinking+growing sub-models)
  * prefill                (full sequence + emit per-layer decode state)
  * decode step            (one token + state)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.launch import sharding
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


# ===========================================================================
# init
# ===========================================================================


def _init_slot(cfg: ArchConfig, spec: LayerSpec, rng, cross: bool) -> dict:
    ks = jax.random.split(rng, 6)
    p: dict = {"norm1": L.init_norm(cfg, cfg.d_model, jnp.dtype(cfg.param_dtype))}
    if spec.mixer == "attn":
        p["attn"] = L.init_attention(cfg, ks[0])
    elif spec.mixer == "mamba":
        p["mamba"] = S.init_mamba(cfg, cfg.ssm, ks[0])
    elif spec.mixer == "rwkv":
        p["rwkv"] = S.init_rwkv(cfg, cfg.rwkv, ks[0])
    else:
        raise ValueError(spec.mixer)
    if cross and spec.mixer == "attn":
        p["norm_cross"] = L.init_norm(cfg, cfg.d_model, jnp.dtype(cfg.param_dtype))
        p["cross"] = L.init_cross_attention(cfg, ks[1])
    if spec.ffn != "none" and not (cfg.parallel_block and spec.mixer == "attn"):
        p["norm2"] = L.init_norm(cfg, cfg.d_model, jnp.dtype(cfg.param_dtype))
    if spec.ffn == "dense":
        p["ffn"] = L.init_mlp(cfg, ks[2])
    elif spec.ffn == "moe":
        p["moe"] = M.init_moe(cfg, cfg.moe, ks[2])
    elif spec.ffn == "rwkv_cm":
        p["rwkv_cm"] = S.init_rwkv_cm(cfg, ks[2])
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_decoder_layers(cfg: ArchConfig, rng, n_groups: Optional[int] = None) -> list:
    """List of per-slot stacked params ([G, ...] leaves)."""
    G = cfg.n_groups if n_groups is None else n_groups
    cross = cfg.encoder is not None
    out = []
    for si, spec in enumerate(cfg.pattern):
        slots = []
        for g in range(G):
            slots.append(
                _init_slot(cfg, spec, jax.random.fold_in(rng, si * 10_000 + g), cross)
            )
        out.append(_stack(slots))
    return out


def init_encoder(cfg: ArchConfig, rng) -> dict:
    """Whisper-style encoder: stub frame embeddings + pos embed + attn/gelu
    layers (bidirectional).  The conv frontend is stubbed per the assignment:
    inputs are precomputed frame embeddings [B, n_frames, d_model]."""
    ecfg = cfg.encoder
    dt = jnp.dtype(cfg.param_dtype)
    enc_layer_cfg = cfg.with_(parallel_block=False)
    slots = []
    for g in range(ecfg.n_layers):
        slots.append(
            _init_slot(
                enc_layer_cfg,
                LayerSpec("attn", "dense"),
                jax.random.fold_in(rng, 777_000 + g),
                cross=False,
            )
        )
    return {
        "pos": (0.02 * jax.random.normal(rng, (ecfg.n_frames, cfg.d_model))).astype(dt),
        "layers": [_stack(slots)],
        "final_norm": L.init_norm(cfg, cfg.d_model, dt),
    }


def init_model(cfg: ArchConfig, rng) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 6)
    params = {
        "embed": {"tok": L.embed_init(ks[0], cfg.vocab, cfg.d_model, dt)},
        "layers": init_decoder_layers(cfg, ks[1]),
        "final_norm": L.init_norm(cfg, cfg.d_model, dt),
    }
    if cfg.learned_pos:
        params["embed"]["pos"] = (
            0.02 * jax.random.normal(ks[5], (cfg.learned_pos, cfg.d_model))
        ).astype(dt)
    if not cfg.tie_embeddings:
        params["head"] = {"w": L.dense_init(ks[2], cfg.d_model, cfg.vocab, dt)}
    if cfg.encoder is not None:
        params["encoder"] = init_encoder(cfg, ks[3])
    if cfg.frontend is not None:
        params["projector"] = {
            "w": L.dense_init(ks[4], cfg.frontend.embed_dim, cfg.d_model, dt),
            "b": jnp.zeros((cfg.d_model,), dt),
        }
    return params


# ===========================================================================
# per-layer application (full sequence)
# ===========================================================================


def apply_layer(
    cfg: ArchConfig,
    spec: LayerSpec,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    enc: Optional[jax.Array],
    *,
    window_override: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """One layer, full-sequence. Returns (x, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block and spec.mixer == "attn":
        h = L.apply_norm(cfg, p["norm1"], x)
        a = L.self_attention(cfg, p["attn"], h, positions, window=window_override)
        f = L.apply_mlp(cfg, p["ffn"], h)
        return sharding.constrain_hidden(x + a + f), aux

    if spec.mixer == "attn":
        h = L.apply_norm(cfg, p["norm1"], x)
        x = x + L.self_attention(cfg, p["attn"], h, positions, window=window_override)
        if enc is not None and "cross" in p:
            hc = L.apply_norm(cfg, p["norm_cross"], x)
            x = x + L.cross_attention(cfg, p["cross"], hc, enc)
    elif spec.mixer == "mamba":
        x = x + S.mamba_forward(cfg, cfg.ssm, p["mamba"], L.apply_norm(cfg, p["norm1"], x))
    elif spec.mixer == "rwkv":
        x = x + S.rwkv_forward(cfg, cfg.rwkv, p["rwkv"], L.apply_norm(cfg, p["norm1"], x))

    if spec.ffn == "dense":
        x = x + L.apply_mlp(cfg, p["ffn"], L.apply_norm(cfg, p["norm2"], x))
    elif spec.ffn == "moe":
        y, aux = M.apply_moe(cfg, cfg.moe, p["moe"], L.apply_norm(cfg, p["norm2"], x))
        x = x + y
    elif spec.ffn == "rwkv_cm":
        x = x + S.rwkv_cm_forward(cfg, p["rwkv_cm"], L.apply_norm(cfg, p["norm2"], x))
    return sharding.constrain_hidden(x), aux


def run_layers(
    cfg: ArchConfig,
    layer_params: list,  # per-slot stacked, leading [G']
    x: jax.Array,
    positions: jax.Array,
    enc: Optional[jax.Array] = None,
    *,
    remat: bool = True,
    window_override: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Scan the group pattern over the (possibly sliced) stack.
    Returns (x, total_moe_aux)."""

    def one_layer(spec):
        def f(p, x):
            return apply_layer(
                cfg, spec, p, x, positions, enc, window_override=window_override
            )
        return f

    # nested remat: per-LAYER checkpoints inside multi-layer groups keep the
    # recomputed-backward transient at max-over-layers instead of
    # sum-over-layers (jamba's 8-layer group held 4 MoE layers' residuals
    # simultaneously — §Perf i6)
    nested = remat and len(cfg.pattern) > 1

    def group_body(carry, slot_params):
        x, aux = carry
        for spec, p in zip(cfg.pattern, slot_params):
            f = one_layer(spec)
            if nested:
                # prevent_cse=True (default): this is straight-line code, not
                # a scan body — with CSE allowed, XLA merges the recompute
                # with the forward and the remat is a no-op (§Perf i6b)
                f = jax.checkpoint(f)
            x, a = f(p, x)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(group_body) if remat else group_body
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), tuple(layer_params))
    return x, aux


# ===========================================================================
# embedding / head / encoder / frontends
# ===========================================================================


def embed_inputs(cfg: ArchConfig, params: dict, batch: dict):
    """batch: {'tokens': [B,S] int32, optional 'frontend_embeds': [B,P,Ef]}.
    Returns (x [B, S', D], positions [S'], n_prefix) where n_prefix is the
    number of prepended frontend tokens (loss is computed on token part)."""
    tokens = batch["tokens"]
    x = params["embed"]["tok"][tokens]  # gather
    if cfg.learned_pos:
        x = x + params["embed"]["pos"][: tokens.shape[1]].astype(x.dtype)
    n_prefix = 0
    if cfg.frontend is not None:
        fe = batch["frontend_embeds"]
        proj = fe @ params["projector"]["w"] + params["projector"]["b"]
        x = jnp.concatenate([proj.astype(x.dtype), x], axis=1)
        n_prefix = cfg.frontend.n_tokens
    positions = jnp.arange(x.shape[1])
    return sharding.constrain_hidden(x), positions, n_prefix


def logits_from_hidden(cfg: ArchConfig, params: dict, x: jax.Array) -> jax.Array:
    x = L.apply_norm(cfg, params["final_norm"], x)
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]["w"]
    logits = x @ w.astype(x.dtype)
    if cfg.logit_soft_cap > 0:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    return sharding.constrain_vocab_logits(logits)


def encode(cfg: ArchConfig, params: dict, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stubbed frame embeddings [B, F, D]."""
    enc_p = params["encoder"]
    x = frames + enc_p["pos"].astype(frames.dtype)
    x = sharding.constrain_hidden(x)

    def body(carry, slot_params):
        x, _ = carry
        h = L.apply_norm(cfg, slot_params["norm1"], x)
        # bidirectional, no rope
        pos = jnp.arange(x.shape[1])
        cfg_enc = cfg.with_(use_rope=False)
        x = x + L.self_attention(cfg_enc, slot_params["attn"], h, pos, causal=False)
        x = x + L.apply_mlp(cfg, slot_params["ffn"], L.apply_norm(cfg, slot_params["norm2"], x))
        return (sharding.constrain_hidden(x), jnp.zeros((), jnp.float32)), None

    (x, _), _ = jax.lax.scan(
        jax.checkpoint(body), (x, jnp.zeros((), jnp.float32)), enc_p["layers"][0]
    )
    return L.apply_norm(cfg, enc_p["final_norm"], x)


def forward_hidden(
    cfg: ArchConfig,
    params: dict,
    batch: dict,
    *,
    remat: bool = True,
    window_override: Optional[int] = None,
):
    """Full stack minus the LM head. Returns (hidden [B,S',D], aux, n_prefix)."""
    x, positions, n_prefix = embed_inputs(cfg, params, batch)
    enc = None
    if cfg.encoder is not None:
        enc = encode(cfg, params, batch["frames"])
    x, aux = run_layers(
        cfg, params["layers"], x, positions, enc,
        remat=remat, window_override=window_override,
    )
    return x, aux, n_prefix


def forward(
    cfg: ArchConfig,
    params: dict,
    batch: dict,
    *,
    remat: bool = True,
    window_override: Optional[int] = None,
):
    """Full-model forward. Returns (logits [B, S', V], moe_aux, n_prefix)."""
    x, aux, n_prefix = forward_hidden(
        cfg, params, batch, remat=remat, window_override=window_override
    )
    return logits_from_hidden(cfg, params, x), aux, n_prefix


# ===========================================================================
# decode path (single token, explicit state) — see train/serve.py for the
# cache construction; here is the per-layer step.
# ===========================================================================


def _decode_attn(
    cfg: ArchConfig, p: dict, x: jax.Array, cache: dict, pos: jax.Array, window: int
):
    """x: [B,1,D]; cache: {'k','v': [B,Kh,W,hd]}; pos: scalar global position.
    Writes the new token at pos % W and attends over valid entries."""
    B = x.shape[0]
    H, Kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    W = cache["k"].shape[2]
    q, k, v = L.qkv_project(cfg, p, x, jnp.full((1,), pos))  # rope at abs pos
    slot = jax.lax.rem(pos, W) if window > 0 else jnp.minimum(pos, W - 1)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, slot, 0))

    j = jnp.arange(W)
    if window > 0:
        stored_pos = pos - jax.lax.rem(slot - j + W, W)
        valid = stored_pos >= 0
    else:
        stored_pos = j
        valid = j <= pos
    qr = q.reshape(B, Kh, H // Kh, 1, hd)
    s = jnp.einsum(
        "bkgqd,bksd->bkgqs", qr.astype(jnp.float32), ck.astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(hd))
    s = jnp.where(valid[None, None, None, None], s, -1e30)
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", pattn, cv.astype(jnp.float32))
    o = o.reshape(B, H, 1, hd).astype(x.dtype)
    return L.attn_out(cfg, p, o), {"k": ck, "v": cv}


def decode_layer_step(
    cfg: ArchConfig,
    spec: LayerSpec,
    p: dict,
    x: jax.Array,
    cache: dict,
    pos: jax.Array,
    window: int,
):
    """One decoder layer, one token. Returns (x, new_cache)."""
    new_cache = dict(cache)
    if cfg.parallel_block and spec.mixer == "attn":
        h = L.apply_norm(cfg, p["norm1"], x)
        a, kv = _decode_attn(cfg, p["attn"], h, cache, pos, window)
        f = L.apply_mlp(cfg, p["ffn"], h)
        new_cache.update(kv)
        return x + a + f, new_cache

    if spec.mixer == "attn":
        h = L.apply_norm(cfg, p["norm1"], x)
        a, kv = _decode_attn(cfg, p["attn"], h, cache, pos, window)
        new_cache.update(kv)
        x = x + a
        if "cross" in p:
            hc = L.apply_norm(cfg, p["norm_cross"], x)
            # cross k/v precomputed at prefill
            B = x.shape[0]
            H, Kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            qc = hc @ p["cross"]["wq"]
            if cfg.qkv_bias:
                qc = qc + p["cross"]["bq"]
            qc = qc.reshape(B, 1, H, hd).transpose(0, 2, 1, 3)
            qr = qc.reshape(B, Kh, H // Kh, 1, hd)
            s = jnp.einsum(
                "bkgqd,bksd->bkgqs",
                qr.astype(jnp.float32),
                cache["cross_k"].astype(jnp.float32),
            ) / jnp.sqrt(jnp.float32(hd))
            pr = jax.nn.softmax(s, -1)
            o = jnp.einsum("bkgqs,bksd->bkgqd", pr, cache["cross_v"].astype(jnp.float32))
            o = o.reshape(B, H, 1, hd).astype(x.dtype)
            x = x + L.attn_out(cfg, p["cross"], o)
    elif spec.mixer == "mamba":
        h = L.apply_norm(cfg, p["norm1"], x)
        y, st = S.mamba_decode_step(cfg, cfg.ssm, p["mamba"], cache["mamba"], h)
        new_cache["mamba"] = st
        x = x + y
    elif spec.mixer == "rwkv":
        h = L.apply_norm(cfg, p["norm1"], x)
        y, st = S.rwkv_decode_step(cfg, cfg.rwkv, p["rwkv"], cache["rwkv"], h)
        new_cache["rwkv"] = st
        x = x + y

    if spec.ffn == "dense":
        x = x + L.apply_mlp(cfg, p["ffn"], L.apply_norm(cfg, p["norm2"], x))
    elif spec.ffn == "moe":
        y, _ = M.apply_moe(cfg, cfg.moe, p["moe"], L.apply_norm(cfg, p["norm2"], x))
        x = x + y
    elif spec.ffn == "rwkv_cm":
        h = L.apply_norm(cfg, p["norm2"], x)
        y = S.rwkv_cm_forward(cfg, p["rwkv_cm"], h, cache["cm_x_prev"])
        new_cache["cm_x_prev"] = h
        x = x + y
    return x, new_cache
