"""Mixture-of-experts FFN: sort-based capacity routing, expert-parallel.

TPU adaptation (vs. the GPU einsum-dispatch in GShard-style code): a dense
one-hot dispatch einsum costs O(T · E·C · D) FLOPs — quadratic in tokens and
ruinous at E=128.  Instead we sort (token, expert) pairs by expert id,
compute each pair's position inside its expert via segment arithmetic, drop
beyond capacity, and scatter tokens into an ``[E, C, D]`` buffer that feeds a
*batched* expert matmul (MXU-friendly, FLOPs = active-expert FLOPs × capacity
factor).  Experts are sharded over the ``model`` mesh axis (expert parallel);
the scatter/gather across the token-sharded → expert-sharded boundary is an
all-to-all that GSPMD inserts from the sharding constraints.

Returns the standard switch-transformer load-balance auxiliary loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoECfg
from repro.launch import sharding
from repro.models import layers


def padded_experts(n: int, tp: int = 16) -> int:
    """Experts padded up to a multiple of the production TP degree so the
    [E, C, D] dispatch buffer shards over the 'model' axis (e.g. qwen2-moe's
    60 -> 64; unsharded 60 replicated the buffer per device —
    EXPERIMENTS.md §Perf i3).  Padded experts are masked in the router and
    never receive tokens."""
    if n < tp:
        return n
    return -(-n // tp) * tp


def init_moe(cfg: ArchConfig, mcfg: MoECfg, rng) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    D, F = cfg.d_model, mcfg.d_expert
    E = padded_experts(mcfg.n_experts)
    ks = jax.random.split(rng, 5)
    std = 1.0 / math.sqrt(D)
    p = {
        "router": (jax.random.normal(ks[0], (D, E), jnp.float32) * 0.02).astype(
            jnp.float32  # router kept in f32: tiny + routing is precision-sensitive
        ),
        "w_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * std).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * std).astype(dt),
        "w_down": (
            jax.random.normal(ks[3], (E, F, D), jnp.float32) / math.sqrt(F)
        ).astype(dt),
    }
    if mcfg.n_shared:
        p["shared"] = layers.init_mlp(cfg, ks[4], d_ff=mcfg.n_shared * F)
    return p


def capacity(mcfg: MoECfg, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * mcfg.top_k * mcfg.capacity_factor / mcfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly tiles


def _dp_groups(T: int) -> int:
    """Number of shard-local routing groups: the dp degree of the active
    mesh when it divides the token count, else 1.  Routing/sort/scatter run
    per group (leading dim sharded over dp) so no global token gather ever
    materializes; the buf resharding (dp-grouped -> expert-sharded) is the
    all-to-all of classic expert parallelism, inserted by GSPMD
    (EXPERIMENTS.md §Perf i3/i5)."""
    env = sharding.current_env()
    if env is None:
        return 1
    dp = sharding._axis_size(env, env.dp_axes)
    return dp if T % dp == 0 else 1


def apply_moe(cfg: ArchConfig, mcfg: MoECfg, p: dict, x: jax.Array):
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    B, S, D = x.shape
    T = B * S
    E_real, K = mcfg.n_experts, mcfg.top_k
    E = padded_experts(E_real)
    G = _dp_groups(T)
    Tl = T // G
    C = capacity(mcfg, Tl)

    env = sharding.current_env()
    dpx = env.dp_axes if env else None
    tpx = env.tp_axis if env else None

    xf = x.reshape(G, Tl, D)
    if env:
        xf = jax.lax.with_sharding_constraint(
            xf, sharding._sanitize(env, jax.sharding.PartitionSpec(dpx, None, None),
                                   xf.shape))
    # bf16 matmul with f32 accumulation: avoids materializing an f32 copy
    # of the whole token stream just for the router (§Perf i7)
    logits = jnp.einsum(
        "gtd,de->gte", xf, p["router"].astype(xf.dtype),
        preferred_element_type=jnp.float32,
    )  # [G, Tl, E] f32
    if E != E_real:  # padded experts never win the top-k
        logits = logits - 1e30 * (jnp.arange(E) >= E_real)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)  # [G, Tl, K]
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # load-balance aux (switch): E * Σ_e fraction_e * prob_e
    density = jnp.mean(
        jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=(0, 1, 2)
    )  # [E]
    prob_mean = jnp.mean(probs, axis=(0, 1))
    aux = E_real * E * jnp.sum(density * prob_mean) / K

    # ---- shard-local sort-based dispatch: GATHERS ONLY ------------------
    # (GSPMD shards batched gathers cleanly; scatters with computed indices
    # forced full replication of the dispatch buffer — §Perf i5)
    TKl = Tl * K
    flat_e = eidx.reshape(G, TKl)
    flat_t = jnp.tile(jnp.repeat(jnp.arange(Tl), K)[None], (G, 1))
    order = jnp.argsort(flat_e, axis=1)  # stable, per group
    se = jnp.take_along_axis(flat_e, order, 1)
    st = jnp.take_along_axis(flat_t, order, 1)
    inv = jnp.argsort(order, axis=1)  # sorted-row of each (t, k) pair
    seg = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E + 1), side="left")
    )(se)  # [G, E+1] segment starts (seg[:, E] == TKl)
    pos = jnp.arange(TKl)[None] - jnp.take_along_axis(seg, se, 1)
    keep = pos < C

    dp_spec = lambda nd: jax.sharding.PartitionSpec(dpx, *([None] * (nd - 1)))

    def glocal(a):  # keep a tensor group-sharded over dp
        if env is None:
            return a
        return jax.lax.with_sharding_constraint(
            a, sharding._sanitize(env, dp_spec(a.ndim), a.shape))

    sorted_x = glocal(jnp.take_along_axis(xf, st[..., None], 1))  # [G,TKl,D]
    # expert e's capacity slots are the contiguous sorted rows
    # [seg[e], seg[e] + C): a plain gather builds the dispatch buffer
    slot_rows = seg[:, :E, None] + jnp.arange(C)[None, None]  # [G, E, C]
    valid = slot_rows < seg[:, 1:, None]  # within this expert's segment
    idx = jnp.clip(slot_rows, 0, TKl - 1).reshape(G, E * C)
    buf = jnp.take_along_axis(sorted_x, idx[..., None], 1).reshape(G, E, C, D)
    buf = glocal(buf * valid[..., None].astype(x.dtype))

    # ---- batched expert matmul (swiglu); experts sharded over 'model' ---
    # the (g: dp) -> (e: model) reshard around the matmuls IS the expert-
    # parallel all-to-all
    def elocal(a):  # [G, E, C, F]: experts over model
        if env is None:
            return a
        return jax.lax.with_sharding_constraint(
            a, sharding._sanitize(
                env, jax.sharding.PartitionSpec(dpx, tpx, None, None), a.shape))

    h = elocal(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"]))
    u = elocal(jnp.einsum("gecd,edf->gecf", buf, p["w_up"]))
    h = jax.nn.silu(h) * u
    y_e = glocal(jnp.einsum("gecf,efd->gecd", h, p["w_down"]))  # [G, E, C, D]

    # ---- combine: two gathers (sorted-row lookup, then un-sort) ----------
    flat_slot = se * C + jnp.minimum(pos, C - 1)  # [G, TKl]
    y_sorted = jnp.take_along_axis(
        y_e.reshape(G, E * C, D), flat_slot[..., None], 1
    ) * keep[..., None].astype(x.dtype)
    routed_tok = glocal(jnp.take_along_axis(y_sorted, inv[..., None], 1))
    y = jnp.sum(
        routed_tok.reshape(G, Tl, K, D) * gate[..., None].astype(x.dtype), axis=2
    )

    if "shared" in p:
        y = y + layers.apply_mlp(cfg, p["shared"], xf)
    y = sharding.constrain_hidden(y.reshape(B, S, D))
    return y, aux
