"""Attention-free token mixers: Mamba (selective SSM, used by the jamba
hybrid) and RWKV6 "Finch" (data-dependent decay linear attention).

TPU adaptation notes
--------------------
* Mamba's CUDA "selective scan" kernel fuses the recurrence into SRAM; the
  TPU-native equivalent is a *chunked associative scan*: ``lax.scan`` over
  time chunks with ``lax.associative_scan`` inside each chunk, so the
  materialized state tensor is O(B · chunk · d_inner · d_state) instead of
  O(B · S · ...), and the MXU-heavy input/output projections stay ordinary
  sharded matmuls (d_inner over the ``model`` axis).
* RWKV6's recurrence has a data-dependent per-channel decay *inside* the
  state product, so the plain first-order associative form still applies per
  (key-dim) row: the state is [hd_k, hd_v] per head and the decay multiplies
  rows.  We use a time-step ``lax.scan`` (state stays O(1) in S — this is
  exactly why rwkv6 is the natural long_500k architecture).

Both expose: init, full-sequence forward (train/prefill), single-token
decode step with explicit state, and state initializers.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RWKVCfg, SSMCfg
from repro.launch import sharding
from repro.models.layers import dense_init


# ===========================================================================
# Mamba
# ===========================================================================


def mamba_dims(cfg: ArchConfig, scfg: SSMCfg):
    d_inner = scfg.expand * cfg.d_model
    dt_rank = scfg.dt_rank or max(1, cfg.d_model // 16)
    return d_inner, dt_rank


def init_mamba(cfg: ArchConfig, scfg: SSMCfg, rng) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    d_inner, dt_rank = mamba_dims(cfg, scfg)
    N = scfg.d_state
    ks = jax.random.split(rng, 6)
    return {
        "w_in": dense_init(ks[0], D, 2 * d_inner, dt),
        "conv_w": (jax.random.normal(ks[1], (d_inner, scfg.d_conv), jnp.float32)
                   / math.sqrt(scfg.d_conv)).astype(dt),
        "conv_b": jnp.zeros((d_inner,), dt),
        "w_x": dense_init(ks[2], d_inner, dt_rank + 2 * N, dt),
        "w_dt2": dense_init(ks[3], dt_rank, d_inner, dt),
        "dt_bias": jnp.zeros((d_inner,), dt),
        "a_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (d_inner, N))
        ).astype(jnp.float32),
        "d": jnp.ones((d_inner,), dt),
        "w_out": dense_init(ks[4], d_inner, D, dt),
    }


def _mamba_proj(cfg, scfg, p, x):
    """Shared pre-recurrence compute. x: [B, S, D] ->
    (a [B,S,di,N], b [B,S,di,N], Cmat [B,S,N], x_conv [B,S,di], z)."""
    d_inner, dt_rank = mamba_dims(cfg, scfg)
    xz = x @ p["w_in"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    # keep d_inner on the 'model' axis (NOT the residual stream's seq
    # sharding) — without this the chunk scan replicates the SSM state
    return sharding.constrain_ff(x_in), sharding.constrain_ff(z)


def _mamba_ssm_terms(cfg, scfg, p, x_conv):
    N = scfg.d_state
    _, dt_rank = mamba_dims(cfg, scfg)
    dbc = x_conv @ p["w_x"]
    dt_low = dbc[..., :dt_rank]
    Bm = dbc[..., dt_rank : dt_rank + N].astype(jnp.float32)
    Cm = dbc[..., dt_rank + N :].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_low @ p["w_dt2"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # [B,S,di]
    A = -jnp.exp(p["a_log"])  # [di, N] f32
    a = jnp.exp(dt[..., None] * A)  # [B,S,di,N]
    b = dt[..., None] * Bm[..., None, :] * x_conv.astype(jnp.float32)[..., None]
    return a, b, Cm


def mamba_forward(
    cfg: ArchConfig, scfg: SSMCfg, p: dict, x: jax.Array, *, return_state: bool = False
):
    """Full-sequence selective SSM. x: [B, S, D] -> [B, S, D]
    (+ decode state when ``return_state``)."""
    B, S, D = x.shape
    d_inner, _ = mamba_dims(cfg, scfg)
    K = scfg.d_conv
    x_in, z = _mamba_proj(cfg, scfg, p, x)

    # causal depthwise conv over time
    xp = jnp.pad(x_in, ((0, 0), (K - 1, 0), (0, 0)))
    x_conv = sum(
        xp[:, j : j + S] * p["conv_w"][:, j] for j in range(K)
    ) + p["conv_b"]
    x_conv = sharding.constrain_ff(jax.nn.silu(x_conv))

    # Chunked associative scan over time.  The (dt, B, C, a, b) SSM terms
    # are computed PER CHUNK inside a checkpointed scan body: materializing
    # them for the full sequence costs O(B·S·d_inner·N) f32 — at jamba scale
    # that was ~4 TiB/device in the compiled step (EXPERIMENTS.md §Perf i1).
    chunk = min(scfg.chunk, S)
    pad = (-S) % chunk
    xc_full = jnp.pad(x_conv, ((0, 0), (0, pad), (0, 0))) if pad else x_conv
    nch = (S + pad) // chunk
    xc_chunks = xc_full.reshape(B, nch, chunk, d_inner).transpose(1, 0, 2, 3)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_step(h, args):  # xc: [B, chunk, di]
        ci, xc = args
        ac, bc, Cc = _mamba_ssm_terms(cfg, scfg, p, xc)  # f32, chunk-local
        ac = sharding.constrain_time_state(ac)
        bc = sharding.constrain_time_state(bc)
        if pad:  # padded tail steps are identity transitions
            valid = (ci * chunk + jnp.arange(chunk)) < S  # [chunk]
            v = valid[None, :, None, None]
            ac = jnp.where(v, ac, 1.0)
            bc = jnp.where(v, bc, 0.0)
        Ac, Bc = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hs = sharding.constrain_time_state(Ac * h[:, None] + Bc)
        yc = jnp.einsum("bcdn,bcn->bcd", hs, Cc)
        yc = yc + p["d"].astype(jnp.float32) * xc.astype(jnp.float32)
        return hs[:, -1], sharding.constrain_time_state(yc)

    h0 = jnp.zeros((B, d_inner, scfg.d_state), jnp.float32)
    h_fin, ys = jax.lax.scan(
        jax.checkpoint(chunk_step, prevent_cse=False), h0,
        (jnp.arange(nch), xc_chunks),
    )  # ys: [nch, B, chunk, di]
    y = ys.transpose(1, 0, 2, 3).reshape(B, S + pad, d_inner)[:, :S]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["w_out"]
    if return_state:
        state = {
            "h": h_fin,
            "conv": x_in[:, -(K - 1):] if K > 1 else x_in[:, :0],
        }
        return out, state
    return out


def mamba_state_init(cfg: ArchConfig, scfg: SSMCfg, batch: int, dtype) -> dict:
    d_inner, _ = mamba_dims(cfg, scfg)
    return {
        "h": jnp.zeros((batch, d_inner, scfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, scfg.d_conv - 1, d_inner), dtype),
    }


def mamba_decode_step(cfg: ArchConfig, scfg: SSMCfg, p: dict, state: dict, x: jax.Array):
    """x: [B, 1, D] -> (y [B, 1, D], new_state)."""
    x_in, z = _mamba_proj(cfg, scfg, p, x)  # [B,1,di]
    hist = jnp.concatenate([state["conv"], x_in], axis=1)  # [B, K, di]
    x_conv = jnp.einsum("bkd,dk->bd", hist, p["conv_w"]) + p["conv_b"]
    x_conv = jax.nn.silu(x_conv)[:, None]  # [B,1,di]
    a, b, Cm = _mamba_ssm_terms(cfg, scfg, p, x_conv)
    h = a[:, 0] * state["h"] + b[:, 0]  # [B, di, N]
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0]) + p["d"].astype(jnp.float32) * x_conv[:, 0]
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    return y @ p["w_out"], {"h": h, "conv": hist[:, 1:]}


# ===========================================================================
# RWKV6 (Finch)
# ===========================================================================


def init_rwkv(cfg: ArchConfig, rcfg: RWKVCfg, rng) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    M = D  # r/k/v/g width == d_model, heads of rcfg.head_dim
    ks = jax.random.split(rng, 8)
    return {
        "mu": 0.5 * jnp.ones((5, D), dt),  # token-shift lerp for r,k,v,g,w
        "w_r": dense_init(ks[0], D, M, dt),
        "w_k": dense_init(ks[1], D, M, dt),
        "w_v": dense_init(ks[2], D, M, dt),
        "w_g": dense_init(ks[3], D, M, dt),
        "w_o": dense_init(ks[4], M, D, dt),
        "decay_base": -6.0 * jnp.ones((M,), jnp.float32),
        "decay_w1": dense_init(ks[5], D, rcfg.decay_lora, dt),
        "decay_w2": (jax.random.normal(ks[6], (rcfg.decay_lora, M), jnp.float32)
                     * 0.01).astype(dt),
        "u": jnp.zeros((M,), jnp.float32),  # per-channel bonus
        "ln_scale": jnp.ones((M,), dt),
        "ln_bias": jnp.zeros((M,), dt),
    }


def _rwkv_pre(cfg, rcfg, p, x, x_prev):
    """Token-shift + projections. x, x_prev: [B, S, D] (x_prev = shifted x).
    Returns r,k,v,g [B,S,H,hd], w decay in (0,1) [B,S,H,hd]."""
    B, S, D = x.shape
    hd = rcfg.head_dim
    H = D // hd
    mu = p["mu"]
    mix = lambda i: x + mu[i] * (x_prev - x)
    cs = sharding.constrain_time_state
    r = cs((mix(0) @ p["w_r"]).reshape(B, S, H, hd))
    k = cs((mix(1) @ p["w_k"]).reshape(B, S, H, hd))
    v = cs((mix(2) @ p["w_v"]).reshape(B, S, H, hd))
    g = sharding.constrain_ff(jax.nn.silu(mix(3) @ p["w_g"]))  # [B,S,M]
    dec = p["decay_base"] + ((mix(4) @ p["decay_w1"]) @ p["decay_w2"]).astype(
        jnp.float32
    )
    w = cs(jnp.exp(-jnp.exp(dec)).reshape(B, S, H, hd))  # data-dependent decay
    return r, k, v, g, w


def _rwkv_groupnorm(p, y, eps=1e-5):
    """Per-head layernorm of y: [B, S, H, hd]."""
    yf = y.astype(jnp.float32)
    mu = jnp.mean(yf, -1, keepdims=True)
    var = jnp.var(yf, -1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + eps)
    B, S, H, hd = y.shape
    yn = yn.reshape(B, S, H * hd)
    return yn * p["ln_scale"].astype(jnp.float32) + p["ln_bias"].astype(jnp.float32)


def rwkv_forward(
    cfg: ArchConfig, rcfg: RWKVCfg, p: dict, x: jax.Array, *, return_state: bool = False
):
    """Full-sequence RWKV6 time mix. x: [B, S, D] -> [B, S, D]
    (+ decode state when ``return_state``)."""
    B, S, D = x.shape
    hd = rcfg.head_dim
    H = D // hd
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _rwkv_pre(cfg, rcfg, p, x, x_prev)
    u = p["u"].reshape(H, hd)

    def step(Sst, rkvw):
        rt, kt, vt, wt = rkvw  # [B,H,hd]
        kv = kt.astype(jnp.float32)[..., None] * vt.astype(jnp.float32)[..., None, :]
        # y = r · (S + u⊙(k⊗v))
        yt = jnp.einsum(
            "bhi,bhij->bhj", rt.astype(jnp.float32), Sst + u[..., None] * kv
        )
        Snew = wt.astype(jnp.float32)[..., None] * Sst + kv
        return Snew, yt

    # Two-level time scan: the outer (chunk) level is checkpointed so the
    # backward pass stores only chunk-boundary states instead of one
    # [B, H, hd, hd] state per TIME STEP (EXPERIMENTS.md §Perf i2).
    chunk = 64
    pad = (-S) % chunk
    nch = (S + pad) // chunk

    def to_chunks(a, pad_value=0.0):  # [B,S,H,hd] -> [nch, chunk, B, H, hd]
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=pad_value)
        return a.reshape(B, nch, chunk, H, hd).transpose(1, 2, 0, 3, 4)

    def chunk_step(Sst, rkvw_c):
        Sn, ys_c = jax.lax.scan(step, Sst, rkvw_c)  # ys_c: [chunk, B, H, hd]
        return Sn, ys_c

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    # pad k/v with zeros (no state writes) and w with ones (identity decay)
    # so the carried state at step S is exact for return_state/prefill
    xs = (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(w, 1.0))
    Sfin, ys = jax.lax.scan(
        jax.checkpoint(chunk_step, prevent_cse=False), S0, xs
    )  # ys: [nch, chunk, B, H, hd]
    y = ys.transpose(2, 0, 1, 3, 4).reshape(B, S + pad, H, hd)[:, :S]
    y = _rwkv_groupnorm(p, y).astype(x.dtype) * g
    out = y @ p["w_o"]
    if return_state:
        return out, {"S": Sfin, "x_prev": x[:, -1:]}
    return out


def rwkv_state_init(cfg: ArchConfig, rcfg: RWKVCfg, batch: int, dtype) -> dict:
    hd = rcfg.head_dim
    H = cfg.d_model // hd
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_prev": jnp.zeros((batch, 1, cfg.d_model), dtype),
    }


def rwkv_decode_step(cfg: ArchConfig, rcfg: RWKVCfg, p: dict, state: dict, x: jax.Array):
    """x: [B, 1, D] -> (y [B, 1, D], new_state)."""
    B, _, D = x.shape
    hd = rcfg.head_dim
    H = D // hd
    r, k, v, g, w = _rwkv_pre(cfg, rcfg, p, x, state["x_prev"])
    u = p["u"].reshape(H, hd)
    rt, kt, vt, wt = r[:, 0], k[:, 0], v[:, 0], w[:, 0]
    kv = kt.astype(jnp.float32)[..., None] * vt.astype(jnp.float32)[..., None, :]
    yt = jnp.einsum("bhi,bhij->bhj", rt.astype(jnp.float32), state["S"] + u[..., None] * kv)
    Snew = wt.astype(jnp.float32)[..., None] * state["S"] + kv
    y = _rwkv_groupnorm(p, yt[:, None]).astype(x.dtype) * g
    return y @ p["w_o"], {"S": Snew, "x_prev": x}


# ---------------------------------------------------------------------------
# RWKV channel mix (the FFN of rwkv blocks)
# ---------------------------------------------------------------------------


def init_rwkv_cm(cfg: ArchConfig, rng) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "mu": 0.5 * jnp.ones((2, D), dt),
        "w_k": dense_init(ks[0], D, F, dt),
        "w_v": dense_init(ks[1], F, D, dt),
        "w_r": dense_init(ks[2], D, D, dt),
    }


def rwkv_cm_forward(cfg: ArchConfig, p: dict, x: jax.Array, x_prev=None) -> jax.Array:
    """Channel mix: sigmoid(r) ⊙ (relu(k)² Wv). x: [B,S,D]."""
    if x_prev is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xk = x + p["mu"][0] * (x_prev - x)
    xr = x + p["mu"][1] * (x_prev - x)
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (kk @ p["w_v"])
