"""The paper's own models: ResNet18/34 and VGG11_bn/VGG16_bn (CIFAR-scale),
in pure JAX (lax.conv), with the paper's modifications:

* VGG11_bn: MaxPool after every 2 convs; VGG16_bn: MaxPool after every 4;
  both use a single linear classifier and AdaptiveAvgPool to (1,1).
* ProFL block partition (paper §4.1): ResNet18/34 -> 4 blocks on the residual
  stages (stem joins block 1); VGG11 -> 2 blocks (4+4 convs); VGG16 -> 3
  blocks (4+4+5 convs).  The classifier head is the *real* output module of
  the last step.

Structure metadata (unit kinds, strides, pools) lives in a static ``plan``
derived from the config, so the param tree contains ONLY arrays (clean for
optimizers / FedAvg / ProFL slicing).  BN running stats are a separate tree;
forward returns ``(features_or_logits, new_bn_state)``.

Width scaling (``ratio``) supports the HeteroFL / AllSmall baselines: every
channel count is scaled and a sub-model's params are the leading slices of
the global tensors (HeteroFL's static channel partition).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp

DN = ("NHWC", "HWIO", "NHWC")
BN_MOMENTUM = 0.9


@dataclass(frozen=True)
class CNNConfig:
    kind: str  # resnet18 | resnet34 | vgg11 | vgg16
    n_classes: int = 10
    width_mult: float = 1.0  # global scale (reduced smoke variants)
    in_size: int = 32

    @property
    def n_prog_blocks(self) -> int:
        return {"resnet18": 4, "resnet34": 4, "vgg11": 2, "vgg16": 3}[self.kind]


@dataclass(frozen=True)
class Unit:
    kind: str  # 'stem' | 'basic' | 'vggconv'
    cin: int
    cout: int
    stride: int = 1
    pool: bool = False
    down: bool = False  # basic unit has a 1x1 downsample path


def _ch(c: int, mult: float) -> int:
    return max(4, int(round(c * mult)))


_RESNET_STAGES = {
    "resnet18": ([2, 2, 2, 2], [64, 128, 256, 512]),
    "resnet34": ([3, 4, 6, 3], [64, 128, 256, 512]),
}
_VGG_PLAN = {
    "vgg11": ([64, 128, 256, 256, 512, 512, 512, 512], 2, [4, 4]),
    "vgg16": (
        [64, 64, 128, 128, 256, 256, 256, 512, 512, 512, 512, 512, 512],
        4,
        [4, 4, 5],
    ),
}


def is_resnet(cfg: CNNConfig) -> bool:
    return cfg.kind.startswith("resnet")


# ---------------------------------------------------------------------------
# static plan: List[List[Unit]] — one list per prog-block
# ---------------------------------------------------------------------------


def build_plan(cfg: CNNConfig, ratio: float = 1.0) -> List[List[Unit]]:
    mult = cfg.width_mult * ratio
    plan: List[List[Unit]] = []
    if is_resnet(cfg):
        nblocks, chans = _RESNET_STAGES[cfg.kind]
        chans = [_ch(c, mult) for c in chans]
        cin = 3
        for si, (nb, c) in enumerate(zip(nblocks, chans)):
            blk: List[Unit] = []
            if si == 0:
                blk.append(Unit("stem", 3, c))
                cin = c
            for bi in range(nb):
                stride = 2 if (si > 0 and bi == 0) else 1
                blk.append(
                    Unit("basic", cin, c, stride, down=(stride != 1 or cin != c))
                )
                cin = c
            plan.append(blk)
        return plan
    chans, pool_every, block_convs = _VGG_PLAN[cfg.kind]
    chans = [_ch(c, mult) for c in chans]
    cin, ci = 3, 0
    for nb in block_convs:
        blk = []
        for _ in range(nb):
            c = chans[ci]
            blk.append(Unit("vggconv", cin, c, pool=((ci + 1) % pool_every == 0)))
            cin = c
            ci += 1
        plan.append(blk)
    return plan


def feature_dim(cfg: CNNConfig, ratio: float = 1.0) -> int:
    return build_plan(cfg, ratio)[-1][-1].cout


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _conv_init(rng, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(rng, (kh, kw, cin, cout), jnp.float32) * math.sqrt(
        2.0 / fan_in
    )


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bn_state_init(c):
    return {"mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def _init_unit(u: Unit, rng):
    ks = jax.random.split(rng, 3)
    if u.kind in ("stem", "vggconv"):
        p = {"conv": _conv_init(ks[0], 3, 3, u.cin, u.cout), "bn": _bn_init(u.cout)}
        s = {"bn": _bn_state_init(u.cout)}
        return p, s
    p = {
        "conv1": _conv_init(ks[0], 3, 3, u.cin, u.cout),
        "bn1": _bn_init(u.cout),
        "conv2": _conv_init(ks[1], 3, 3, u.cout, u.cout),
        "bn2": _bn_init(u.cout),
    }
    s = {"bn1": _bn_state_init(u.cout), "bn2": _bn_state_init(u.cout)}
    if u.down:
        p["down"] = _conv_init(ks[2], 1, 1, u.cin, u.cout)
        p["down_bn"] = _bn_init(u.cout)
        s["down_bn"] = _bn_state_init(u.cout)
    return p, s


def init_cnn(cfg: CNNConfig, rng, ratio: float = 1.0) -> Tuple[dict, dict]:
    """Returns (params, bn_state); param tree contains only arrays."""
    plan = build_plan(cfg, ratio)
    params: dict = {"blocks": [], "head": {}}
    state: dict = {"blocks": []}
    i = 0
    for blk in plan:
        bp, bs = [], []
        for u in blk:
            p, s = _init_unit(u, jax.random.fold_in(rng, i))
            bp.append(p)
            bs.append(s)
            i += 1
        params["blocks"].append(bp)
        state["blocks"].append(bs)
    cf = plan[-1][-1].cout
    params["head"] = {
        "w": jax.random.normal(jax.random.fold_in(rng, 9999), (cf, cfg.n_classes))
        / math.sqrt(cf),
        "b": jnp.zeros((cfg.n_classes,)),
    }
    return params, state


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _bn(x, p, s, train: bool):
    if train:
        mu = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new_s = {
            "mean": BN_MOMENTUM * s["mean"] + (1 - BN_MOMENTUM) * mu,
            "var": BN_MOMENTUM * s["var"] + (1 - BN_MOMENTUM) * var,
        }
    else:
        mu, var = s["mean"], s["var"]
        new_s = s
    y = (x - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    return y, new_s


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=DN
    )


def _apply_unit(u: Unit, p, s, x, train):
    new_s = dict(s)
    if u.kind == "stem":
        x = _conv(x, p["conv"])
        x, new_s["bn"] = _bn(x, p["bn"], s["bn"], train)
        return jax.nn.relu(x), new_s
    if u.kind == "vggconv":
        x = _conv(x, p["conv"])
        x, new_s["bn"] = _bn(x, p["bn"], s["bn"], train)
        x = jax.nn.relu(x)
        if u.pool:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )
        return x, new_s
    h = _conv(x, p["conv1"], u.stride)
    h, new_s["bn1"] = _bn(h, p["bn1"], s["bn1"], train)
    h = jax.nn.relu(h)
    h = _conv(h, p["conv2"])
    h, new_s["bn2"] = _bn(h, p["bn2"], s["bn2"], train)
    if u.down:
        x = _conv(x, p["down"], u.stride)
        x, new_s["down_bn"] = _bn(x, p["down_bn"], s["down_bn"], train)
    return jax.nn.relu(x + h), new_s


def forward_blocks(
    cfg: CNNConfig,
    params: dict,
    bn_state: dict,
    x: jax.Array,  # [N, H, W, 3]
    *,
    n_blocks: int = -1,  # run first n blocks (-1 = all)
    train: bool = True,
    ratio: float = 1.0,
):
    """Runs prog-blocks [0, n_blocks); returns (features NHWC, new_bn_state)."""
    plan = build_plan(cfg, ratio)
    nb = len(params["blocks"]) if n_blocks < 0 else n_blocks
    new_state = {"blocks": list(bn_state["blocks"])}
    for bi in range(nb):
        new_bs = []
        for u, p, s in zip(plan[bi], params["blocks"][bi], bn_state["blocks"][bi]):
            x, ns = _apply_unit(u, p, s, x, train)
            new_bs.append(ns)
        new_state["blocks"][bi] = new_bs
    return x, new_state


def head_logits(params: dict, feats: jax.Array) -> jax.Array:
    """AdaptiveAvgPool(1,1) + linear classifier."""
    pooled = jnp.mean(feats, axis=(1, 2))
    return pooled @ params["head"]["w"] + params["head"]["b"]


def forward_cnn(cfg: CNNConfig, params, bn_state, x, train=True, ratio: float = 1.0):
    feats, new_state = forward_blocks(
        cfg, params, bn_state, x, train=train, ratio=ratio
    )
    return head_logits(params, feats), new_state


# ---------------------------------------------------------------------------
# block metadata (for ProFL + Table 5)
# ---------------------------------------------------------------------------


def block_param_counts(params: dict) -> List[int]:
    """Trainable params per prog-block (head excluded, as in paper Table 5)."""
    return [sum(x.size for x in jax.tree.leaves(bp)) for bp in params["blocks"]]


def block_out_channels(cfg: CNNConfig, ratio: float = 1.0) -> List[int]:
    return [blk[-1].cout for blk in build_plan(cfg, ratio)]


def block_spatial_sizes(cfg: CNNConfig) -> List[int]:
    """Feature-map side length after each prog-block."""
    s = cfg.in_size
    out = []
    for blk in build_plan(cfg):
        for u in blk:
            if u.stride == 2 or u.pool:
                s //= 2
        out.append(s)
    return out


# ---------------------------------------------------------------------------
# HeteroFL width slicing: sub-model params are leading slices of the global
# ---------------------------------------------------------------------------


def slice_cnn_params(global_params: dict, sub_template: dict) -> dict:
    """Extract a width-scaled sub-model's params from the global tensors."""
    return jax.tree.map(
        lambda g, s: g[tuple(slice(0, d) for d in s.shape)],
        global_params,
        sub_template,
    )


def scatter_cnn_params(global_like: dict, sub_params: dict):
    """Place sub-model params back into zero-padded global-shaped tensors,
    plus a mask of which entries were covered (for HeteroFL aggregation)."""

    def put(g, s):
        out = jnp.zeros_like(g)
        out = out.at[tuple(slice(0, d) for d in s.shape)].set(s)
        return out

    def mask(g, s):
        m = jnp.zeros(g.shape, jnp.float32)
        return m.at[tuple(slice(0, d) for d in s.shape)].set(1.0)

    return (
        jax.tree.map(put, global_like, sub_params),
        jax.tree.map(mask, global_like, sub_params),
    )
