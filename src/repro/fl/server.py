"""ProFL server: round orchestration, memory-aware client selection, block
freezing, the shrinking→growing schedule, and federated proxy distillation.

This is the paper's full Fig. 1 workflow over the CNN models (the faithful
path); the transformer at-scale path reuses core/progressive.py inside the
pjit launcher instead of this simulator.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks as B
from repro.core import distill as D
from repro.core import effective_movement as EM
from repro.core import output_module as OM
from repro.core import progressive as P
from repro.fl import async_server as AS
from repro.fl import data as DATA
from repro.fl import engine as ENG
from repro.fl import faults as FLT
from repro.fl import memory_model as MM
from repro.models import cnn as C


@dataclass
class FLConfig:
    n_clients: int = 100
    clients_per_round: int = 20
    local_steps: int = 10
    batch_size: int = 32
    lr: float = 0.05
    n_local_fixed: int = 64  # fixed-size local dataset view (vmap)
    max_rounds_per_step: int = 60
    distill_rounds: int = 8
    distill_lr: float = 0.01
    use_shrinking: bool = True
    em: EM.EMConfig = field(default_factory=lambda: EM.EMConfig(
        window_h=3, slope_phi=0.01, patience_w=2, fit_points=4,
        em_level=0.75, min_rounds=9,
    ))
    eval_every: int = 5
    seed: int = 0
    ratio: float = 1.0  # width of the simulated model (reduced on CPU)
    # cohort engine: auto (default: sharded on multi-device, packed otherwise)
    # | vmap (the reference oracle) | packed | sharded
    engine: str = "auto"
    # freezing-aware layouts: track per-proxy effective movement and drop
    # converged proxies' columns from the aggregation panel/stream/kernel
    # (fl/engine.py::grouped_round(frozen=...)).  The step-termination EM
    # over the whole trainable tree is unaffected by this knob.
    freeze_layouts: bool = True
    # fault tolerance (fl/faults.py): when set, every training round samples
    # a deterministic per-client FaultPlan from (faults.seed, global round
    # counter) and runs grouped_round(faults=...) — dropped clients become
    # zero-weight rows, corrupt rows are quarantined inside the fused
    # dispatch, stragglers park and merge with the staleness discount.
    # None (default) keeps the exact fault-free path.
    faults: FLT.FaultConfig = None
    # async buffered aggregation (fl/async_server.py): when set, TRAINING
    # rounds route through a versioned AsyncAggServer — each round's cohort
    # becomes a submission tagged with the version it trained against,
    # arrivals follow the config's seeded latency schedule, and the global
    # model advances only when the buffer reaches publish_at rows (stale
    # arrivals merge at the staleness discount w·β^s).  With p_slow=0 and
    # publish_at=0 (→ cohort size) every round publishes exactly the sync
    # result bit-for-bit.  Distillation rounds keep the sync barrier (a
    # server-side Map step, not client traffic); submissions still in
    # flight at a step boundary are dropped — the next step's model
    # structure invalidates them.  None (default) keeps the sync loop.
    async_agg: AS.AsyncConfig = None


class ProFLServer:
    def __init__(
        self,
        cfg: C.CNNConfig,
        fl: FLConfig,
        xtr: np.ndarray,
        ytr: np.ndarray,
        xte: np.ndarray,
        yte: np.ndarray,
        parts: List[np.ndarray],  # per-client index sets
        budgets_mb: np.ndarray,
    ):
        self.cfg, self.fl = cfg, fl
        self.xtr, self.ytr, self.xte, self.yte = xtr, ytr, xte, yte
        self.parts, self.budgets = parts, budgets_mb
        self.rng = np.random.default_rng(fl.seed)
        key = jax.random.PRNGKey(fl.seed)
        self.params, self.bn_state = C.init_cnn(cfg, key, fl.ratio)
        self.init_params = copy.deepcopy(self.params)  # shrinking prefix
        self.head = self.params["head"]
        self.proxies: Dict[int, dict] = {}  # block id -> proxy params
        self.init_bank: Dict[int, dict] = {}  # θ_t^ini from shrinking
        self.history: List[dict] = []
        self.total_uplink_params = 0
        self._key = key
        self.engine = ENG.make_engine(fl.engine)
        self._fault_rounds = 0  # global round counter for FaultPlan sampling
        # async aggregation state (fl.async_agg): lazily (re)built per model
        # structure — a ProFL step change invalidates the buffered column
        # space, so the server and its arrival schedule start fresh
        self._async_srv: AS.AsyncAggServer = None
        self._async_sim: AS.ArrivalSimulator = None
        self._async_spec = None
        self._async_round = 0
        # cumulative step-boundary drop counters (ISSUE 10 bugfix): rows /
        # resident bytes of buffered + in-flight submissions discarded when
        # a model-structure change rebuilt the async server
        self.async_dropped_on_growth = 0
        self.async_dropped_bytes_on_growth = 0

    def _next_fault_plan(self, k_total: int):
        """Deterministic per-round FaultPlan under ``fl.faults`` (None when
        fault injection is off): a pure function of (faults.seed, global
        round index), so a run's fault trajectory is reproducible."""
        if self.fl.faults is None:
            return None
        self._fault_rounds += 1
        return FLT.sample_fault_plan(
            self.fl.faults, k_total, self._fault_rounds
        )

    def _async_grouped(self, plan, trainable, fro_cols):
        """One training round through the async server: the cohort becomes
        a versioned submission on the seeded arrival schedule; publishes
        fire whenever the buffer fills.  Returns the LAST publish's result,
        or None when nothing published this round (cohort in flight — the
        async steady state)."""
        ac = self.fl.async_agg
        spec_key = (ENG.make_pack_spec(trainable),
                    ENG.make_pack_spec(self.bn_state))
        if self._async_srv is None or self._async_spec != spec_key:
            if self._async_srv is not None:
                # step boundary under async aggregation (ISSUE 10 bugfix):
                # submissions buffered or still in flight were trained
                # against the OLD pack spec — the grown column space
                # invalidates them and they are dropped (re-projection onto
                # the new spec stays a ROADMAP residual).  The drop used to
                # vanish silently; count rows + resident bytes into
                # AGG_STATS (cumulative on the server too), with the bytes
                # pinned to the memory-model twin MM.async_buffer_bytes of
                # exactly the discarded buffer.
                dropped_rows = (self._async_srv.buffer_rows
                                + sum(int(item[0].xs.shape[0]) for _, _, item
                                      in self._async_sim._pending))
                dropped_bytes = self._async_srv.buffer_bytes()
                self.async_dropped_on_growth += dropped_rows
                self.async_dropped_bytes_on_growth += dropped_bytes
                ENG.AGG_STATS.update(
                    async_dropped_on_growth=self.async_dropped_on_growth,
                    async_dropped_bytes_on_growth=(
                        self.async_dropped_bytes_on_growth
                    ),
                )
            publish_at = ac.publish_at or int(plan.xs.shape[0])
            self._async_srv = AS.AsyncAggServer(
                self.engine, trainable, self.bn_state,
                publish_at=publish_at, beta=ac.beta,
                max_buffer=max(ac.max_buffer, publish_at),
                max_versions=ac.max_versions,
            )
            self._async_sim = AS.ArrivalSimulator(ac)
            self._async_spec = spec_key
        srv = self._async_srv
        srv.frozen = fro_cols
        arrived = self._async_sim.step(
            self._async_round, [(plan, srv.version)]
        )
        self._async_round += 1
        for p, ver in arrived:
            srv.submit(p, ver)
        res = None
        while srv.ready():
            res = srv.publish(faults_fn=self._next_fault_plan)
        if self.async_dropped_on_growth:
            # a publish clears AGG_STATS: keep the cumulative step-boundary
            # drop counters visible on every async round after the first drop
            ENG.AGG_STATS.update(
                async_dropped_on_growth=self.async_dropped_on_growth,
                async_dropped_bytes_on_growth=(
                    self.async_dropped_bytes_on_growth
                ),
            )
        return res

    # ------------------------------------------------------------------
    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def _select(self, need_mb: float):
        elig = MM.eligible(self.budgets, need_mb)
        pr = len(elig) / self.fl.n_clients
        if len(elig) == 0:
            return None, 0.0
        k = min(self.fl.clients_per_round, len(elig))
        sel = self.rng.choice(elig, k, replace=False)
        return sel, pr

    def _cohort_data(self, sel):
        xs, ys, w = [], [], []
        for cid in sel:
            xb, yb = DATA.client_batch(
                self.xtr, self.ytr, self.parts[cid], self.fl.n_local_fixed, self.rng
            )
            xs.append(xb)
            ys.append(yb)
            w.append(len(self.parts[cid]))
        return (
            jnp.asarray(np.stack(xs)),
            jnp.asarray(np.stack(ys)),
            jnp.asarray(np.array(w, np.float32)),
        )

    # ------------------------------------------------------------------
    def _output_module(self, t: int, rng) -> dict:
        T_ = self.cfg.n_prog_blocks
        proxies = []
        for b in range(t + 1, T_):
            if b in self.proxies:
                proxies.append(copy.deepcopy(self.proxies[b]))
            else:
                proxies.append(OM.init_cnn_proxy(self.cfg, rng, b, self.fl.ratio))
        return {"proxies": proxies, "head": copy.deepcopy(self.head)}

    def _train_step_t(self, stage: str, t: int) -> dict:
        """Train sub-model step t until the block freezes. Returns info."""
        cfg, fl = self.cfg, self.fl
        base = self.init_params if stage == "shrink" else self.params
        frozen, active = B.cnn_split(base, t)
        if stage == "grow" and t in self.init_bank:
            active = copy.deepcopy(self.init_bank[t])  # θ_t^ini initialization
        trainable = {"active": active, "op": self._output_module(t, self._next_key())}
        loss_fn = _make_cnn_loss(cfg, t, fl.ratio)
        need_mb = MM.submodel_train_memory_mb(cfg, t)
        em_state = EM.em_init(trainable)
        info = {"stage": stage, "t": t, "rounds": 0, "pr": 0.0}
        uplink = sum(x.size for x in jax.tree.leaves(trainable))

        # freezing-aware layouts: a per-PROXY FreezeTracker over stable
        # packed column ids.  Proxies that converge before the active block
        # leave the panel, the stream, and the kernel for the rest of the
        # step (grouped_round(frozen=...)) — the whole-tree em_state above
        # still decides when the STEP ends, engine-invariantly.
        tracker, fro_cols = None, None
        if fl.freeze_layouts and trainable["op"]["proxies"]:
            blocks = {
                f"['op']['proxies'][{i}]": ENG.columns_for_paths(
                    trainable, [f"['op']['proxies'][{i}]"]
                )
                for i in range(len(trainable["op"]["proxies"]))
            }
            tracker = EM.FreezeTracker(fl.em, blocks)

        for rnd in range(fl.max_rounds_per_step):
            sel, pr = self._select(need_mb)
            info["pr"] = pr
            if sel is None:
                break
            xs, ys, w = self._cohort_data(sel)
            rngs = jax.random.split(self._next_key(), len(sel))
            # ProFL rounds share the grouped entry point with the
            # heterogeneous baselines: one (degenerate) GroupPlan per round
            plan = ENG.GroupPlan(
                loss_fn, trainable, frozen, self.bn_state, xs, ys, rngs, w,
                fl.lr, fl.local_steps, fl.batch_size,
            )
            if fl.async_agg is not None:
                res = self._async_grouped(plan, trainable, fro_cols)
            else:
                res = self.engine.grouped_round(
                    [plan], trainable, self.bn_state, frozen=fro_cols,
                    faults=self._next_fault_plan(len(sel)))
            self.total_uplink_params += uplink * len(sel)
            info["rounds"] = rnd + 1
            if res is None:
                continue  # async: no publish this round — model unchanged,
                # so EM/freeze state must not observe a zero-movement step
            trainable, self.bn_state, loss = res.trainable, res.bn_state, res.loss
            # packed engines hand back the flat aggregated vector — feed EM
            # directly, skipping the per-round tree re-flatten
            flat = (res.packed if res.packed is not None
                    else EM.flatten_params(trainable))
            em_val = EM.em_update_flat(fl.em, em_state, flat)
            if tracker is not None and tracker.update(flat):
                fro_cols = ENG.frozen_columns_for_paths(
                    trainable, self.bn_state, tracker.frozen_names
                )
            rec = {
                "stage": stage, "t": t, "round": rnd, "loss": float(loss),
                "em": em_val, "pr": pr,
                "n_frozen": 0 if fro_cols is None else fro_cols.n_frozen,
            }
            if (rnd + 1) % fl.eval_every == 0:
                rec["sub_acc"] = self.eval_submodel(frozen, trainable, t)
            self.history.append(rec)
            if em_val is not None and EM.should_freeze(fl.em, em_state):
                break

        # freeze: persist the trained block + θ_L
        self.head = trainable["op"]["head"]
        if stage == "shrink":
            self.init_bank[t] = copy.deepcopy(trainable["active"])
            self.init_params = B.cnn_merge(self.init_params, trainable["active"], t)
            self._distill_proxy(t, trainable["active"])
        else:
            self.params = B.cnn_merge(self.params, trainable["active"], t)
            for i, b in enumerate(range(t + 1, cfg.n_prog_blocks)):
                self.proxies[b] = trainable["op"]["proxies"][i]
        self.params["head"] = self.head
        return info

    # ------------------------------------------------------------------
    def _distill_proxy(self, t: int, teacher_active: dict):
        """Map: federated KD of block t into proxy_t (paper Fig. 3)."""
        cfg, fl = self.cfg, self.fl
        frozen_prefix, _ = B.cnn_split(self.init_params, t)
        proxy = OM.init_cnn_proxy(cfg, self._next_key(), t, fl.ratio)
        map_loss = D.cnn_map_loss(cfg, t, fl.ratio)

        def loss_fn(proxy, frozen, bn_state, xb, yb):
            loss = map_loss(
                proxy, frozen["prefix"], frozen["teacher"], bn_state, xb
            )
            return loss, bn_state

        frozen = {"prefix": frozen_prefix, "teacher": teacher_active}
        need_mb = MM.submodel_train_memory_mb(cfg, t)
        for _ in range(fl.distill_rounds):
            sel, _ = self._select(need_mb)
            if sel is None:
                break
            xs, ys, w = self._cohort_data(sel)
            rngs = jax.random.split(self._next_key(), len(sel))
            plan = ENG.GroupPlan(
                loss_fn, proxy, frozen, self.bn_state, xs, ys, rngs, w,
                fl.distill_lr, fl.local_steps, fl.batch_size,
            )
            proxy = self.engine.grouped_round(
                [plan], proxy, self.bn_state
            ).trainable
        self.proxies[t] = proxy

    # ------------------------------------------------------------------
    def run(self) -> dict:
        steps = list(P.schedule(self.cfg.n_prog_blocks, self.fl.use_shrinking))
        step_infos = [self._train_step_t(stage, t) for stage, t in steps]
        return {
            "steps": step_infos,
            "final_acc": self.eval_full(),
            "history": self.history,
            "uplink_params": self.total_uplink_params,
        }

    # ------------------------------------------------------------------
    def eval_full(self) -> float:
        logits, _ = C.forward_cnn(
            self.cfg, self.params, self.bn_state,
            jnp.asarray(self.xte), train=True, ratio=self.fl.ratio,
        )
        return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(self.yte)))

    def eval_submodel(self, frozen, trainable, t) -> float:
        logits, _ = P.cnn_submodel_forward(
            self.cfg, frozen, trainable, self.bn_state,
            jnp.asarray(self.xte), t, train=True, ratio=self.fl.ratio,
        )
        return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(self.yte)))


# ---------------------------------------------------------------------------
# module-level loss factory with caching so cohort_round's jit cache hits
# across rounds of the same step
# ---------------------------------------------------------------------------

_LOSS_CACHE: ENG.BoundedCache = ENG.BoundedCache(maxsize=128)


def _make_cnn_loss(cfg: C.CNNConfig, t: int, ratio: float):
    key = (cfg, t, ratio)
    if key not in _LOSS_CACHE:
        _LOSS_CACHE[key] = P.cnn_submodel_loss(cfg, t, ratio)
    return _LOSS_CACHE[key]
