"""Deterministic fault injection for cohort rounds (ISSUE 8).

Real federated deployments lose clients mid-round (device death, network
partition), receive updates late (stragglers), and occasionally receive
garbage (overflowed local training, malicious updates).  This module makes
those failure modes REPRODUCIBLE FIXTURES rather than flaky simulations:

* :class:`ClientFault` — one client's verdict for one round:
  ``ok | dropped | straggler(delay) | corrupt(nan|inf|norm_blowup)``.
* :class:`FaultPlan` — the per-client verdict vector for a whole cohort
  (concatenated group order, exactly the order ``grouped_round`` sees the
  clients in) plus the fault-handling knobs: the on-device quarantine
  ``norm_bound``, the staleness discount base ``beta`` (a straggler merged
  ``s`` rounds late contributes with weight ``w·beta**s``), and the staging
  buffer capacity ``max_staged``.
* :class:`FaultConfig` + :func:`sample_fault_plan` — seeded Bernoulli
  sampling of plans (``np.random.default_rng((seed, round_idx))``), so a
  training loop's fault trajectory is a pure function of ``(seed, round)``
  — two processes with the same seed inject the identical faults.
* :func:`inject_panel` — the *injection hook*: perturbs one client's row of
  a group-local ``[K_g, n_g]`` panel AFTER local SGD, i.e. exactly the
  update that would hit the wire.  ``norm_blowup`` ADDS a large constant
  rather than multiplying, so exact-zero entries are perturbed too and the
  whole row trips the kernel quarantine gate (a multiplicative blowup would
  leave zeros untouched and split the row's verdict per column).

Handling semantics (fl/engine.py::grouped_round, kernels/fedavg.py):

* ``dropped`` clients become zero-weight panel columns — no re-trace, no
  new ``GroupLayout`` epoch; columns covered by nobody fall back to the
  kernels' existing zero-denominator→``prev`` passthrough.
* ``straggler`` panels park in a bounded staging buffer on the engine and
  merge into the round ``delay`` rounds later as associative num/den side
  inputs with the staleness-discounted weight ``w·beta**s``.
* ``corrupt`` rows ride the normal panel into the fused dispatch, where the
  per-entry quarantine gate (finite check + ``|update| > norm_bound``)
  zeroes the bad entries' weight INSIDE the kernel pass — no extra host
  sync, and the round still issues one dispatch and one
  ``block_until_ready``.

The async buffered-aggregation server (ISSUE 9,
``fl/async_server.py::AsyncAggServer``) reuses this machinery from the
other direction: stale buffered submissions park in the SAME engine staging
buffer and merge at the same ``w·beta**s`` discount, and a publish with
stale rows in flight arms an :func:`all_ok` plan at the server's ``beta``
(``max_staged`` raised to the staging occupancy) so the side merge rides
the one fused dispatch without perturbing fresh rows.  An explicitly
faulted async publish must carry the server's ``beta`` — one staleness
price per publish.

A fault-free plan (:func:`all_ok`) is bit-equal to running with
``faults=None``: the quarantine math degenerates exactly (all-false mask,
``den - 0.0``) and tests/test_contract.py pins it across the conformance
matrix.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence, Tuple

import numpy as np

KINDS = ("ok", "dropped", "straggler", "corrupt")
CORRUPT_MODES = ("nan", "inf", "norm_blowup")

# additive magnitude for the norm_blowup corruption: far above any realistic
# update yet far below f32 overflow, so the injected row is finite (the
# finite check alone won't catch it — only the norm bound does)
NORM_BLOWUP_ADD = 3e8


@dataclass(frozen=True)
class ClientFault:
    """One client's verdict for one round."""

    kind: str = "ok"
    delay: int = 0  # straggler: rounds the panel parks before merging
    mode: str = ""  # corrupt: one of CORRUPT_MODES

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"fault kind {self.kind!r} not in {KINDS}"
            )
        if self.kind == "straggler":
            if self.delay < 1:
                raise ValueError(
                    f"straggler delay must be >= 1 round, got {self.delay}"
                )
        elif self.delay != 0:
            raise ValueError(f"delay only applies to stragglers")
        if self.kind == "corrupt":
            if self.mode not in CORRUPT_MODES:
                raise ValueError(
                    f"corrupt mode {self.mode!r} not in {CORRUPT_MODES}"
                )
        elif self.mode:
            raise ValueError("mode only applies to corrupt verdicts")


OK = ClientFault()


@dataclass(frozen=True)
class FaultPlan:
    """Per-client verdicts for one cohort round, in the concatenated group
    order ``grouped_round`` sees the clients in (group 0's clients first).

    ``norm_bound`` is the kernel quarantine gate's magnitude bound: a panel
    entry with ``|update| > norm_bound`` (or non-finite) has its client's
    weight zeroed for that column inside the fused dispatch.  The default
    ``inf`` keeps the finite check only.  ``beta`` and ``max_staged``
    parameterize the straggler staging buffer (see module docstring)."""

    verdicts: Tuple[ClientFault, ...]
    norm_bound: float = math.inf
    beta: float = 0.5
    max_staged: int = 8

    def __post_init__(self):
        object.__setattr__(self, "verdicts", tuple(self.verdicts))
        for v in self.verdicts:
            if not isinstance(v, ClientFault):
                raise TypeError(f"verdicts must be ClientFault, got {v!r}")
        if not (self.norm_bound > 0):
            raise ValueError(
                f"norm_bound must be > 0 (use math.inf to disable), "
                f"got {self.norm_bound}"
            )
        if not (0.0 < self.beta <= 1.0):
            raise ValueError(f"beta must be in (0, 1], got {self.beta}")
        if self.max_staged < 0:
            raise ValueError(f"max_staged must be >= 0, got {self.max_staged}")

    @property
    def k_total(self) -> int:
        return len(self.verdicts)

    @property
    def any_faults(self) -> bool:
        return any(v.kind != "ok" for v in self.verdicts)

    def counts(self) -> dict:
        """Per-kind verdict counts — the host-side metadata twin that
        ``engine.AGG_STATS`` surfaces and ``fl/memory_model.py::
        fault_counts`` mirrors (both count the same plan, never a device
        value)."""
        c = {k: 0 for k in KINDS}
        for v in self.verdicts:
            c[v.kind] += 1
        return c

    def for_cohort(self, ks: Sequence[int]) -> Tuple[Tuple[ClientFault, ...], ...]:
        """Split the flat verdict vector back into per-group tuples for a
        cohort with ``ks[gi]`` clients in group ``gi``."""
        if sum(ks) != len(self.verdicts):
            raise ValueError(
                f"FaultPlan covers {len(self.verdicts)} clients but the "
                f"cohort has {sum(ks)} (groups {tuple(ks)})"
            )
        out, o = [], 0
        for k in ks:
            out.append(self.verdicts[o : o + k])
            o += k
        return tuple(out)


def all_ok(k_total: int, **kw) -> FaultPlan:
    """The fault-free plan: every client ``ok``.  Bit-equal to
    ``faults=None`` across the conformance matrix (the quarantine gate
    degenerates exactly at the default ``norm_bound=inf``)."""
    return FaultPlan(verdicts=(OK,) * k_total, **kw)


@dataclass(frozen=True)
class FaultConfig:
    """Seeded Bernoulli fault sampling for a training loop.  The per-round
    plan is a pure function of ``(seed, round_idx)`` — reproducible across
    processes (tests/test_fl.py pins the determinism of the underlying
    ``np.random.default_rng`` seeding)."""

    seed: int = 0
    p_drop: float = 0.0
    p_straggle: float = 0.0
    p_corrupt: float = 0.0
    max_delay: int = 2  # straggler delays sample uniformly from [1, max_delay]
    corrupt_modes: Tuple[str, ...] = CORRUPT_MODES
    norm_bound: float = math.inf
    beta: float = 0.5
    max_staged: int = 8

    def __post_init__(self):
        p = self.p_drop + self.p_straggle + self.p_corrupt
        if not (0.0 <= p <= 1.0):
            raise ValueError(f"fault probabilities sum to {p}, must be <= 1")
        if self.max_delay < 1:
            raise ValueError("max_delay must be >= 1")
        bad = set(self.corrupt_modes) - set(CORRUPT_MODES)
        if bad:
            raise ValueError(f"unknown corrupt modes {sorted(bad)}")


def sample_fault_plan(cfg: FaultConfig, k_total: int,
                      round_idx: int) -> FaultPlan:
    """Sample one round's :class:`FaultPlan` deterministically from
    ``(cfg.seed, round_idx)``."""
    rng = np.random.default_rng((cfg.seed, round_idx))
    u = rng.random(k_total)
    delays = rng.integers(1, cfg.max_delay + 1, size=k_total)
    modes = rng.choice(len(cfg.corrupt_modes), size=k_total)
    verdicts = []
    t_drop = cfg.p_drop
    t_strag = t_drop + cfg.p_straggle
    t_corr = t_strag + cfg.p_corrupt
    for i in range(k_total):
        if u[i] < t_drop:
            verdicts.append(ClientFault("dropped"))
        elif u[i] < t_strag:
            verdicts.append(ClientFault("straggler", delay=int(delays[i])))
        elif u[i] < t_corr:
            verdicts.append(
                ClientFault("corrupt", mode=cfg.corrupt_modes[int(modes[i])])
            )
        else:
            verdicts.append(OK)
    return FaultPlan(
        verdicts=tuple(verdicts), norm_bound=cfg.norm_bound,
        beta=cfg.beta, max_staged=cfg.max_staged,
    )


def _jitted_inject(mode: str):
    """Jitted row-perturbation for :func:`inject_panel`, cached per mode.
    Un-jitted ``.at[row]`` scatters pay a full op-by-op dispatch (~0.6 ms
    on CPU — enough to blow the bench's x1.15 quarantine-overhead gate on
    its own); jitting with ``row`` as an operand keeps the injection one
    cached scatter dispatch for any row of the same panel shape."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def inject(panel, row):
        if mode == "nan":
            return panel.at[row].set(jnp.nan)
        if mode == "inf":
            return panel.at[row].set(jnp.inf)
        # norm_blowup: ADD so exact-zero entries are perturbed too and the
        # whole row trips the |update| > norm_bound gate
        return panel.at[row].add(jnp.asarray(NORM_BLOWUP_ADD, panel.dtype))

    return inject


_INJECT_CACHE: dict = {}


def inject_panel(panel, row: int, fault: ClientFault):
    """Perturb client ``row`` of a group-local ``[K_g, n_g]`` panel after
    local SGD — the injection hook ``grouped_round`` applies before the
    panel enters the (possibly quantized/sharded) stream.  Every column of
    a group-local panel belongs to the group, so a whole-row perturbation
    never violates the engine's zero-outside-group scatter invariant."""
    if fault.kind != "corrupt":
        return panel
    fn = _INJECT_CACHE.get(fault.mode)
    if fn is None:
        fn = _INJECT_CACHE[fault.mode] = _jitted_inject(fault.mode)
    return fn(panel, row)
