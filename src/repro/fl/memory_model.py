"""Analytic training- and aggregation-memory estimators (paper §4.1 / Fig. 6).

Client eligibility follows the paper's setup: budgets are drawn uniformly
from 100–900 MB and a client joins a round iff its budget covers the
*training* footprint of the current sub-model — which we estimate at the
PAPER'S scale (full-width model, 32×32 inputs, local batch 128) even when
the simulation trains a width-reduced model, so participation rates match
the paper's regime (DESIGN.md §2).

Footprint model (f32):
    params_term = (params_active + params_op) × 3        (param+grad+SGD buf)
                + params_frozen × 1                       (weights only)
    act_term    = Σ_{units on the backward path} stored activations × B
                  (conv input + BN input + ReLU mask ≈ 3 tensors/unit)
    transient   = 2 × max unit output on the frozen prefix × B
peak ≈ params_term + act_term + transient.

:func:`server_aggregation_peak_bytes` models the OTHER side of the memory
wall — the server's fused grouped aggregation (fl/engine.py) — per
aggregation placement mode, so the column-sharded path's ``≈ K_total·n/D``
per-device claim is pinned by a regression test instead of vibes.

Two-tier hierarchical rounds (ISSUE 10): under ``grouped_round(...,
edges=E)`` the server never holds the ``[K_total, n]`` cohort panel at
all — its peak is the fan-in (``E`` edge partial pairs + the carrier
operands), modeled by :func:`hier_server_peak_bytes` with
:func:`edge_partial_bytes` as the per-edge term; both twin the engine's
``AGG_STATS["hier_server_peak_bytes"]`` / ``["hier_edge_partial_bytes"]``
exactly.  This module is also the round ADMISSION policy:
``fl/population.py`` filters cohort candidates through
:func:`submodel_train_memory_mb` (device side) and
:func:`server_aggregation_peak_bytes` (server side) — the memory wall
turned into a scheduler.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.models import cnn as C

BYTES = 4
# Calibrated so the full-model participation-rate ordering matches the
# paper's Tables 1–2 regime (r34/v16: 0%, r18: ~8%, v11 highest):
PAPER_BATCH = 144


def _unit_out_elems(u: C.Unit, side: int) -> int:
    out_side = side // u.stride
    if u.pool:
        out_side //= 2
    return out_side * out_side * u.cout


def _unit_act_elems(u: C.Unit, side: int) -> int:
    """Stored-activation elements for backward through this unit."""
    inp = side * side * u.cin
    out = _unit_out_elems(u, side)
    if u.kind == "basic":
        mid = (side // u.stride) ** 2 * u.cout
        extra = mid if u.down else 0
        return inp + 2 * mid + out + extra
    return inp + 2 * out


def _unit_params(u: C.Unit) -> int:
    if u.kind in ("stem", "vggconv"):
        return 9 * u.cin * u.cout + 2 * u.cout
    p = 9 * u.cin * u.cout + 9 * u.cout * u.cout + 4 * u.cout
    if u.down:
        p += u.cin * u.cout + 2 * u.cout
    return p


def _walk(cfg: C.CNNConfig, ratio: float = 1.0):
    """Yields (block_idx, unit, in_side) across the plan."""
    side = cfg.in_size
    for bi, blk in enumerate(C.build_plan(cfg, ratio)):
        for u in blk:
            yield bi, u, side
            side = side // u.stride // (2 if u.pool else 1)


def paper_scale(cfg: C.CNNConfig) -> C.CNNConfig:
    """Eligibility is ALWAYS judged at the paper's scale (full width, 32×32,
    batch 144) even when the simulation trains a reduced model — otherwise a
    width-0.25 sim makes every client eligible and the heterogeneity
    disappears (DESIGN.md §2)."""
    if cfg.width_mult == 1.0 and cfg.in_size == 32:
        return cfg
    return C.CNNConfig(cfg.kind, n_classes=cfg.n_classes, width_mult=1.0,
                       in_size=32)


def submodel_train_memory_mb(
    cfg: C.CNNConfig,
    t: int,  # active block (0-indexed); t == -1 -> head ("op only")
    *,
    batch: int = PAPER_BATCH,
    ratio: float = 1.0,
    full_model: bool = False,
) -> float:
    """Peak training memory (MB) of ProFL step t (or the full model),
    evaluated at paper scale regardless of the simulated width."""
    cfg = paper_scale(cfg)
    params_active = params_frozen = 0
    act = 0
    transient = 0
    feat_elems = C.feature_dim(cfg, ratio)
    for bi, u, side in _walk(cfg, ratio):
        pe = _unit_params(u)
        on_bwd = full_model or (bi == t)
        if on_bwd:
            params_active += pe
            act += _unit_act_elems(u, side) * batch
        else:
            params_frozen += pe
            if not full_model and (t < 0 or bi < t):
                transient = max(transient, 2 * _unit_out_elems(u, side) * batch)
    # output module: proxies for blocks t+1.. + head
    if not full_model and 0 <= t < cfg.n_prog_blocks - 1:
        chans = [3] + C.block_out_channels(cfg, ratio)
        sizes = C.block_spatial_sizes(cfg)
        for b in range(t + 1, cfg.n_prog_blocks):
            params_active += 9 * chans[b] * chans[b + 1] + 2 * chans[b + 1]
            act += 3 * sizes[b] ** 2 * chans[b + 1] * batch
    params_active += feat_elems * cfg.n_classes + cfg.n_classes  # head
    act += feat_elems * batch * 2
    total = (3 * params_active + params_frozen) * BYTES + (act + transient) * BYTES
    return total / 1e6


def full_train_memory_mb(cfg: C.CNNConfig, *, batch: int = PAPER_BATCH,
                         ratio: float = 1.0) -> float:
    return submodel_train_memory_mb(cfg, -1, batch=batch, ratio=ratio,
                                    full_model=True)


def head_only_memory_mb(cfg: C.CNNConfig, *, batch: int = PAPER_BATCH) -> float:
    """Clients below every block train only the output layer (paper §4.1)."""
    return submodel_train_memory_mb(cfg, -1, batch=batch, full_model=False)


def assign_budgets_mb(rng: np.random.Generator, n_clients: int,
                      lo: float = 100.0, hi: float = 900.0) -> np.ndarray:
    return rng.uniform(lo, hi, size=n_clients)


def eligible(budgets_mb: np.ndarray, need_mb: float) -> np.ndarray:
    return np.where(budgets_mb >= need_mb)[0]


def width_ratio_for_budget(
    cfg: C.CNNConfig, budget_mb: float,
    ratios=(1.0, 0.5, 0.25, 0.125),
    *, batch: int = PAPER_BATCH,
) -> Optional[float]:
    """Largest HeteroFL width ratio whose FULL-model training fits."""
    for r in ratios:
        if full_train_memory_mb(cfg, batch=batch, ratio=r) <= budget_mb:
            return r
    return None


def depth_for_budget(
    cfg: C.CNNConfig, budget_mb: float, *, batch: int = PAPER_BATCH
) -> int:
    """DepthFL: number of leading blocks (with their classifier) whose
    training fits. 0 = cannot train even one block."""
    for d in range(cfg.n_prog_blocks, 0, -1):
        mem = _depthfl_memory_mb(cfg, d, batch=batch)
        if mem <= budget_mb:
            return d
    return 0


# ---------------------------------------------------------------------------
# Server-side aggregation peak (fl/engine.py fused grouped rounds)
# ---------------------------------------------------------------------------

# mirrors repro.kernels.fedavg.AGG_TILE (this module stays jax-free; the
# cross-check test in tests/test_contract.py pins the two constants equal)
AGG_TILE = 128

# mirrors fl/engine.py::STREAM_ELEM_BYTES (wire dtypes of the group-panel
# stream; same cross-check test pins the two maps equal)
STREAM_ELEM_BYTES = {"f32": 4, "bf16": 2, "int8": 1}


def agg_columns_per_device(n: int, *, n_devices: int = 1,
                           agg: str = "replicated",
                           tile: int = AGG_TILE,
                           n_frozen: int = 0) -> int:
    """Columns of the shared ``[K_total, n_active]`` panel resident on ONE
    device under the given aggregation placement: all ``n_active`` when
    replicated, the tile-aligned ``ceil(ceil(n_active / D) / tile) · tile``
    column block when column-sharded over a ``D``-device ``model`` axis
    (fl/engine.py::GroupLayout.column_shards uses the same rounding over
    ``max(n_active, 1)``).

    ``n_frozen`` is the freezing-aware-layouts term: columns the engine's
    frozen-column epoch (fl/engine.py::FrozenColumns) dropped from the
    panel entirely, so ``n_active = n - n_frozen`` and the per-device
    figure DECAYS at each freeze point — the server-side half of the
    paper's peak-memory-decay claim."""
    if not 0 <= n_frozen <= n:
        raise ValueError(f"n_frozen={n_frozen} outside [0, {n}]")
    n_act = n - n_frozen
    if agg == "replicated":
        return n_act
    if agg != "sharded":
        raise ValueError(f"unknown agg mode {agg!r}")
    n_cols = -(-max(n_act, 1) // n_devices)
    return -(-n_cols // tile) * tile


def agg_stream_cols_per_device(n_g: int, *, n_devices: int = 1,
                               agg: str = "replicated",
                               tile: int = AGG_TILE,
                               n_frozen: int = 0) -> int:
    """Columns of one group's ``[K_g, n_live]`` panel transiently resident
    on ONE agg device PER STREAM PASS while the group streams into the
    shared panel: all ``n_live`` when replicated (the whole panel lands on
    the aggregation device), the tile-aligned even share
    ``min(n_live, ⌈⌈n_live/D⌉/tile⌉·tile)`` under the shard-local stream
    (fl/engine.py::GroupLayout.stream_plan uses the same ``m_chunk`` — a
    concentrated group streams in ≤ D passes of that width instead of one
    wide slice; the engine's module docstring records the transfer-pacing
    caveat on multiple passes being resident at once).

    ``n_frozen`` counts THIS GROUP'S columns dropped by the frozen-column
    epoch — stream_plan gathers only the live columns before staging, so
    ``n_live = n_g - n_frozen`` and frozen columns never cross the wire."""
    if not 0 <= n_frozen <= n_g:
        raise ValueError(f"n_frozen={n_frozen} outside [0, {n_g}]")
    n_live = n_g - n_frozen
    if agg == "replicated":
        return n_live
    if agg != "sharded":
        raise ValueError(f"unknown agg mode {agg!r}")
    even = -(-max(n_live, 0) // n_devices)
    return min(n_live, -(-even // tile) * tile)


def agg_stream_elems_per_device(k_g: int, n_g: int, *, n_devices: int = 1,
                                agg: str = "replicated",
                                tile: int = AGG_TILE,
                                n_frozen: int = 0) -> int:
    """Per-device transient elements of one group's stream buffer —
    ``K_g`` rows × :func:`agg_stream_cols_per_device` columns.  The engine
    records the measured counterpart in ``engine.AGG_STATS
    ["per_device_stream_elems"]`` (max over the round's groups, from the
    real transfer sharding); tests/test_contract.py pins the two equal."""
    return k_g * agg_stream_cols_per_device(
        n_g, n_devices=n_devices, agg=agg, tile=tile, n_frozen=n_frozen
    )


def _ragged_wire_cols(live: int, m_chunk: int, tile: int) -> int:
    """Interconnect columns one shard receives over a group's whole ragged
    stream: ``⌊live/m⌋`` full passes of ``m_chunk`` columns plus a final
    tile-aligned remainder slice (capped at ``m_chunk``) — exactly the
    per-shard sum of ``StreamPlan.widths`` the engine transfers
    (fl/engine.py; launch/mesh.py::put_model_ragged)."""
    full, rem = divmod(live, m_chunk)
    cols = full * m_chunk
    if rem:
        cols += min(m_chunk, -(-rem // tile) * tile)
    return cols


def agg_wire_bytes(groups, *, agg: str = "replicated", tile: int = AGG_TILE,
                   stream_dtype: str = "f32") -> int:
    """Logical interconnect bytes one fused grouped round's panel stream
    puts on the wire — the analytic twin of ``engine.AGG_STATS
    ["wire_bytes"]`` (tests/test_contract.py pins the two equal).

    ``groups`` is a sequence of per-group entries:

    * ``agg="replicated"`` — ``(K_g, n_live)``: the whole live group panel
      lands on the aggregation device, ``K_g · n_live`` elements (plus the
      ``[n_live]`` bf16 scale row, 2 B/column, under ``"int8"``).
    * ``agg="sharded"`` — ``(K_g, live_per_shard)`` with ``live_per_shard``
      the per-column-shard live column counts (length D): each shard
      receives its ragged :func:`_ragged_wire_cols` share of the ≤ D
      ``m_chunk``-column passes, and under ``"int8"`` each live slice adds
      its packed 4-bit scale exponents (``⌈width/2⌉`` bytes) plus the
      2-byte bf16 group base.

    Everything is plan metadata — this module stays jax-free and the
    engine's measured counterpart never syncs a device."""
    eb = STREAM_ELEM_BYTES[stream_dtype]
    total = 0
    for k_g, live in groups:
        if agg == "replicated":
            n_live = int(live)
            total += k_g * n_live * eb
            if stream_dtype == "int8":
                total += 2 * n_live
            continue
        if agg != "sharded":
            raise ValueError(f"unknown agg mode {agg!r}")
        per_shard = [int(x) for x in live]
        n_live = sum(per_shard)
        m_chunk = agg_stream_cols_per_device(
            n_live, n_devices=len(per_shard), agg="sharded", tile=tile
        )
        if m_chunk == 0:
            continue
        for ld in per_shard:
            total += k_g * _ragged_wire_cols(ld, m_chunk, tile) * eb
            if stream_dtype == "int8" and ld:
                full, rem = divmod(ld, m_chunk)
                total += full * (-(-m_chunk // 2) + 2)
                if rem:
                    w = min(m_chunk, -(-rem // tile) * tile)
                    total += -(-w // 2) + 2
    return total


def agg_wire_bytes_uniform(groups, *, agg: str = "replicated",
                           tile: int = AGG_TILE,
                           stream_dtype: str = "f32") -> int:
    """Counterfactual wire bytes of the PRE-ragged uniform axis-0-split
    transfer at the same dtype — every pass ships an ``m_chunk``-column
    (pad) row to EVERY shard.  Analytic twin of ``engine.AGG_STATS
    ["wire_bytes_uniform"]``; the ragged/uniform ratio it enables is the
    benchmark's concentrated-group transport headline."""
    eb = STREAM_ELEM_BYTES[stream_dtype]
    total = 0
    for k_g, live in groups:
        if agg == "replicated":
            n_live = int(live)
            total += k_g * n_live * eb
            if stream_dtype == "int8":
                total += 2 * n_live
            continue
        if agg != "sharded":
            raise ValueError(f"unknown agg mode {agg!r}")
        per_shard = [int(x) for x in live]
        n_shards = len(per_shard)
        m_chunk = agg_stream_cols_per_device(
            sum(per_shard), n_devices=n_shards, agg="sharded", tile=tile
        )
        if m_chunk == 0:
            continue
        n_chunks = max(-(-ld // m_chunk) for ld in per_shard)
        total += n_chunks * k_g * n_shards * m_chunk * eb
        if stream_dtype == "int8":
            total += n_chunks * n_shards * (-(-m_chunk // 2) + 2)
    return total


def fault_counts(kinds) -> dict:
    """Per-kind verdict counts of one round's fault plan — the analytic
    twin of ``engine.AGG_STATS``'s ``fault_ok`` / ``fault_dropped`` /
    ``fault_stragglers`` / ``fault_corrupt`` fields (and of
    ``fl/faults.py::FaultPlan.counts``).  ``kinds`` is a sequence of
    verdict kind strings; both sides count plan METADATA, never a device
    value, so tests pin them equal without a sync."""
    c = {"ok": 0, "dropped": 0, "straggler": 0, "corrupt": 0}
    for k in kinds:
        if k not in c:
            raise ValueError(f"unknown fault kind {k!r}")
        c[k] += 1
    return c


def fault_staging_bytes(widths, elem_bytes: int = 4) -> int:
    """Resident bytes of the engine's straggler staging buffer: one parked
    f32 row of ``width`` columns per entry (fl/engine.py::StagedPanel keeps
    the clean pre-quantization row, so the element size is 4 B regardless
    of the round's wire dtype).  Analytic twin of ``engine.AGG_STATS
    ["fault_staging_bytes"]``; :func:`server_aggregation_peak_bytes` takes
    the same figure as its ``staging_bytes`` term so parked stragglers
    join the server peak-memory model."""
    return sum(int(elem_bytes) * int(w) for w in widths)


def async_buffer_bytes(entries, elem_bytes: int = 4) -> int:
    """Resident bytes of the async server's submission buffer
    (fl/async_server.py::AsyncAggServer): one materialized f32 ``[k, n_g]``
    row panel per buffered submission, given as ``(k, n_g)`` pairs.  Rows
    are buffered pre-quantization (the wire dtype is a per-publish stream
    knob, not a buffer property), so the element size is 4 B.  Analytic
    twin of ``engine.AGG_STATS["async_buffer_bytes"]``; the bench gate
    pins buffer PEAK bytes against this figure."""
    return sum(int(elem_bytes) * int(k) * int(n_g) for k, n_g in entries)


def async_version_table_bytes(n_versions: int, n: int,
                              elem_bytes: int = 4) -> int:
    """Resident bytes of the async server's bounded checkout table: each
    retained version keeps one full ``[n]`` f32 global model copy (the
    packed trainable + bn column space).  Analytic twin of
    ``engine.AGG_STATS["async_version_table_bytes"]``."""
    return int(n_versions) * int(n) * int(elem_bytes)


def async_staleness_hist(staleness_rows) -> dict:
    """Staleness histogram ``{s: rows}`` from ``(s, rows)`` pairs — the
    host-side twin of ``engine.AGG_STATS["async_staleness_hist"]`` (the
    per-publish distribution of ``publish version − trained version``
    over published rows)."""
    h: dict = {}
    for s, k in staleness_rows:
        h[int(s)] = h.get(int(s), 0) + int(k)
    return h


def server_aggregation_peak_bytes(
    k_total: int,
    n: int,
    n_groups: int,
    *,
    n_devices: int = 1,
    agg: str = "replicated",
    groups: Optional[List[tuple]] = None,
    tile: int = AGG_TILE,
    elem_bytes: int = 4,
    n_frozen: int = 0,
    stream_dtype: Optional[str] = None,
    staging_bytes: int = 0,
) -> int:
    """Per-DEVICE peak bytes of the fused grouped aggregation
    (fl/engine.py::_grouped_fused with the ``fedavg_grouped`` kernel):

        panel   [K_total, n_dev]   — the scattered client panel
        gmask   [G, n_dev]         — group-compressed membership
        scratch [n_dev] × 4        — prev + num + den + out
        weights [K_total] + wsum [G]

    where ``n_dev`` is :func:`agg_columns_per_device` — the full ``n`` when
    replicated, the tile-aligned ``≈ n/D`` column block when sharded.  The
    panel term dominates (``K_total ×`` the rest), so sharding the columns
    divides server peak memory by ``D`` up to tile padding — the last
    single-device bottleneck the paper's memory-wall argument left open on
    the server tier.

    When ``groups`` is given — a sequence of per-group ``(K_g, n_g)`` pairs
    — the figure additionally includes the STREAM term: the transient
    per-device footprint of the largest group panel while it streams into
    the shared panel, ``max_g`` :func:`agg_stream_elems_per_device`.  Under
    the shard-local stream (``agg="sharded"``) that is
    ``max_g K_g·n_g/D + tile padding`` — the group panels are sliced per
    column shard on their source devices, so a near-full-width majority
    group can no longer transiently re-approach ``K·n`` on one agg device
    the way the PR 4 replicated stream allowed.  Without ``groups`` the
    figure covers the persistent buffers only (the PR 4 behavior).

    Freezing-aware layouts: ``n_frozen`` columns dropped by the engine's
    frozen-column epoch shrink EVERY term — panel, gmask, scratch, and
    stream all size over ``n_active = n - n_frozen`` (the engine rebuilds
    ``column_shards``/``stream_plan``/``stream_buffers`` over the
    compressed panel at each freeze event; fl/engine.py module docstring,
    "Freezing-aware layouts").  ``groups`` entries may carry a per-group
    frozen count as an optional third element ``(K_g, n_g, frozen_g)`` —
    omitted, a group is assumed fully live.  Per-device bytes therefore
    DECAY at each freeze point, and tests/test_contract.py pins this
    figure to the measured ``AGG_STATS`` across a freeze transition.

    ``stream_dtype`` sizes the panel and stream terms at the engine's wire
    dtype (fl/engine.py ``stream_dtype`` knob): the shared panel is BORN
    at that dtype, so its resident per-device bytes shrink by the same
    factor as the wire, and ``"int8"`` adds the resident ``[G, n_dev]``
    bf16 dequantization-scale panel.  ``None`` (default) keeps the uniform
    ``elem_bytes`` sizing — the pre-transport behavior, bit-compatible
    with existing callers.  The gmask/scratch/weight terms stay f32 either
    way (the kernel accumulates in f32).

    ``staging_bytes`` is the fault-tolerance term: the resident bytes of
    the engine's bounded straggler staging buffer
    (:func:`fault_staging_bytes` over the parked row widths — at most
    ``max_staged`` rows by construction), additive because the parked f32
    rows live beside the panel until their merge round."""
    n_dev = agg_columns_per_device(n, n_devices=n_devices, agg=agg, tile=tile,
                                   n_frozen=n_frozen)
    stream = max(
        (agg_stream_elems_per_device(g[0], g[1], n_devices=n_devices, agg=agg,
                                     tile=tile,
                                     n_frozen=g[2] if len(g) > 2 else 0)
         for g in groups),
        default=0,
    ) if groups else 0
    panel_eb = (elem_bytes if stream_dtype is None
                else STREAM_ELEM_BYTES[stream_dtype])
    scales = 2 * n_groups * n_dev if stream_dtype == "int8" else 0
    return panel_eb * (k_total * n_dev + stream) + elem_bytes * (
        n_groups * n_dev + 4 * n_dev + k_total + n_groups
    ) + scales + staging_bytes


def edge_partial_bytes(n: int, *, n_frozen: int = 0,
                       elem_bytes: int = 4) -> int:
    """Resident bytes of ONE edge aggregator's partial: the associative
    ``(num, den)`` pair — two f32 vectors over the ``n_active = n -
    n_frozen`` live panel columns (kernels/ops.py::fedavg_grouped_edge
    folds the edge's client rows into exactly this pair).  Analytic twin
    of ``engine.AGG_STATS["hier_edge_partial_bytes"]``; the edge→server
    uplink of a hierarchical round is ``E`` of these per round instead of
    ``K_total`` client rows."""
    if not 0 <= n_frozen <= n:
        raise ValueError(f"n_frozen={n_frozen} outside [0, {n}]")
    return elem_bytes * 2 * (n - n_frozen)


def hier_server_peak_bytes(n: int, n_edges: int, *, n_devices: int = 1,
                           agg: str = "replicated", tile: int = AGG_TILE,
                           n_frozen: int = 0) -> int:
    """Per-DEVICE peak bytes of the TOP (server) tier of a two-tier
    hierarchical round (fl/engine.py::_grouped_hier):

        partials  [2·E, n_dev]  — the E arriving edge (num, den) pairs
        reduced   [2, n_dev]    — the tree-reduced pair (the carrier side)
        carrier   [1, n_dev]    — the zero-weight single-row dispatch panel
        gmask     [1, n_dev]    + prev [n_dev] + w/wsum scalars

    where ``n_dev`` is :func:`agg_columns_per_device` over the live
    columns (partials and carrier column-shard over the ``model`` axis
    under ``agg="sharded"``, tile-padded like every other operand).  The
    cohort panel term (``K_total·n``, the dominant flat-round term in
    :func:`server_aggregation_peak_bytes`) is GONE: server peak is a
    function of fan-in ``E`` and the edge-partial width, not of cohort
    size — the bench gate pins the hierarchical figure strictly below the
    flat round's at the "cohort=512 from pop=1M" cell.  Analytic twin of
    ``engine.AGG_STATS["hier_server_peak_bytes"]`` (measured from array +
    sharding metadata; tests pin the two equal).  Straggler staging
    stays its own additive figure (:func:`fault_staging_bytes`), as in
    the flat model."""
    if n_edges < 0:
        raise ValueError(f"n_edges must be >= 0, got {n_edges}")
    n_dev = agg_columns_per_device(n, n_devices=n_devices, agg=agg,
                                   tile=tile, n_frozen=n_frozen)
    # 2E partial vectors + 2 reduced + carrier + gmask + prev, all f32,
    # plus the two carrier weight scalars
    return 4 * ((2 * n_edges + 5) * n_dev + 2)


def _depthfl_memory_mb(cfg: C.CNNConfig, depth: int, *, batch: int) -> float:
    cfg = paper_scale(cfg)
    params = act = 0
    for bi, u, side in _walk(cfg):
        if bi < depth:
            params += _unit_params(u)
            act += _unit_act_elems(u, side) * batch
    chans = C.block_out_channels(cfg)
    for b in range(depth):  # a classifier per trained block
        params += chans[b] * cfg.n_classes + cfg.n_classes
    return (3 * params * BYTES + act * BYTES) / 1e6
