"""FedBuff-style async buffered aggregation: drop the round barrier.

:class:`AsyncAggServer` (ISSUE 9) inverts the engine's control flow — a
VERSIONED global model server consumes a continuous stream of client update
submissions instead of running a synchronous round loop:

* every submission is tagged with the global **version** it trained against
  (``checkout()`` hands out ``(version, trainable, bn_state)``);
* submissions accumulate in a bounded FIFO **buffer** (whole-submission
  eviction, oldest first, when the row cap is exceeded);
* whenever the buffer holds ``publish_at`` rows the server **publishes** a
  new global version: buffered rows are folded through the engine's
  EXISTING associative staleness merge — stale rows (version < current)
  park in ``CohortEngine._staging`` as :class:`~repro.fl.engine.StagedPanel`
  entries and ride the ``(snum, sden)`` side inputs at the discounted
  weight ``w·β^s`` (``s`` = publish version − trained version), exactly
  :func:`repro.fl.engine._staged_side`'s semantics — so every publish is
  still ONE logical ``fedavg_grouped`` dispatch + ONE ``block_until_ready``
  and composes with every engine knob (``impl``, agg placement,
  ``stream_dtype``/``inflight``, :class:`FrozenColumns`, ``FaultPlan``).

**The sync round is the oracle, by construction.**  With staleness-0
scheduling and ``publish_at == cohort size``, a publish's buffer holds only
fresh plan submissions and the server makes the VERBATIM
``engine.grouped_round(plans, ...)`` call today's round loop makes — the
synchronous round is a special case of the async server, not a parallel
code path, and the conformance matrix pins the two bit-equal
(tests/test_contract.py's ASYNC axis).

Publishes are deterministic in the submission stream: buffered rows fold in
the canonical ``(version, tag, seq)`` order (``tag`` defaults to the arrival
sequence number), so any arrival-order permutation of same-version
submissions that carries stable tags publishes an identical model —
num/den associativity made testable (tests/test_properties.py).

A publish whose buffer holds ONLY stale rows still works: the server runs a
degenerate zero-weight dispatch whose side inputs carry the whole update
(``(0 + snum) / (0 + sden)`` with the kernels' zero-denominator → ``prev``
passthrough for untouched columns).  Such a publish reports loss 0.0 (side
rows carry no loss, matching the engine's straggler-merge semantics) and
runs replicated on the default device — a rows-only publish has no group
panel to place, so the agg knob has nothing to shard.

:class:`ArrivalSimulator` supplies deterministic seeded arrival schedules
(per-``(seed, round)`` latency draws via ``np.random.default_rng``) so a
run's staleness distribution is reproducible; :class:`AsyncConfig` is the
knob bundle ``FLConfig.async_agg``/the baselines wire through.  Buffer
occupancy, the staleness histogram, and the bounded version table are
surfaced through ``AGG_STATS`` ``async_*`` fields, twinned by
``fl/memory_model.py::async_buffer_bytes``/``async_version_table_bytes``/
``async_staleness_hist``.  Checkpointing: :func:`async_state_to_tree` /
:func:`async_state_from_tree` round-trip the version counter and buffer
contents (as materialized rows) through ``train/checkpoint.py``; a restored
mid-stream server's subsequent stale-row publishes are bit-identical to the
never-stopped server's.  Materialized row panels are device buffers and are
dropped by ``engine.clear_caches()`` (re-materialized on demand) via the
clear-hook this module registers at import.
"""
from __future__ import annotations

import weakref
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl import engine as ENG
from repro.fl import faults as FLT
from repro.fl import memory_model as MM
from repro.kernels import ops


class Submission:
    """One buffered client-update submission: either a live
    :class:`~repro.fl.engine.GroupPlan` (local training not yet run — the
    usual path) or pre-materialized rows (checkpoint restore, or the raw
    ``submit_rows`` wire API).  ``tag`` is the caller's stable ordering key
    for the canonical ``(version, tag, seq)`` publish order; it defaults to
    the arrival sequence number ``seq``."""

    __slots__ = ("plan", "rows", "version", "tag", "seq", "k", "n_cols")

    def __init__(self, *, plan, rows, version, tag, seq, k, n_cols):
        self.plan = plan  # GroupPlan | None
        self.rows = rows  # (vals [k, n_cols], weights [k], idx [n_cols]) | None
        self.version = version  # global version the update trained against
        self.tag = tag  # Optional[int] canonical ordering key
        self.seq = seq  # monotone arrival id
        self.k = k  # client rows
        self.n_cols = n_cols  # columns the update covers (n_g)

    @property
    def sort_key(self):
        return (self.version, self.seq if self.tag is None else self.tag,
                self.seq)


def _tree_cols(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


# live servers, so engine.clear_caches() can drop materialized row device
# buffers without a module cycle (engine never imports this module)
_SERVERS: "weakref.WeakSet[AsyncAggServer]" = weakref.WeakSet()


def _drop_all_row_buffers() -> None:
    for srv in list(_SERVERS):
        srv.drop_row_buffers()


ENG.register_clear_hook(_drop_all_row_buffers)


class AsyncAggServer:
    """Versioned buffered-aggregation server over a
    :class:`~repro.fl.engine.CohortEngine` (module docstring for the
    control-flow story).

    ``publish_at`` rows trigger a publish; ``beta`` prices staleness
    (merge weight ``w·β^s``); ``max_buffer`` bounds buffered rows (FIFO
    whole-submission eviction, a lone over-sized submission is kept);
    ``max_versions`` bounds the checkout version table.  ``frozen`` /
    ``impl`` / ``agg`` / ``stream_dtype`` / ``inflight`` are forwarded
    verbatim to ``engine.grouped_round`` on every fresh publish — the
    sync-oracle contract is that this call IS the sync round.  ``frozen``
    is a plain mutable attribute: a freeze epoch may advance between
    publishes (parked rows carry stable full-space ids, so narrowing
    composes)."""

    def __init__(self, engine: ENG.CohortEngine, trainable, bn_state, *,
                 publish_at: int, beta: float = 1.0, max_buffer: int = 256,
                 max_versions: int = 4, frozen=None, impl: Optional[str] = None,
                 agg: Optional[str] = None, stream_dtype: Optional[str] = None,
                 inflight: Optional[int] = None):
        if publish_at < 1:
            raise ValueError("publish_at must be >= 1")
        if not (0.0 < beta <= 1.0):
            raise ValueError("beta must be in (0, 1]")
        if max_buffer < publish_at:
            raise ValueError("max_buffer must be >= publish_at")
        if max_versions < 1:
            raise ValueError("max_versions must be >= 1")
        self.engine = engine
        self.trainable, self.bn_state = trainable, bn_state
        self.publish_at, self.beta = publish_at, beta
        self.max_buffer, self.max_versions = max_buffer, max_versions
        self.frozen = frozen
        self.impl, self.agg = impl, agg
        self.stream_dtype, self.inflight = stream_dtype, inflight
        self.version = 0
        self.publishes = 0
        self.evicted = 0  # cumulative rows dropped by buffer eviction
        self.buffer: List[Submission] = []
        self._versions: "OrderedDict[int, tuple]" = OrderedDict(
            {0: (trainable, bn_state)}
        )
        self._seq = 0
        self._n = (ENG.make_pack_spec(trainable).n
                   + ENG.make_pack_spec(bn_state).n)
        self._last_hist: dict = {}
        _SERVERS.add(self)

    # ------------------------------------------------------------------
    # client-facing API
    # ------------------------------------------------------------------
    @property
    def buffer_rows(self) -> int:
        return sum(e.k for e in self.buffer)

    def buffer_bytes(self) -> int:
        """Analytic f32 byte footprint of the buffered rows — the
        memory-model twin input (``MM.async_buffer_bytes``)."""
        return MM.async_buffer_bytes([(e.k, e.n_cols) for e in self.buffer])

    def checkout(self, version: Optional[int] = None):
        """``(version, trainable, bn_state)`` for a client to train
        against.  ``None`` → the current version; older versions stay
        checkable until they age out of the bounded table (KeyError)."""
        v = self.version if version is None else version
        tr, bn = self._versions[v]
        return v, tr, bn

    def submit(self, plan: ENG.GroupPlan, version: int, *,
               tag: Optional[int] = None) -> Submission:
        """Buffer one group's update as a live plan (local training runs
        lazily, against the trees ``plan`` itself carries — i.e. the
        version the client checked out)."""
        if not (0 <= version <= self.version):
            raise ValueError(
                f"submission version {version} outside [0, {self.version}]"
            )
        e = Submission(plan=plan, rows=None, version=version, tag=tag,
                       seq=self._seq, k=int(plan.xs.shape[0]),
                       n_cols=_tree_cols(plan.trainable)
                       + _tree_cols(plan.bn_state))
        self._seq += 1
        self.buffer.append(e)
        self._evict()
        return e

    def submit_rows(self, vals, weights, version: int, *, idx=None,
                    tag: Optional[int] = None) -> Submission:
        """Buffer pre-materialized update rows — the raw wire form
        (``vals [k, m]`` client-trained parameter rows, ``weights [k]``,
        ``idx [m]`` stable global column ids; ``None`` = the full column
        space).  Rows are held on HOST until a publish folds them."""
        if not (0 <= version <= self.version):
            raise ValueError(
                f"submission version {version} outside [0, {self.version}]"
            )
        vals = np.asarray(vals, np.float32)
        weights = np.asarray(weights, np.float32)
        idx = (np.arange(self._n, dtype=np.int64) if idx is None
               else np.asarray(idx, np.int64))
        if vals.ndim != 2 or vals.shape[1] != idx.shape[0]:
            raise ValueError(
                f"vals {vals.shape} does not cover idx {idx.shape}"
            )
        if weights.shape != (vals.shape[0],):
            raise ValueError("weights must be [k]")
        e = Submission(plan=None, rows=(vals, weights, idx), version=version,
                       tag=tag, seq=self._seq, k=int(vals.shape[0]),
                       n_cols=int(vals.shape[1]))
        self._seq += 1
        self.buffer.append(e)
        self._evict()
        return e

    def _evict(self) -> None:
        while self.buffer_rows > self.max_buffer and len(self.buffer) > 1:
            gone = self.buffer.pop(0)
            self.evicted += gone.k

    def ready(self) -> bool:
        return self.buffer_rows >= self.publish_at

    # ------------------------------------------------------------------
    # publish
    # ------------------------------------------------------------------
    def _materialize(self, e: Submission):
        """``(vals [k, n_g] f32, weights [k] np, idx [n_g] np)`` for a
        buffered submission, running the plan's local training if needed
        (cached on the entry; the cached device panel is what
        ``drop_row_buffers`` releases)."""
        if e.rows is not None:
            return e.rows
        plan = e.plan
        lay = ENG.make_group_layout([plan], self.trainable, self.bn_state,
                                    force_index=True)
        if lay.n != self._n:
            raise ValueError(
                f"submission column space {lay.n} != server space {self._n}"
            )
        eng = self.engine
        if eng.mode == "sharded" and eng.mesh is not None:
            a = ENG._align_for_mesh(eng.mesh, (
                plan.trainable, plan.frozen, plan.bn_state, plan.xs, plan.ys,
                plan.rngs,
            ))
            vals, _ = ENG._group_local_pack_sharded(
                plan.loss_fn, *a, lr=plan.lr, local_steps=plan.local_steps,
                batch_size=plan.batch_size, mesh=eng.mesh,
            )
        else:
            vals, _ = ENG._group_local_pack(
                plan.loss_fn, plan.trainable, plan.frozen, plan.bn_state,
                plan.xs, plan.ys, plan.rngs, lr=plan.lr,
                local_steps=plan.local_steps, batch_size=plan.batch_size,
            )
        rows = (vals.astype(jnp.float32), np.asarray(plan.weights, np.float32),
                lay.idx[0], )
        e.rows = rows
        return rows

    def drop_row_buffers(self) -> None:
        """Release cached materialized row panels for entries that can
        re-run their plan (checkpoint/clear_caches hygiene: buffered device
        buffers must not pin HBM across a cache clear).  Row-only entries
        (``plan is None``) hold host arrays and keep them."""
        for e in self.buffer:
            if e.plan is not None:
                e.rows = None

    def _park_stale(self, entries: Sequence[Submission],
                    fault_round: int) -> dict:
        """Park every row of ``entries`` in the engine staging buffer so the
        publish's ONE dispatch folds them as ``w·β^s`` side inputs:
        ``born = fault_round − s`` makes the engine's
        ``β**(fault_round − born)`` discount exactly ``β**s``."""
        hist: dict = {}
        for e in entries:
            vals, w, idx = self._materialize(e)
            s = self.version - e.version
            hist[s] = hist.get(s, 0) + e.k
            for r in range(e.k):
                self.engine._staging.append(ENG.StagedPanel(
                    vals=jnp.asarray(vals[r], jnp.float32), idx=idx,
                    weight=float(w[r]), born=fault_round - s,
                    due=fault_round, n=self._n,
                ))
        return hist

    def publish(self, *, faults: Optional[FLT.FaultPlan] = None,
                faults_fn: Optional[Callable[[int], object]] = None):
        """Drain the buffer into ONE new global version (module docstring
        for semantics).  ``faults`` arms the publish's fresh cohort with an
        explicit :class:`FaultPlan` (its ``beta`` must match the server's
        when stale rows are in flight — one staleness price per publish);
        ``faults_fn(k_fresh)`` lazily samples one sized to the fresh
        cohort.  Returns the engine's :class:`GroupedResult`."""
        if not self.buffer:
            raise ValueError("publish() with an empty buffer")
        pre_rows, pre_bytes = self.buffer_rows, self.buffer_bytes()
        entries = sorted(self.buffer, key=lambda e: e.sort_key)
        self.buffer = []
        fresh = [e for e in entries
                 if e.plan is not None and e.version == self.version]
        stale = [e for e in entries if e not in fresh]
        k_fresh = sum(e.k for e in fresh)
        fplan = faults if faults is not None else (
            faults_fn(k_fresh) if faults_fn is not None else None
        )
        eng = self.engine
        hist = self._park_stale(stale, eng._fault_round + 1)
        hist_fresh = dict(hist)
        if k_fresh:
            hist_fresh[0] = hist_fresh.get(0, 0) + k_fresh

        if fresh:
            if fplan is None and eng._staging:
                # staging in flight needs an ARMED plan for the side merge;
                # all-ok at the server's β keeps fresh rows untouched
                fplan = FLT.all_ok(
                    k_fresh, beta=self.beta,
                    max_staged=max(8, len(eng._staging)),
                )
            elif fplan is not None and stale and fplan.beta != self.beta:
                raise ValueError(
                    f"FaultPlan.beta={fplan.beta} != server beta={self.beta}"
                    " with stale rows in flight — one staleness price per"
                    " publish"
                )
            # THE sync round: at staleness 0 with publish_at == cohort size
            # this call is bit-identical to today's grouped_round loop
            res = eng.grouped_round(
                [e.plan for e in fresh], self.trainable, self.bn_state,
                impl=self.impl, agg=self.agg, frozen=self.frozen,
                stream_dtype=self.stream_dtype, inflight=self.inflight,
                faults=fplan,
            )
        else:
            res = self._publish_side_only(fplan)

        self.version += 1
        self.publishes += 1
        self.trainable, self.bn_state = res.trainable, res.bn_state
        self._versions[self.version] = (self.trainable, self.bn_state)
        while len(self._versions) > self.max_versions:
            self._versions.popitem(last=False)
        self._last_hist = hist_fresh
        ENG.AGG_STATS.update(
            async_version=self.version,
            async_publishes=self.publishes,
            async_published_rows=pre_rows,
            async_fresh_rows=k_fresh,
            async_stale_rows=pre_rows - k_fresh,
            async_staleness_hist=hist_fresh,
            async_buffer_rows=pre_rows,
            async_buffer_bytes=pre_bytes,
            async_buffer_evicted=self.evicted,
            async_versions_retained=len(self._versions),
            async_version_table_bytes=MM.async_version_table_bytes(
                len(self._versions), self._n
            ),
        )
        return res

    def _publish_side_only(self, fplan):
        """Degenerate publish with no fresh plans: a zero-weight single-row
        carrier dispatch whose ``(snum, sden)`` side inputs hold the entire
        update — ``(0 + snum)/(0 + sden)`` with zero-denominator → ``prev``
        passthrough.  Still one ``fedavg_grouped`` dispatch + one
        ``block_until_ready``; loss is 0.0 (side rows carry no loss).  Runs
        replicated on the default device — with no group panel there is
        nothing for the agg placement to shard."""
        eng = self.engine
        eng._fault_round += 1
        fr = eng._fault_round
        due, evicted = ENG._collect_due_staged(eng._staging, fr, self._n)
        max_staged = fplan.max_staged if fplan is not None else max(
            8, len(eng._staging)
        )
        while len(eng._staging) > max_staged:
            eng._staging.pop(0)
        snum, sden = ENG._staged_side(due, self.beta, fr, self._n)
        spec_tr = ENG.make_pack_spec(self.trainable)
        spec_bn = ENG.make_pack_spec(self.bn_state)
        # the globals may be committed to a multi-device mesh (a sharded
        # publish's output) — land them beside the side vectors first
        dev0 = jax.devices()[0]
        tr0 = jax.device_put(self.trainable, dev0)
        bn0 = jax.device_put(self.bn_state, dev0)
        prev = jnp.concatenate([spec_tr.pack(tr0), spec_bn.pack(bn0)])
        fro = self.frozen
        if fro is not None and not isinstance(fro, ENG.FrozenColumns):
            fro = ENG.make_frozen_columns(fro)
        if fro is not None:
            act = jnp.asarray(fro.active_idx)
            prev_a = jnp.take(prev, act)
            side = (jnp.take(snum, act), jnp.take(sden, act))
            n_act = fro.n_active
        else:
            prev_a, side, n_act = prev, (snum, sden), self._n
        flat = ops.fedavg_grouped(
            jnp.zeros((1, n_act), jnp.float32),
            jnp.zeros((1,), jnp.float32),
            jnp.ones((1, n_act), jnp.float32),
            jnp.zeros((1,), jnp.float32),
            prev_a, side=side,
        )
        flat = ENG._barrier(flat)
        full = prev.at[act].set(flat) if fro is not None else flat
        new_tr = spec_tr.unpack(full[: spec_tr.n])
        new_bn = spec_bn.unpack(full[spec_tr.n:])
        ENG.AGG_STATS.clear()
        ENG.AGG_STATS.update(
            agg="replicated", kernel="side_only", n=self._n,
            n_active=n_act, k_total=0,
            fault_merged_rows=len(due), fault_evicted_rows=evicted,
            fault_staged_rows=len(eng._staging),
            fault_staging_bytes=MM.fault_staging_bytes(
                [ent.idx.shape[0] for ent in eng._staging]
            ),
        )
        return ENG.GroupedResult(new_tr, new_bn, jnp.float32(0.0), flat)

    def poll(self, *, faults_fn: Optional[Callable[[int], object]] = None):
        """Publish while ``ready()``; returns the list of results (possibly
        empty — the no-publish case is the async steady state)."""
        out = []
        while self.ready():
            out.append(self.publish(faults_fn=faults_fn))
        return out


# ---------------------------------------------------------------------------
# deterministic arrival schedules + the FLConfig-facing knob bundle
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AsyncConfig:
    """Knobs for driving :class:`AsyncAggServer` from ``fl/server.py`` /
    ``fl/baselines.py`` (``FLConfig.async_agg``).  ``publish_at == 0``
    resolves to the first submission wave's cohort size (the sync-oracle
    cell); ``p_slow == 0`` is staleness-0 scheduling (every arrival is
    immediate) — together they reproduce the synchronous round bit-exactly."""

    publish_at: int = 0
    beta: float = 0.9
    max_buffer: int = 256
    max_versions: int = 4
    seed: int = 0
    p_slow: float = 0.0  # probability a submission is delayed
    max_delay: int = 2  # delayed submissions draw uniform from [1, max_delay]

    def __post_init__(self):
        if self.publish_at < 0:
            raise ValueError("publish_at must be >= 0 (0 = cohort size)")
        if not (0.0 < self.beta <= 1.0):
            raise ValueError("beta must be in (0, 1]")
        if self.max_buffer < 1 or self.max_versions < 1:
            raise ValueError("max_buffer and max_versions must be >= 1")
        if not (0.0 <= self.p_slow <= 1.0):
            raise ValueError("p_slow must be in [0, 1]")
        if self.max_delay < 1:
            raise ValueError("max_delay must be >= 1")


class ArrivalSimulator:
    """Deterministic seeded arrival schedule: each ``step(round_idx,
    items)`` draws every item's training latency from
    ``np.random.default_rng((seed, round_idx))`` — delay 0 with probability
    ``1 − p_slow``, else uniform in ``[1, max_delay]`` rounds — and returns
    the submissions that ARRIVE this round (this wave's on-time items plus
    earlier waves' delayed ones), ordered by ``(arrival round, submission
    seq)``.  A pure function of ``(cfg.seed, round sequence)``: staleness
    distributions are reproducible across runs and after restarts replaying
    the same rounds."""

    def __init__(self, cfg: AsyncConfig):
        self.cfg = cfg
        self._pending: list = []  # (ready_round, seq, item)
        self._seq = 0

    def step(self, round_idx: int, items: Sequence) -> list:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, round_idx))
        u = rng.random(len(items))
        d = rng.integers(1, cfg.max_delay + 1, size=len(items))
        for i, item in enumerate(items):
            delay = int(d[i]) if u[i] < cfg.p_slow else 0
            self._pending.append((round_idx + delay, self._seq, item))
            self._seq += 1
        arrived = sorted(
            (p for p in self._pending if p[0] <= round_idx),
            key=lambda p: (p[0], p[1]),
        )
        self._pending = [p for p in self._pending if p[0] > round_idx]
        return [item for _, _, item in arrived]

    @property
    def in_flight(self) -> int:
        return len(self._pending)


# ---------------------------------------------------------------------------
# checkpointing (train/checkpoint.py save/load round-trip)
# ---------------------------------------------------------------------------


def async_state_to_tree(srv: AsyncAggServer) -> dict:
    """Flat numpy tree of the server's restorable state: the version /
    publish / sequence / eviction counters plus every buffered submission
    as MATERIALIZED rows (live plans run their local training here — the
    rows, not the closures, are the durable wire state).  The version
    TABLE is deliberately not captured: a restored server re-seeds it with
    the restored current model only (older checkouts age out anyway)."""
    tree = {"__async__": np.asarray(
        [srv.version, srv.publishes, srv._seq, srv.evicted], np.int64
    )}
    for i, e in enumerate(srv.buffer):
        vals, w, idx = srv._materialize(e)
        tree[f"e{i}:vals"] = np.asarray(vals, np.float32)
        tree[f"e{i}:w"] = np.asarray(w, np.float32)
        tree[f"e{i}:idx"] = np.asarray(idx, np.int64)
        tree[f"e{i}:meta"] = np.asarray(
            [e.version, -1 if e.tag is None else e.tag], np.int64
        )
    return tree


def async_state_from_tree(srv: AsyncAggServer, tree: dict) -> AsyncAggServer:
    """Restore counters + buffer into ``srv`` (freshly constructed around
    the restored global model).  Buffered entries come back as row
    submissions; a restored STALE entry's subsequent publish is bit-equal
    to the never-stopped server's (same materialized f32 rows, same
    canonical fold order through ``_staged_side``)."""
    version, publishes, seq, evicted = (int(x) for x in tree["__async__"])
    srv.version, srv.publishes = version, publishes
    srv._seq, srv.evicted = seq, evicted
    srv._versions = OrderedDict({version: (srv.trainable, srv.bn_state)})
    srv.buffer = []
    i = 0
    while f"e{i}:vals" in tree:
        ver, tag = (int(x) for x in tree[f"e{i}:meta"])
        vals = np.asarray(tree[f"e{i}:vals"], np.float32)
        srv.buffer.append(Submission(
            plan=None, rows=(vals, np.asarray(tree[f"e{i}:w"], np.float32),
                             np.asarray(tree[f"e{i}:idx"], np.int64)),
            version=ver, tag=None if tag < 0 else tag, seq=len(srv.buffer),
            k=int(vals.shape[0]), n_cols=int(vals.shape[1]),
        ))
        i += 1
    return srv
