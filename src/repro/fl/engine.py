"""Sharded cohort execution engine.

``client.cohort_round`` (the oracle) vmaps all K clients on ONE device and
aggregates with a per-leaf einsum tree-map.  At production cohort sizes that
caps the round at single-device memory and leaves the fused Pallas
aggregation kernels idle.  This module executes the same round three ways:

* ``vmap``    — delegate to the oracle (bit-identical reference path).
* ``packed``  — vmap local SGD, then RAVEL every client's trainable + BN
                trees into one contiguous ``[K, n]`` f32 panel (cached
                treedef/offset spec) and aggregate with the Pallas ``fedavg``
                kernel: one HBM pass over the stacked params instead of a
                tree of K-way einsums.
* ``sharded`` — same packed aggregation, but local SGD runs under
                ``shard_map`` with clients split across a ``clients`` mesh
                axis (launch/mesh.py::make_client_mesh), so the cohort scales
                with device count.  K is padded up to a multiple of the axis
                size with zero-weight ghost clients.

The packed round also returns the aggregated flat trainable vector so the
server can feed effective movement (core/effective_movement.py::
em_update_flat) without re-flattening the tree every round — the EM update
itself is the fused Pallas ``effective_movement_update`` pass over exactly
this packed delta.

Equivalence to the oracle is asserted in tests/test_engine.py.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.fl import client as CL
from repro.kernels import ops

MODES = ("vmap", "packed", "sharded", "auto")


# ===========================================================================
# Packing: tree <-> contiguous flat f32 vector, with a cached spec
# ===========================================================================


@dataclass(frozen=True)
class PackSpec:
    """Ravel/unravel plan for one pytree structure.

    ``pack`` concatenates every leaf (cast to f32, matching the f32
    accumulation of the einsum oracle) into one [n] vector; ``unpack``
    restores shapes and original dtypes.  Built once per (treedef, avals)
    via :func:`make_pack_spec` and reused across rounds."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    n: int

    def pack(self, tree) -> jax.Array:
        leaves = self.treedef.flatten_up_to(tree)
        if not leaves:
            return jnp.zeros((0,), jnp.float32)
        return jnp.concatenate(
            [jnp.ravel(l).astype(jnp.float32) for l in leaves]
        )

    def pack_stacked(self, tree, k: int) -> jax.Array:
        """Leaves carry a leading client axis [K, ...] -> [K, n] panel."""
        leaves = self.treedef.flatten_up_to(tree)
        if not leaves:
            return jnp.zeros((k, 0), jnp.float32)
        return jnp.concatenate(
            [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves],
            axis=1,
        )

    def unpack(self, vec: jax.Array):
        leaves = [
            vec[o : o + s].reshape(sh).astype(dt)
            for o, s, sh, dt in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes
            )
        ]
        return self.treedef.unflatten(leaves)


_SPEC_CACHE: dict = {}


def make_pack_spec(tree) -> PackSpec:
    """Cached PackSpec for ``tree`` (keyed on treedef + leaf avals)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    key = (treedef, shapes, dtypes)
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        sizes = tuple(math.prod(s) for s in shapes)
        offsets, off = [], 0
        for s in sizes:
            offsets.append(off)
            off += s
        spec = PackSpec(treedef, shapes, dtypes, tuple(offsets), sizes, off)
        _SPEC_CACHE[key] = spec
    return spec


# ===========================================================================
# Round execution
# ===========================================================================


class RoundResult(NamedTuple):
    trainable: Any
    bn_state: Any
    loss: jax.Array
    packed: Optional[jax.Array]  # aggregated flat trainable (f32) or None


def _local_training(loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
                    *, lr, local_steps, batch_size):
    """vmap the per-client update — identical math to the oracle."""
    upd = CL.make_client_update(
        loss_fn, lr=lr, local_steps=local_steps, batch_size=batch_size
    )
    return jax.vmap(upd, in_axes=(None, None, None, 0, 0, 0))(
        trainable, frozen, bn_state, xs, ys, rngs
    )


def _packed_aggregate(trainable, bn_state, trs, bns, losses, weights):
    """One fused pass: pack (trainable, bn) panels, Pallas fedavg, unpack."""
    k = losses.shape[0]
    spec_tr = make_pack_spec(trainable)
    spec_bn = make_pack_spec(bn_state)
    panel_tr = spec_tr.pack_stacked(trs, k)
    panel_bn = spec_bn.pack_stacked(bns, k)
    panel = jnp.concatenate([panel_tr, panel_bn], axis=1)
    w = weights / jnp.sum(weights)
    flat = ops.fedavg(panel, w)
    new_tr = spec_tr.unpack(flat[: spec_tr.n])
    new_bn = spec_bn.unpack(flat[spec_tr.n :])
    # re-pack AFTER the unpack cast so the flat vector matches the tree's
    # leaf dtypes bit-for-bit (EM must see the same values either way)
    return new_tr, new_bn, jnp.sum(w * losses), spec_tr.pack(new_tr)


@functools.partial(
    jax.jit, static_argnames=("loss_fn", "lr", "local_steps", "batch_size")
)
def _round_packed(loss_fn, trainable, frozen, bn_state, xs, ys, rngs, weights,
                  *, lr, local_steps, batch_size):
    trs, bns, losses = _local_training(
        loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
        lr=lr, local_steps=local_steps, batch_size=batch_size,
    )
    return _packed_aggregate(trainable, bn_state, trs, bns, losses, weights)


@functools.partial(
    jax.jit,
    static_argnames=("loss_fn", "lr", "local_steps", "batch_size", "mesh"),
)
def _round_sharded(loss_fn, trainable, frozen, bn_state, xs, ys, rngs, weights,
                   *, lr, local_steps, batch_size, mesh):
    k = xs.shape[0]
    n_shards = mesh.shape["clients"]
    pad = (-k) % n_shards
    if pad:
        # ghost clients: replicate client 0's shard inputs at weight 0 so the
        # K axis divides the mesh; they drop out of the weighted aggregation.
        idx = jnp.concatenate([jnp.arange(k), jnp.zeros((pad,), jnp.int32)])
        xs, ys, rngs = xs[idx], ys[idx], rngs[idx]
        weights = jnp.concatenate([weights, jnp.zeros((pad,), weights.dtype)])

    def local(trainable, frozen, bn_state, xs, ys, rngs):
        trs, bns, losses = _local_training(
            loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
            lr=lr, local_steps=local_steps, batch_size=batch_size,
        )
        kl = losses.shape[0]
        panel_tr = make_pack_spec(trainable).pack_stacked(trs, kl)
        panel_bn = make_pack_spec(bn_state).pack_stacked(bns, kl)
        return jnp.concatenate([panel_tr, panel_bn], axis=1), losses

    panel, losses = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(), P(), P("clients"), P("clients"), P("clients")),
        out_specs=(P("clients"), P("clients")),
        check_rep=False,
    )(trainable, frozen, bn_state, xs, ys, rngs)

    spec_tr = make_pack_spec(trainable)
    spec_bn = make_pack_spec(bn_state)
    w = weights / jnp.sum(weights)
    flat = ops.fedavg(panel, w)
    new_tr = spec_tr.unpack(flat[: spec_tr.n])
    return (
        new_tr,
        spec_bn.unpack(flat[spec_tr.n :]),
        jnp.sum(w * losses),
        spec_tr.pack(new_tr),
    )


class CohortEngine:
    """Executes FL rounds under one of the MODES.  Stateless apart from the
    mesh; safe to share across server + baselines."""

    def __init__(self, mode: str = "vmap", mesh: Optional[Mesh] = None):
        if mode == "auto":
            mode = "sharded" if len(jax.devices()) > 1 else "packed"
        if mode not in ("vmap", "packed", "sharded"):
            raise ValueError(f"unknown engine mode {mode!r} (one of {MODES})")
        if mode == "sharded" and mesh is None:
            from repro.launch.mesh import make_client_mesh

            mesh = make_client_mesh()
        self.mode, self.mesh = mode, mesh

    def round(
        self,
        loss_fn: Callable,
        trainable,
        frozen,
        bn_state,
        xs,
        ys,
        rngs,
        weights,
        *,
        lr: float,
        local_steps: int,
        batch_size: int,
    ) -> RoundResult:
        kw = dict(lr=lr, local_steps=local_steps, batch_size=batch_size)
        if self.mode == "vmap":
            tr, bn, loss = CL.cohort_round(
                loss_fn, trainable, frozen, bn_state, xs, ys, rngs, weights,
                **kw,
            )
            return RoundResult(tr, bn, loss, None)
        if self.mode == "packed":
            return RoundResult(
                *_round_packed(
                    loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
                    weights, **kw,
                )
            )
        return RoundResult(
            *_round_sharded(
                loss_fn, trainable, frozen, bn_state, xs, ys, rngs, weights,
                mesh=self.mesh, **kw,
            )
        )


def make_engine(mode: str = "vmap", mesh: Optional[Mesh] = None) -> CohortEngine:
    return CohortEngine(mode, mesh)
