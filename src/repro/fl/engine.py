"""Sharded cohort execution engine.

``client.cohort_round`` (the oracle) vmaps all K clients on ONE device and
aggregates with a per-leaf einsum tree-map.  At production cohort sizes that
caps the round at single-device memory and leaves the fused Pallas
aggregation kernels idle.  This module executes the same round three ways:

* ``vmap``    — delegate to the oracle (bit-identical reference path).
* ``packed``  — vmap local SGD, then RAVEL every client's trainable + BN
                trees into one contiguous ``[K, n]`` f32 panel (cached
                treedef/offset spec) and aggregate with the Pallas ``fedavg``
                kernel: one HBM pass over the stacked params instead of a
                tree of K-way einsums.
* ``sharded`` — same packed aggregation, but local SGD runs under
                ``shard_map`` with clients split across a ``clients`` mesh
                axis (launch/mesh.py::make_client_mesh), so the cohort scales
                with device count.  K is padded up to a multiple of the axis
                size with zero-weight ghost clients.

The packed round also returns the aggregated flat trainable vector so the
server can feed effective movement (core/effective_movement.py::
em_update_flat) without re-flattening the tree every round — the EM update
itself is the fused Pallas ``effective_movement_update`` pass over exactly
this packed delta.

Grouped heterogeneous rounds
----------------------------
``CohortEngine.grouped_round(plans, ...)`` executes a cohort whose groups
train *different* sub-model structures (HeteroFL widths, DepthFL depths,
ProFL distill/train phases) and aggregates them in ONE fused dispatch.  Each
:class:`GroupPlan` carries a group's loss_fn, its trainable/bn trees (a
sliced or prefix view of the global trees), client data, and raw weights.
The panel layout:

* every group's vmapped (or shard_mapped) local SGD result is packed into
  its own ``[K_g, n_g]`` panel via the cached :class:`PackSpec` machinery;
* a cached :class:`GroupLayout` maps each group's flat coordinates into the
  GLOBAL flat space (trainable columns first, then bn columns) by matching
  leaf *paths* between the group tree and the global tree — a group leaf
  must be a leading-corner slice of (or identical to) the global leaf, which
  covers HeteroFL channel slicing, DepthFL block prefixes, and the identity;
* the group panels are scattered into one shared ``[K_total, n_global]``
  panel UNDER JIT (``lax.dynamic_update_slice`` into the group's contiguous
  row block, panel buffer donated so XLA updates in place) — no host round
  trip between group launches;
* one ``kernels.ops.fedavg_grouped`` dispatch computes the per-column ratio
  with the GROUP-COMPRESSED denominator.  Membership is identical for every
  client of a structure group, so the dense ``[K_total, n]`` mask collapses
  to a ``[G, n]`` group mask and per-group weight sums ``[G]``:

      out[j] = Σ_k w_k·p_kj / Σ_g wsum_g·gmask_gj     (denominator > 0)
      out[j] = prev[j]                                 (no group covers j)

  The numerator needs no mask at all because the scattered panel is zero
  outside each group's columns; only the denominator reads membership, and
  it reads K_total/G fewer mask elements than the per-client formulation
  (``fedavg_masked``, kept as the ``impl="fused_masked"`` escape hatch and
  benchmark comparison point via :attr:`GroupLayout.legacy_mask`).

Pipelining: the fused path issues every group's local-SGD dispatch and
panel scatter back to back without host blocking (jax async dispatch
pipelines them; the scatters are jitted with donated panel buffers) and
calls :func:`jax.block_until_ready` exactly ONCE, at the aggregation
barrier after the single ``fedavg_grouped`` dispatch — counted in ``SYNCS``
and asserted by a sync-counting shim in tests/test_engine.py.  In sharded
mode, groups map to DISJOINT contiguous slices of the ``clients`` mesh axis
(per-group sub-meshes, sized proportionally to K_g) so different structures
run concurrently on different devices instead of back-to-back over the full
mesh; when there are fewer devices than groups the full mesh is reused
per group as before.

Column-sharded aggregation (the ``agg`` knob)
---------------------------------------------
``grouped_round(..., agg=...)`` controls WHERE the fused aggregation runs:

* ``"replicated"`` — the PR 3 behavior: every group panel collects onto one
  device and the single ``fedavg_grouped`` dispatch reads the full
  ``[K_total, n]`` panel there, so server peak memory scales as ``K_total·n``
  on one chip.
* ``"sharded"``   — the panel is BORN column-sharded over a ``model`` mesh
  axis (``launch/mesh.py::make_model_mesh``, or the ``model`` axis of a
  composed ``clients × model`` mesh from ``make_fl_cohort_mesh``): columns
  are split into :data:`repro.kernels.fedavg.AGG_TILE`-aligned blocks
  (:meth:`GroupLayout.column_shards` caches the per-shard offsets), group
  panels stream into the per-shard buffers via shard-local
  ``dynamic_update_slice`` scatters, and ``kernels.ops.fedavg_grouped_sharded``
  runs the UNCHANGED shard-local kernel per device — the full shared panel
  never materializes anywhere, PERSISTENT per-device peak drops to
  ``≈ K_total·n/D`` (fl/memory_model.py::server_aggregation_peak_bytes
  models both modes).  The STREAM is shard-local too (see below): each
  finished ``[K_g, n_g]`` group panel is sliced per column shard on its
  SOURCE device(s) and each agg device receives only the group columns
  inside its own block, so the transient per-device peak is bounded by
  ``max_g K_g·(⌈n_g/D⌉ tile-aligned)`` — never the ``max_g K_g·n_g`` full
  replica a near-full-width majority group used to push back toward
  ``K·n``.
* ``"auto"``      — ``sharded`` when a multi-device ``model`` axis is
  available, else ``replicated``.

Shard-local group-panel streaming
--------------------------------
Under ``agg="sharded"`` the per-group stream is sharded end-to-end.  For
each group, :meth:`GroupLayout.stream_plan` partitions the group's global
column indices by destination column shard (host metadata, cached), and the
engine then

1. GATHERS each shard's columns out of the finished ``[K_g, n_g]`` panel on
   the panel's OWN source device(s) (``_stream_gather`` — the sub-mesh that
   ran the group's local SGD, or the default device in packed mode),
   producing a ``[D, K_g, m]`` selection buffer whose row ``d`` holds
   exactly the columns shard ``d`` owns;
2. lands that buffer axis-0-sharded over the agg mesh's ``model`` axis
   (``launch/mesh.py::put_model_sharded`` — one async ``device_put``; each
   agg device receives ONLY its ``[1, K_g, m]`` slice, never a replica);
3. scatters it shard-locally (``kernels.ops.scatter_stream_sharded``:
   read-modify-write of the donated per-shard panel block, out-of-range
   padding columns dropped device-side).

``m`` is capped at ``min(n_g, ⌈⌈n_g/D⌉/tile⌉·tile)``: when a group's
columns concentrate on few shards (a DepthFL prefix group lives entirely in
the leading shards), the stream is split into ≤ D passes of ``m`` columns
instead of one wide slice, so each PASS stages at most ``K_g·m`` elements
per device regardless of how the layout distributes — that per-pass figure
is what ``AGG_STATS`` measures and the memory model pins.

The transfers themselves are RAGGED: :class:`StreamPlan` records the
tile-aligned live width of every ``(pass, shard)`` slice (``widths``) and
``launch/mesh.py::put_model_ragged`` ships exactly those columns, zero-
padding back to the uniform ``m`` on the DESTINATION device — shards with
no live columns in a pass receive nothing at all (their slice is zeros
born on-device), so a fully concentrated group no longer broadcasts a pad
row to every shard and its aggregate interconnect traffic drops from
``D×`` useful bytes to ``≈ 1×``.  Balanced groups (HeteroFL widths) hit
the all-widths-equal fast path: one uniform async ``device_put``, exactly
the old transfer.  The device-side buffers keep the uniform
``[D, K_g, m]`` shape/sharding either way, so the per-pass staging bound
above is unchanged.

Successive passes are PACED by data-dependency tokens, not by the host:
each shard-local scatter returns, alongside the updated panel, a tiny
``[D]`` token sliced from the per-shard blocks it just consumed.  The
engine keeps the last ``inflight`` tokens in a deque; once it is full, the
next pass's SOURCE-side gather is gated on the oldest token via
``jax.lax.optimization_barrier`` (the token is device_put back to the
gather's placement — an async transfer, no sync).  A pass's transfer
therefore cannot launch until the pass ``inflight`` before it has retired
its scatter, bounding transient residency to ``inflight`` passes'
buffers per device while the round still issues exactly one
``block_until_ready``.  ``inflight`` is an engine knob (default 2 —
double-buffering: one pass in flight while the previous one drains).

Panels can be COMPRESSED on the wire via the ``stream_dtype`` engine knob
(``"f32"`` | ``"bf16"`` | ``"int8"``, default ``"f32"`` — bit-exact):
the finished group panel is quantized at the source, streamed and
scattered at the narrow dtype, and the shared panel itself is BORN at
that dtype — no agg device ever materializes an f32 group panel.  Under
``"int8"`` each column gets a power-of-two scale against a per-group bf16
base (``kernels/ref.py::quantize_columns``): the 4-bit scale exponents
travel packed two-per-byte beside the panel (~0.5 B/column,
``launch/mesh.py::put_scales_ragged``), are decoded to bf16 scale rows on
the destination shards, and dequantization happens INSIDE the fused
Pallas kernel (``fedavg_grouped_dequant``) — same single logical
dispatch.  A per-group error-feedback residual (carried across rounds on
the engine) makes the quantization unbiased over time.  ``"bf16"`` simply
halves the wire/panel bytes; the kernel accumulates in f32 either way and
the round output is always f32.  ``fused_masked`` has no dequant variant
and rejects ``stream_dtype != "f32"``; the serial oracle and the identity
fast path have no transport and ignore the knob.

The one-logical-dispatch / one-``block_until_ready`` contract is agg-mode
independent: ``DISPATCHES["fedavg_grouped"]`` still counts 1 per round, and
the per-shard kernel launches that one logical dispatch fans out to are
recorded separately under ``DISPATCHES["fedavg_grouped_shards"]`` (D per
round); the streaming scatters are counted under
``DISPATCHES["stream_scatter"]``/``["stream_scatter_shards"]``.
``AGG_STATS`` exposes the last round's per-device panel footprint from
sharding METADATA only (no device sync), plus the transient-stream fields:
``stream`` (placement mode), ``per_device_stream_elems`` (max per-device
footprint of any streamed group buffer, read from the real transfer
sharding — ``max_g K_g·n_g`` replicated, ``≤ max_g K_g·(⌈n_g/D⌉
tile-aligned)`` sharded), and ``stream_chunks`` (total PANEL scatter
passes — the int8 scale-row companion scatters are not counted).  The
transport fields make interconnect traffic a first-class metric, all
derived from plan metadata (never a sync): ``stream_dtype``, ``inflight``,
``panel_elem_bytes``, ``per_device_panel_bytes`` /
``per_device_scales_bytes`` (resident footprint at the wire dtype),
``per_device_stream_bytes``, and ``wire_bytes`` — the logical bytes the
round's panel stream put on the interconnect (ragged widths × element
bytes, plus packed scale slices under int8) — beside
``wire_bytes_uniform``, the counterfactual cost of the pre-ragged uniform
axis-0-split transfer at the same dtype.
``fl/memory_model.py::agg_stream_elems_per_device`` (and the wire-byte
twins ``agg_wire_bytes`` / ``agg_wire_bytes_uniform``) model the same
figures and tests/test_contract.py pins model == measurement.  The single-group
identity fast path keeps the PR 1 packed/sharded round regardless of
``agg`` — its panel has no group structure to column-shard.

Freezing-aware layouts (the ``frozen`` knob)
--------------------------------------------
``grouped_round(..., frozen=...)`` takes a frozen-column epoch — a ``[n]``
bool mask over the global ``[trainable | bn]`` packed space, normally built
from an effective-movement freeze decision via
:func:`frozen_columns_for_paths` — and drops those columns from the round
entirely: the shared panel, ``gmask``/``gmask_sharded``, ``column_shards``,
``stream_plan``/``stream_buffers``, and the ``fedavg_grouped`` dispatch all
shrink to the ``n_active`` surviving columns, so per-round aggregation work
and per-device panel/stream bytes DECAY at each freeze point (the paper's
peak-memory story; fl/memory_model.py carries the matching
``n_frozen`` term).  Clients still train their full sub-model locally —
freezing is an AGGREGATION decision: the server simply stops updating the
frozen columns, which keep their previous global values.

The re-layout invariant is stable global column ids:
:attr:`GroupLayout.idx` always records FULL-space column ids and a
:class:`FrozenColumns` epoch only REMAPS them onto the compressed panel
(:attr:`GroupLayout.dst`, frozen entries pointing at an out-of-range
sentinel the scatters drop device-side).  Freeze events therefore never
renumber columns — EM traces, checkpoints, and block→column maps keyed on
global ids stay valid across re-layouts.  The frozen-column lifecycle:

1. a freeze decision fires (``core/effective_movement.py::should_freeze``
   via a :class:`~repro.core.effective_movement.FreezeTracker`, wired
   through ``fl/server.py::_train_step_t`` and the baselines);
2. the caller passes the widened mask to ``grouped_round`` →
   :func:`make_group_layout` keys ``_LAYOUT_CACHE`` on the
   :class:`FrozenColumns` epoch (digest-hashed — two layouts differing only
   in frozen columns NEVER collide) and eagerly evicts superseded sibling
   layouts (same structure, strict-subset frozen mask, including the
   unfrozen original), dropping their device buffers so the wider panel's
   gmask/stream/index memory frees at the freeze point, not at LRU
   pressure;
3. the new layout rebuilds ``column_shards``/``stream_plan``/
   ``stream_buffers`` over the ``n_active`` columns — one ``≤ D``-pass
   shard-local stream per group as before, just narrower — and the round
   contracts (one logical dispatch, one ``block_until_ready``,
   replicated ≡ sharded bit-equality) hold unchanged across the
   transition (tests/test_contract.py's frozen conformance axis).

Fault-tolerant rounds (the ``faults`` knob)
-------------------------------------------
``grouped_round(..., faults=...)`` takes a seeded, deterministic
:class:`fl.faults.FaultPlan` — per-client verdicts ``ok | dropped |
straggler(delay) | corrupt(nan|inf|norm_blowup)`` in concatenated group
order — and degrades gracefully instead of poisoning the model:

* ``dropped`` clients become ZERO-WEIGHT panel rows: no re-trace, no new
  :class:`GroupLayout` epoch; columns covered by nobody fall back to the
  kernels' existing zero-denominator→``prev`` passthrough.
* ``straggler`` updates park in a bounded engine staging buffer (the clean
  f32 row + STABLE global column ids, captured before wire quantization
  and frozen narrowing) and merge into a later faults-armed round as
  associative ``(snum, sden)`` side inputs to the fused kernels at the
  staleness-discounted weight ``w·beta**s`` — num/den pairs are
  associative, so the merge is a per-column addition before the ratio:
  the direct stepping stone to a FedBuff-style async buffered server.
  The buffer holds at most ``max_staged`` rows (oldest evicted first) and
  evicts entries parked against a different column space.
* ``corrupt`` rows are injected AFTER local SGD (``fl/faults.py::
  inject_panel`` — the update that would hit the wire) and ride the
  normal stream into the one dispatch, where the fused QUARANTINE gate
  (per-entry finite check + ``|update| > norm_bound``) zeroes the bad
  entries' weight inside the kernel pass — no extra host sync, no second
  dispatch.

The amended round contracts: one logical ``fedavg_grouped`` dispatch and
one ``block_until_ready`` still hold under injection (the gate and the
merge are extra OPERANDS of the same ``pallas_call``, selected by a cached
kernel-body factory — a clean round still traces the untouched clean
bodies); a fault-free plan at the default ``norm_bound=inf`` is BIT-EQUAL
to ``faults=None`` (the gate degenerates to an all-false mask and
``den - 0.0``); and the serial oracle's semantics of record is corrupt ≡
dropped ≡ zero weight, which the quarantined fused round matches because
a fully-poisoned row trips the gate on every column.  ``AGG_STATS`` gains
the fault fields (``faults_armed``, ``quarantine_bound``, ``fault_ok`` /
``fault_dropped`` / ``fault_stragglers`` / ``fault_corrupt``,
``fault_merged_rows``, ``fault_evicted_rows``, ``fault_staged_rows``,
``fault_staging_bytes``) — all from plan + shape metadata, never a device
sync — twinned exactly by ``fl/memory_model.py::fault_counts`` /
``fault_staging_bytes``, and the staging bytes join the peak-memory model.

Two-tier hierarchical rounds (ISSUE 10).  ``grouped_round(...,
edges=E)`` with ``E > 1`` routes the fused path through ``E`` EDGE
aggregators instead of one shared panel: each edge folds its slice of
every group panel (deterministic round-robin over the concatenated
client order — row ``r`` of the cohort belongs to edge ``r % E``) into
an associative ``(num, den)`` partial via
``kernels/ops.py::fedavg_grouped_edge`` — exactly the per-row terms of
``fedavg_grouped``, including the quarantine gate and the int8
dequantization, evaluated at the edge.  The ``E`` partials reduce
tree-wise and enter the global round as ``(snum, sden)`` SIDE inputs of
a zero-weight single-row carrier dispatch (the PR 9
``_publish_side_only`` pattern), so the amended round contract holds
verbatim: still exactly ONE logical ``fedavg_grouped`` dispatch and ONE
``block_until_ready`` per round, with the per-edge launches reported
under ``DISPATCHES["fedavg_grouped_edges"]`` like the sharded per-shard
counters.  ``edges=1`` (or ``None``) routes VERBATIM to the flat fused
path — bit-equality at ``E=1`` is by construction, the same way sync
publishes are a special case of async.  The server never materializes
the ``[K_total, n]`` cohort panel: its peak is the fan-in — ``E``
partial pairs plus the carrier operands — measured into
``AGG_STATS["hier_server_peak_bytes"]`` (and per-edge
``hier_edge_partial_bytes``) from real array/sharding metadata and
twinned exactly by ``fl/memory_model.py::hier_server_peak_bytes`` /
``edge_partial_bytes``.  The serial oracle accepts and ignores
``edges`` (its host num/den accumulation is already edge-order-free);
``fused_masked`` rejects ``E > 1`` (its kernel has no side operands).

The serial per-group oracle (``impl="serial"``, default under the ``vmap``
mode) runs each group through ``client.cohort_round`` and accumulates the
same num/den host-side; equivalence is asserted in tests/test_engine.py.

Equivalence to the oracle across the full mode × impl × agg matrix is
asserted by the engine-contract conformance suite (tests/test_contract.py).
Module-level caches (_SPEC_CACHE, _LAYOUT_CACHE, the loss caches in
fl/server.py and fl/baselines.py) are bounded LRU maps; :func:`clear_caches`
empties them all and drops every cached layout's lazily-built device
buffers.
"""
from __future__ import annotations

import collections
import functools
import hashlib
import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.fl import client as CL
from repro.fl import faults as FLT
from repro.kernels import ops
from repro.kernels import ref as _kref
from repro.kernels.fedavg import AGG_TILE

MODES = ("vmap", "packed", "sharded", "auto")
AGG_MODES = ("auto", "replicated", "sharded")

# Wire dtypes the fused group-panel stream can travel at (module docstring,
# "Panels can be COMPRESSED on the wire").  Element bytes drive the logical
# wire/panel byte accounting in AGG_STATS and fl/memory_model.py.
STREAM_DTYPES = ("f32", "bf16", "int8")
STREAM_ELEM_BYTES = {"f32": 4, "bf16": 2, "int8": 1}
_STREAM_JNP = {"f32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}

# Host-sync accounting for the pipelined fused path: every block_until_ready
# the engine issues goes through _barrier and increments this counter.  The
# fused grouped round must show exactly one ("aggregation_barrier") per call.
SYNCS: collections.Counter = collections.Counter()

# Telemetry from the most recent fused grouped aggregation, recorded from
# sharding METADATA only (sharding.shard_shape — never a device sync):
# agg mode, shard count, padded width, and the per-device panel footprint.
# Tests and benchmarks assert the never-a-full-panel-on-one-device contract
# and report per-device panel bytes against it.
AGG_STATS: dict = {}


def reset_syncs() -> None:
    SYNCS.clear()


def _barrier(x):
    SYNCS["aggregation_barrier"] += 1
    return jax.block_until_ready(x)


class BoundedCache(collections.OrderedDict):
    """Tiny LRU map for module-level spec/layout/loss caches: long sweeps
    over many (cfg, t, ratio) keys must not grow memory without limit.

    Caveat for the loss caches: loss closures are jit static keys, so an
    evicted-then-recreated closure retraces its round on the next visit, and
    the evicted closure stays referenced by jax's jit cache until
    :func:`clear_caches` (which also calls ``jax.clear_caches``) runs.  Size
    the maxsize above the working set; the bound is a leak backstop, not a
    hot-path eviction policy.

    ``on_evict`` runs on each value as LRU eviction unlinks it — the layout
    cache uses it to drop device buffers on layouts a caller may still
    reference (the lazy properties rebuild on next use, so this is safe)."""

    def __init__(self, maxsize: int = 256, on_evict=None):
        super().__init__()
        self.maxsize = maxsize
        self.on_evict = on_evict

    def __getitem__(self, key):
        val = super().__getitem__(key)
        self.move_to_end(key)
        return val

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def __setitem__(self, key, val):
        super().__setitem__(key, val)
        self.move_to_end(key)
        while len(self) > self.maxsize:
            # NOT popitem(): OrderedDict.popitem re-enters __getitem__ after
            # unlinking the key, which would trip move_to_end
            lru = next(iter(self))
            if self.on_evict is not None:
                self.on_evict(super().__getitem__(lru))
            del self[lru]


# extension point: modules holding device buffers the FL layer should drop
# on clear_caches() register a zero-arg callable here (fl/async_server.py
# registers its buffered-row panel drop at import — engine never imports it)
_CLEAR_HOOKS: list = []


def register_clear_hook(fn) -> None:
    """Register ``fn`` to run inside :func:`clear_caches` (idempotent)."""
    if fn not in _CLEAR_HOOKS:
        _CLEAR_HOOKS.append(fn)


def clear_caches() -> None:
    """Empty every module-level cache in the FL layer (pack specs, group
    layouts, and the server/baseline loss caches), plus jax's jit caches —
    compiled rounds are keyed on loss-closure identity, so dropping the loss
    caches without the jit caches would leave the executables (and the
    evicted closures they reference) alive.  Cached :class:`GroupLayout`
    objects get their lazily-built device buffers (group mask, legacy mask)
    dropped explicitly: callers may still hold a layout reference after the
    cache entry is gone, and without the drop that reference keeps
    ``O(G·n)``/``O(K·n)`` of device memory alive for the session.
    Registered clear hooks run too (e.g. the async server's buffered
    materialized row panels — re-materialized on demand).  Wired into
    tests/conftest.py; also useful between long parameter sweeps."""
    for fn in list(_CLEAR_HOOKS):
        fn()
    for layout in _LAYOUT_CACHE.values():
        layout.drop_device_buffers()
    _SPEC_CACHE.clear()
    _LAYOUT_CACHE.clear()
    _SUBMESH_CACHE.clear()
    _slice_index.cache_clear()
    _sharded_zeros_fn.cache_clear()
    ops.clear_shard_caches()
    AGG_STATS.clear()
    from repro.fl import baselines as _bl
    from repro.fl import server as _srv

    _bl._LOSS_CACHE.clear()
    _srv._LOSS_CACHE.clear()
    try:
        jax.clear_caches()
    except AttributeError:  # very old jax without clear_caches
        pass


# ===========================================================================
# Packing: tree <-> contiguous flat f32 vector, with a cached spec
# ===========================================================================


@dataclass(frozen=True)
class PackSpec:
    """Ravel/unravel plan for one pytree structure.

    ``pack`` concatenates every leaf (cast to f32, matching the f32
    accumulation of the einsum oracle) into one [n] vector; ``unpack``
    restores shapes and original dtypes.  Built once per (treedef, avals)
    via :func:`make_pack_spec` and reused across rounds."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    n: int

    def pack(self, tree) -> jax.Array:
        leaves = self.treedef.flatten_up_to(tree)
        if not leaves:
            return jnp.zeros((0,), jnp.float32)
        return jnp.concatenate(
            [jnp.ravel(l).astype(jnp.float32) for l in leaves]
        )

    def pack_stacked(self, tree, k: int) -> jax.Array:
        """Leaves carry a leading client axis [K, ...] -> [K, n] panel."""
        leaves = self.treedef.flatten_up_to(tree)
        if not leaves:
            return jnp.zeros((k, 0), jnp.float32)
        return jnp.concatenate(
            [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves],
            axis=1,
        )

    def unpack(self, vec: jax.Array):
        leaves = [
            vec[o : o + s].reshape(sh).astype(dt)
            for o, s, sh, dt in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes
            )
        ]
        return self.treedef.unflatten(leaves)


_SPEC_CACHE: BoundedCache = BoundedCache(maxsize=256)


def make_pack_spec(tree) -> PackSpec:
    """Cached PackSpec for ``tree`` (keyed on treedef + leaf avals)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    key = (treedef, shapes, dtypes)
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        sizes = tuple(math.prod(s) for s in shapes)
        offsets, off = [], 0
        for s in sizes:
            offsets.append(off)
            off += s
        spec = PackSpec(treedef, shapes, dtypes, tuple(offsets), sizes, off)
        _SPEC_CACHE[key] = spec
    return spec


# ===========================================================================
# Frozen-column epochs: the freeze decision in layout space
# ===========================================================================


@dataclass(frozen=True, eq=False)
class FrozenColumns:
    """One frozen-column epoch of the global ``[trainable | bn]`` packed
    coordinate space: ``mask[j]`` is True when global column ``j`` has been
    frozen by an effective-movement decision and must leave the panel, the
    stream, and the kernel.

    Column ids are STABLE: a FrozenColumns never renumbers the global
    space — it only selects which columns survive (``active_idx``) so
    :func:`make_group_layout` can compress the panel to ``n_active``
    columns while every consumer keyed on global ids (EM traces,
    checkpoints, block→column maps) stays valid across freeze events.

    Equality and hash use ``(n, digest)`` — a sha1 prefix of the mask
    bytes — so epochs can key ``_LAYOUT_CACHE`` without O(n) mask
    comparisons per lookup, and two layouts differing only in frozen
    columns can never collide (the PR 6 cache-key bugfix)."""

    n: int
    mask: np.ndarray  # [n] bool, True = frozen (read-only)
    active_idx: np.ndarray  # [n_active] int64 global ids of live columns
    digest: str

    @property
    def n_active(self) -> int:
        return int(self.active_idx.size)

    @property
    def n_frozen(self) -> int:
        return self.n - self.n_active

    def __eq__(self, other) -> bool:
        return (isinstance(other, FrozenColumns)
                and self.n == other.n and self.digest == other.digest)

    def __hash__(self) -> int:
        return hash((self.n, self.digest))

    def supersedes(self, other: Optional["FrozenColumns"]) -> bool:
        """True when this epoch freezes a strict SUPERSET of ``other``'s
        columns (``other is None`` — the unfrozen layout — is superseded by
        any epoch).  Freezing is monotone forward over a run, so a layout
        superseded by a newly built epoch is stale and its device buffers
        can be dropped at the freeze event."""
        if other is None:
            return True
        return (self.n == other.n and self.n_frozen > other.n_frozen
                and bool(np.all(other.mask <= self.mask)))


def make_frozen_columns(mask) -> Optional[FrozenColumns]:
    """Build a :class:`FrozenColumns` epoch from a ``[n]`` bool mask
    (True = frozen).  An all-False mask returns None — the unfrozen layout
    needs no epoch object, and callers can pass the result straight to
    ``grouped_round(frozen=...)`` either way."""
    mask = np.ascontiguousarray(np.asarray(mask), dtype=bool).reshape(-1)
    if not mask.any():
        return None
    mask.setflags(write=False)
    digest = hashlib.sha1(mask.tobytes()).hexdigest()[:16]
    active = np.nonzero(~mask)[0].astype(np.int64)
    return FrozenColumns(int(mask.size), mask, active, digest)


def _path_columns(tree, spec: PackSpec, prefixes: Tuple[str, ...]) -> np.ndarray:
    parts = [
        np.arange(off, off + size, dtype=np.int64)
        for (path, _), off, size in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            spec.offsets, spec.sizes,
        )
        if any(jax.tree_util.keystr(path).startswith(p) for p in prefixes)
    ]
    if not parts:
        return np.zeros((0,), np.int64)
    return np.concatenate(parts)


def columns_for_paths(tree, prefixes) -> np.ndarray:
    """Packed column ids (``make_pack_spec(tree)`` order) of every leaf
    whose ``jax.tree_util.keystr`` path starts with one of ``prefixes`` —
    the bridge from a block-level freeze decision ("blocks[2] converged")
    to column coordinates."""
    return _path_columns(tree, make_pack_spec(tree), tuple(prefixes))


def frozen_columns_for_paths(global_trainable, global_bn,
                             prefixes) -> Optional[FrozenColumns]:
    """Frozen-column epoch over the ``[trainable | bn]`` global packed
    space freezing every leaf whose path starts with one of ``prefixes``
    in EITHER tree — a frozen block takes its BN statistics out of
    aggregation with it.  Returns None when no leaf matches."""
    spec_tr = make_pack_spec(global_trainable)
    spec_bn = make_pack_spec(global_bn)
    prefixes = tuple(prefixes)
    mask = np.zeros(spec_tr.n + spec_bn.n, bool)
    mask[_path_columns(global_trainable, spec_tr, prefixes)] = True
    mask[spec_tr.n + _path_columns(global_bn, spec_bn, prefixes)] = True
    return make_frozen_columns(mask)


# ===========================================================================
# Round execution
# ===========================================================================


class RoundResult(NamedTuple):
    trainable: Any
    bn_state: Any
    loss: jax.Array
    packed: Optional[jax.Array]  # aggregated flat trainable (f32) or None


def _local_training(loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
                    *, lr, local_steps, batch_size):
    """vmap the per-client update — identical math to the oracle."""
    upd = CL.make_client_update(
        loss_fn, lr=lr, local_steps=local_steps, batch_size=batch_size
    )
    return jax.vmap(upd, in_axes=(None, None, None, 0, 0, 0))(
        trainable, frozen, bn_state, xs, ys, rngs
    )


def _packed_aggregate(trainable, bn_state, trs, bns, losses, weights):
    """One fused pass: pack (trainable, bn) panels, Pallas fedavg, unpack."""
    k = losses.shape[0]
    spec_tr = make_pack_spec(trainable)
    spec_bn = make_pack_spec(bn_state)
    panel_tr = spec_tr.pack_stacked(trs, k)
    panel_bn = spec_bn.pack_stacked(bns, k)
    panel = jnp.concatenate([panel_tr, panel_bn], axis=1)
    w = weights / jnp.sum(weights)
    flat = ops.fedavg(panel, w)
    new_tr = spec_tr.unpack(flat[: spec_tr.n])
    new_bn = spec_bn.unpack(flat[spec_tr.n :])
    # re-pack AFTER the unpack cast so the flat vector matches the tree's
    # leaf dtypes bit-for-bit (EM must see the same values either way)
    return new_tr, new_bn, jnp.sum(w * losses), spec_tr.pack(new_tr)


@functools.partial(
    jax.jit, static_argnames=("loss_fn", "lr", "local_steps", "batch_size")
)
def _round_packed(loss_fn, trainable, frozen, bn_state, xs, ys, rngs, weights,
                  *, lr, local_steps, batch_size):
    trs, bns, losses = _local_training(
        loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
        lr=lr, local_steps=local_steps, batch_size=batch_size,
    )
    return _packed_aggregate(trainable, bn_state, trs, bns, losses, weights)


def _sharded_local_panel(loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
                         *, lr, local_steps, batch_size, mesh):
    """Local SGD under shard_map across the ``clients`` axis, returning the
    packed [K, n_tr + n_bn] panel and [K] losses (ghost padding stripped)."""
    k = xs.shape[0]
    n_shards = mesh.shape["clients"]
    pad = (-k) % n_shards
    if pad:
        # ghost clients: ZERO-pad the shard inputs so the K axis divides the
        # mesh; their rows are sliced off after the shard_map.  This must be
        # jnp.pad, not a gather/concat of client 0's rows: any gather-shaped
        # prologue feeding a shard_map over a composed clients×model mesh
        # miscompiles under jit on jax 0.4.37 (wrong rows land in the
        # middle shards; the 1-D clients mesh is unaffected) — exercised by
        # the 8-device subprocess test in tests/test_contract.py.
        wide = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        xs, ys, rngs = wide(xs), wide(ys), wide(rngs)

    def local(trainable, frozen, bn_state, xs, ys, rngs):
        trs, bns, losses = _local_training(
            loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
            lr=lr, local_steps=local_steps, batch_size=batch_size,
        )
        kl = losses.shape[0]
        panel_tr = make_pack_spec(trainable).pack_stacked(trs, kl)
        panel_bn = make_pack_spec(bn_state).pack_stacked(bns, kl)
        return jnp.concatenate([panel_tr, panel_bn], axis=1), losses

    panel, losses = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(), P(), P("clients"), P("clients"), P("clients")),
        out_specs=(P("clients"), P("clients")),
        check_rep=False,
    )(trainable, frozen, bn_state, xs, ys, rngs)
    return panel[:k], losses[:k]


@functools.partial(
    jax.jit,
    static_argnames=("loss_fn", "lr", "local_steps", "batch_size", "mesh"),
)
def _round_sharded(loss_fn, trainable, frozen, bn_state, xs, ys, rngs, weights,
                   *, lr, local_steps, batch_size, mesh):
    panel, losses = _sharded_local_panel(
        loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
        lr=lr, local_steps=local_steps, batch_size=batch_size, mesh=mesh,
    )
    spec_tr = make_pack_spec(trainable)
    spec_bn = make_pack_spec(bn_state)
    w = weights / jnp.sum(weights)
    flat = ops.fedavg(panel, w)
    new_tr = spec_tr.unpack(flat[: spec_tr.n])
    return (
        new_tr,
        spec_bn.unpack(flat[spec_tr.n :]),
        jnp.sum(w * losses),
        spec_tr.pack(new_tr),
    )


# ===========================================================================
# Grouped heterogeneous rounds: one fused dispatch for multi-structure cohorts
# ===========================================================================


class GroupPlan(NamedTuple):
    """One structure-group of a heterogeneous round.

    ``trainable``/``bn_state`` are the group's view of the global trees:
    every leaf must be a leading-corner slice of (HeteroFL widths) or
    identical to (DepthFL prefixes, ProFL) a global leaf at the same tree
    path.  ``weights`` are RAW aggregation weights (e.g. |D_k|) — the fused
    num/den ratio makes normalization unnecessary."""

    loss_fn: Callable
    trainable: Any
    frozen: Any
    bn_state: Any
    xs: jax.Array  # [K_g, n_local, ...]
    ys: jax.Array  # [K_g, n_local]
    rngs: jax.Array  # [K_g, 2]
    weights: jax.Array  # [K_g] raw weights
    lr: float
    local_steps: int
    batch_size: int


class GroupedResult(NamedTuple):
    trainable: Any
    bn_state: Any
    loss: jax.Array
    packed: Optional[jax.Array]  # aggregated flat trainable (f32) or None


@functools.lru_cache(maxsize=4096)
def _slice_index(gshape: Tuple[int, ...], sshape: Tuple[int, ...]) -> np.ndarray:
    """Flat positions of the leading-corner ``sshape`` slice inside a C-order
    flattened ``gshape`` leaf."""
    if gshape == sshape:
        return np.arange(math.prod(gshape), dtype=np.int64)
    if len(gshape) != len(sshape) or any(
        s > g for s, g in zip(sshape, gshape)
    ):
        raise ValueError(
            f"group leaf {sshape} is not a leading-corner slice of {gshape}"
        )
    return np.ravel_multi_index(np.indices(sshape), gshape).reshape(-1)


def _scatter_index(global_tree, global_spec: PackSpec, sub_tree) -> np.ndarray:
    """Map ``sub_tree``'s packed coordinates into ``global_tree``'s packed
    coordinate space by leaf-path matching."""
    gmap = {}
    for (path, leaf), off in zip(
        jax.tree_util.tree_flatten_with_path(global_tree)[0],
        global_spec.offsets,
    ):
        gmap[jax.tree_util.keystr(path)] = (off, tuple(leaf.shape))
    parts = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(sub_tree)[0]:
        key = jax.tree_util.keystr(path)
        if key not in gmap:
            raise ValueError(f"group leaf {key} has no global counterpart")
        off, gshape = gmap[key]
        parts.append(off + _slice_index(gshape, tuple(leaf.shape)))
    if not parts:
        return np.zeros((0,), np.int64)
    return np.concatenate(parts)


@dataclass(frozen=True)
class ColumnShards:
    """Tile-aligned column partition of the shared ``[K_total, n]`` panel
    across the ``model`` mesh axis: shard ``d`` owns the global column range
    ``[offsets[d], offsets[d] + n_shard)`` of the zero-padded ``n_padded``
    column space.  Alignment to ``tile`` (the Pallas lane width,
    kernels/fedavg.py::AGG_TILE) keeps every shard boundary on a kernel tile
    boundary."""

    n_shards: int
    tile: int
    n_shard: int  # columns per device
    n_padded: int  # n_shards * n_shard (>= n)
    offsets: Tuple[int, ...]  # global start column of each shard


@dataclass(frozen=True)
class StreamPlan:
    """Shard-local streaming plan for one group's ``[K_g, n_g]`` panel into
    the column-sharded shared panel: the group's global column indices are
    partitioned by destination column shard and split into ``n_chunks``
    passes of at most ``m_chunk`` columns per shard, so no single pass
    lands more than ``K_g·m_chunk`` elements of the group panel on an agg
    device.

    ``m_chunk = min(n_g, ⌈⌈n_g/D⌉/tile⌉·tile)`` — the tile-aligned even
    share — which makes the PER-PASS per-device stream bound
    ``K_g·n_g/D + K_g·tile`` hold regardless of how the group's columns
    distribute over the shards (a concentrated group just takes more
    passes, up to D of them; see the module docstring for the transfer-
    pacing caveat on simultaneous pass residency).

    ``src[c, d]`` are the source columns (positions in the group panel)
    shard ``d`` receives in pass ``c``; ``dst[c, d]`` the matching local
    columns inside shard ``d``'s block.  Unused slots are padded with
    ``n_g`` / ``n_shard`` respectively — the scatter drops them device-side
    (``mode="drop"``).

    The plan is RAGGED on the wire: ``chunk_counts[d]`` is how many passes
    shard ``d`` actually receives data in (``≤ n_chunks``; 0 for a shard
    with no live columns of this group) and ``widths[c, d]`` the tile-
    aligned live width of pass ``c``'s slice for shard ``d`` (0 = nothing
    ships).  ``launch/mesh.py::put_model_ragged`` transfers exactly
    ``widths[c, d]`` columns to shard ``d`` and zero-pads back to the
    uniform ``m_chunk`` ON the destination, so the device-side buffers (and
    the per-pass per-device staging bound) keep the uniform shape while the
    interconnect carries only ``Σ_c widths[c, d] =
    min-capped ⌈live_d/tile⌉·tile`` bytes per shard — a concentrated
    DepthFL group no longer broadcasts a pad row to every shard."""

    n_shards: int
    m_chunk: int
    n_chunks: int
    src: np.ndarray  # [n_chunks, D, m_chunk] int32, pad = n_g
    dst: np.ndarray  # [n_chunks, D, m_chunk] int32, pad = n_shard
    chunk_counts: Tuple[int, ...] = ()  # per-shard live pass counts
    widths: np.ndarray = np.zeros((0, 0), np.int32)  # [n_chunks, D] wire cols


@dataclass
class GroupLayout:
    """Cached scatter plan for one (global trees, group structures, frozen
    epoch) combo: column layout is [trainable columns | bn columns] in
    global pack order; rows are groups' clients stacked in plan order.

    ``idx`` always records STABLE full-space column ids; when a
    :class:`FrozenColumns` epoch is attached, ``dst`` remaps them onto the
    ``n_active``-column compressed panel (frozen entries point at the
    ``n_active`` sentinel and every scatter drops them device-side).  All
    panel-space machinery — ``gmask``, ``column_shards``, ``stream_plan``,
    the shared panel itself — is sized to ``n_active``, so frozen columns
    cost nothing per round."""

    gspec_tr: PackSpec
    gspec_bn: PackSpec
    n: int  # total GLOBAL columns (stable ids, frozen included)
    k_total: int  # total clients (rows)
    rows: Tuple[int, ...]  # per-group row offset
    ks: Tuple[int, ...]  # per-group client count
    idx: Tuple[np.ndarray, ...]  # per-group STABLE global column indices
    group_specs: Tuple[Tuple[PackSpec, PackSpec], ...]
    identity: bool  # single unfrozen group covering every column in order
    frozen: Optional[FrozenColumns]  # frozen-column epoch (None: all live)
    n_active: int  # panel width (== n when frozen is None)
    dst: Tuple[np.ndarray, ...]  # per-group PANEL-space scatter destinations
    _gmask: Optional[jax.Array] = None  # built lazily, [G, n_active] f32
    _legacy_mask: Optional[jax.Array] = None  # lazy, [k_total, n_active] f32
    _idx_dev: Optional[Tuple[jax.Array, ...]] = None  # lazy device dst
    _col_shards: Optional[dict] = None  # (n_shards, tile) -> ColumnShards
    _gmask_sharded: Optional[dict] = None  # mesh device ids -> sharded gmask
    _stream_plans: Optional[dict] = None  # (gi, n_shards, tile) -> StreamPlan
    _stream_dev: Optional[dict] = None  # (gi, mesh key) -> (src, dst) buffers
    _active_idx_dev: Optional[jax.Array] = None  # lazy [n_active] global ids
    _frozen_mask_dev: Optional[jax.Array] = None  # lazy [n] bool
    _live_pos_dev: Optional[Tuple[jax.Array, ...]] = None  # lazy live cols
    _gsel: Optional[jax.Array] = None  # lazy [k_total, G] row->group one-hot

    @property
    def n_groups(self) -> int:
        return len(self.ks)

    @property
    def idx_dev(self) -> Tuple[jax.Array, ...]:
        """Per-group PANEL-space scatter destinations on device — staged
        once per layout so the per-round jitted scatters don't re-upload
        O(n_g) index vectors every round.  For a frozen layout only the
        LIVE destinations are staged (ordered to match
        :attr:`live_pos_dev`'s column selection): the replicated scatter
        consumes the already-narrowed group panel."""
        if self._idx_dev is None:
            if self.frozen is None:
                self._idx_dev = tuple(jnp.asarray(d) for d in self.dst)
            else:
                self._idx_dev = tuple(
                    jnp.asarray(self.group_active_cols(gi))
                    for gi in range(self.n_groups)
                )
        return self._idx_dev

    @property
    def live_pos_dev(self) -> Tuple[jax.Array, ...]:
        """Per-group positions (columns of the local ``[K_g, n_g]`` panel)
        that survive freezing, staged UNCOMMITTED so the source-side
        ``_live_take`` gather runs wherever the group panel lives — frozen
        columns are dropped before the panel streams anywhere."""
        if self._live_pos_dev is None:
            assert self.frozen is not None
            self._live_pos_dev = tuple(
                jnp.asarray(np.nonzero(d < self.n_active)[0])
                for d in self.dst
            )
        return self._live_pos_dev

    @property
    def active_idx_dev(self) -> jax.Array:
        """``[n_active]`` stable global ids of the surviving panel columns,
        staged on device — the gather/expand map between the full ``prev``
        vector and the compressed kernel space (frozen layouts only)."""
        if self._active_idx_dev is None:
            assert self.frozen is not None
            self._active_idx_dev = jnp.asarray(self.frozen.active_idx)
        return self._active_idx_dev

    @property
    def frozen_mask_dev(self) -> jax.Array:
        """``[n]`` bool frozen mask on device (frozen layouts only) — the
        serial oracle's stop-updating overwrite reads it."""
        if self._frozen_mask_dev is None:
            assert self.frozen is not None
            self._frozen_mask_dev = jnp.asarray(self.frozen.mask)
        return self._frozen_mask_dev

    def group_active_cols(self, gi: int) -> np.ndarray:
        """Panel-space columns group ``gi`` actually writes — its ``dst``
        entries below ``n_active`` (all of them when nothing is frozen)."""
        d = self.dst[gi]
        return d[d < self.n_active]

    @property
    def gmask(self) -> jax.Array:
        """[G, n_active] per-GROUP membership (one row per structure
        group) — materialized on first use so the serial/identity paths
        (which never read it) pay nothing.  This is the only membership
        array the fused path stages: K_total/G smaller than the per-client
        mask.  Frozen columns have no panel slot, hence no mask entry."""
        if self._gmask is None:
            if self.identity:
                self._gmask = jnp.ones((1, self.n), jnp.float32)
            else:
                m = np.zeros((self.n_groups, self.n_active), np.float32)
                for gi in range(self.n_groups):
                    m[gi, self.group_active_cols(gi)] = 1.0
                self._gmask = jnp.asarray(m)
        return self._gmask

    @property
    def gsel(self) -> jax.Array:
        """``[k_total, G]`` row→group one-hot selector, staged lazily — the
        dequant kernel variants (``stream_dtype="int8"``) contract it
        against the ``[G, n]`` per-group scale rows to recover each row's
        per-column scale without a gather (an MXU-friendly matmul inside
        the Pallas kernel).  Rows of group ``gi`` are ``rows[gi] …
        rows[gi]+ks[gi]-1`` by layout construction."""
        if self._gsel is None:
            m = np.zeros((self.k_total, self.n_groups), np.float32)
            for gi, (r, k) in enumerate(zip(self.rows, self.ks)):
                m[r : r + k, gi] = 1.0
            self._gsel = jnp.asarray(m)
        return self._gsel

    @property
    def legacy_mask(self) -> jax.Array:
        """[k_total, n_active] per-CLIENT membership — escape hatch for the
        ``fedavg_masked`` oracle/benchmark path only; the fused round never
        materializes it (the group rows just repeat within each group)."""
        if self._legacy_mask is None:
            if self.identity:
                self._legacy_mask = jnp.ones((self.k_total, self.n),
                                             jnp.float32)
            else:
                m = np.zeros((self.k_total, self.n_active), np.float32)
                for gi, (r, k) in enumerate(zip(self.rows, self.ks)):
                    m[r : r + k, self.group_active_cols(gi)] = 1.0
                self._legacy_mask = jnp.asarray(m)
        return self._legacy_mask

    def column_shards(self, n_shards: int, tile: int = AGG_TILE) -> ColumnShards:
        """Cached tile-aligned column partition of this layout's
        ``n_active`` PANEL columns over ``n_shards`` devices (host metadata
        only — the offsets the sharded scatter and the memory model both
        key off).  A freeze event builds a NEW layout, so the partition
        shrinks with the panel and per-device column counts decay."""
        if self._col_shards is None:
            self._col_shards = {}
        key = (n_shards, tile)
        cs = self._col_shards.get(key)
        if cs is None:
            n_cols = -(-max(self.n_active, 1) // n_shards)
            n_shard = -(-n_cols // tile) * tile
            cs = ColumnShards(
                n_shards, tile, n_shard, n_shard * n_shards,
                tuple(i * n_shard for i in range(n_shards)),
            )
            self._col_shards[key] = cs
        return cs

    def gmask_sharded(self, mesh: Mesh) -> jax.Array:
        """``[G, n_padded]`` group mask, zero-padded to the tile-aligned
        column partition of ``mesh``'s ``model`` axis and committed
        column-sharded — cached per device set so rounds never re-upload
        membership.  Padded columns are zero, so their denominator is zero
        and the (also zero-padded) ``prev`` passes through."""
        if self._gmask_sharded is None:
            self._gmask_sharded = {}
        # key on the model-axis size too: two meshes over the SAME devices
        # with different model-axis sizes need different paddings, and a
        # device-ids-only key would hand the second one a stale gmask
        key = (tuple(d.id for d in mesh.devices.reshape(-1)),
               mesh.shape["model"])
        gm = self._gmask_sharded.get(key)
        if gm is None:
            cs = self.column_shards(mesh.shape["model"])
            padded = jnp.pad(
                self.gmask, ((0, 0), (0, cs.n_padded - self.n_active))
            )
            gm = jax.device_put(padded, NamedSharding(mesh, P(None, "model")))
            self._gmask_sharded[key] = gm
        return gm

    def stream_plan(self, gi: int, n_shards: int,
                    tile: int = AGG_TILE) -> StreamPlan:
        """Cached :class:`StreamPlan` for group ``gi`` over ``n_shards``
        column shards (host metadata only): partition the group's LIVE
        panel-space columns by destination shard and chunk each shard's
        share to at most ``m_chunk`` columns per pass.  Frozen columns are
        absent from the plan entirely — they are never gathered off the
        source device, never transferred, never scattered — and ``m_chunk``
        is sized from the live count, so the per-pass stream bound decays
        with the frozen fraction."""
        if self._stream_plans is None:
            self._stream_plans = {}
        key = (gi, n_shards, tile)
        sp = self._stream_plans.get(key)
        if sp is None:
            cs = self.column_shards(n_shards, tile)
            d_full = self.dst[gi]
            n_g = int(d_full.size)  # group panel width (frozen cols incl.)
            if self.frozen is None:
                pos, cols = None, d_full
            else:
                # positions within the group panel that survive, and the
                # panel-space columns they land on
                pos = np.nonzero(d_full < self.n_active)[0]
                cols = d_full[pos]
            n_live = int(cols.size)
            even = -(-n_live // n_shards) if n_live else 0  # ceil(n/D)
            m_chunk = min(n_live, -(-even // tile) * tile) if n_live else 0
            if m_chunk == 0:  # empty or fully frozen group: nothing streams
                sp = StreamPlan(n_shards, 0, 0,
                                np.zeros((0, n_shards, 0), np.int32),
                                np.zeros((0, n_shards, 0), np.int32),
                                (0,) * n_shards,
                                np.zeros((0, n_shards), np.int32))
            else:
                sels = [
                    np.nonzero((cols >= off) & (cols < off + cs.n_shard))[0]
                    for off in cs.offsets
                ]
                n_chunks = max(-(-s.size // m_chunk) for s in sels)
                src = np.full((n_chunks, n_shards, m_chunk), n_g, np.int32)
                dst = np.full((n_chunks, n_shards, m_chunk), cs.n_shard,
                              np.int32)
                widths = np.zeros((n_chunks, n_shards), np.int32)
                for d, sel in enumerate(sels):
                    for c in range(-(-sel.size // m_chunk)):
                        part = sel[c * m_chunk:(c + 1) * m_chunk]
                        spart = part if pos is None else pos[part]
                        src[c, d, : part.size] = spart
                        dst[c, d, : part.size] = cols[part] - cs.offsets[d]
                        widths[c, d] = min(
                            m_chunk, -(-int(part.size) // tile) * tile
                        )
                sp = StreamPlan(
                    n_shards, m_chunk, n_chunks, src, dst,
                    tuple(-(-int(s.size) // m_chunk) for s in sels), widths,
                )
            self._stream_plans[key] = sp
        return sp

    def stream_buffers(self, gi: int, mesh: Mesh, tile: int = AGG_TILE):
        """Device-staged per-pass ``(src, dst)`` index buffers for streaming
        group ``gi`` onto ``mesh``'s ``model`` axis, cached so rounds never
        re-upload them.  Each ``src`` is an UNCOMMITTED ``[D, m]`` int32 —
        it must follow the group panel's placement into the source-side
        gather jit, wherever local SGD ran — while each matching ``dst`` is
        COMMITTED axis-0-sharded on the agg mesh for the shard-local
        scatter."""
        if self._stream_dev is None:
            self._stream_dev = {}
        key = (gi, tuple(d.id for d in mesh.devices.reshape(-1)),
               mesh.shape["model"], tile)
        bufs = self._stream_dev.get(key)
        if bufs is None:
            sp = self.stream_plan(gi, mesh.shape["model"], tile)
            sh = NamedSharding(mesh, P("model", None))
            bufs = (
                tuple(jnp.asarray(sp.src[c]) for c in range(sp.n_chunks)),
                tuple(jax.device_put(sp.dst[c], sh)
                      for c in range(sp.n_chunks)),
            )
            self._stream_dev[key] = bufs
        return bufs

    def drop_device_buffers(self) -> None:
        """Release the lazily-built device buffers (group mask — replicated
        and column-sharded — legacy per-client mask, scatter indices, stream
        src/dst index buffers).  Called by :func:`clear_caches` on every
        cached layout so a layout reference that outlives its cache entry
        cannot pin mask/index buffers for the rest of the session."""
        self._gmask = None
        self._legacy_mask = None
        self._idx_dev = None
        self._gmask_sharded = None
        self._stream_dev = None
        self._active_idx_dev = None
        self._frozen_mask_dev = None
        self._live_pos_dev = None
        self._gsel = None


_LAYOUT_CACHE: BoundedCache = BoundedCache(
    maxsize=32, on_evict=lambda l: l.drop_device_buffers()
)

# per-(mesh devices, group sizes) disjoint sub-mesh splits for the sharded
# fused path; cleared together with the layouts in clear_caches()
_SUBMESH_CACHE: BoundedCache = BoundedCache(maxsize=32)


def _group_submeshes(mesh: Mesh, ks: Tuple[int, ...]):
    """Disjoint contiguous slices of the ``clients`` mesh axis, one sub-mesh
    per group, sized ~proportionally to the group's client count (largest-
    remainder apportionment, ≥1 slice each) so different structure groups'
    local SGD runs CONCURRENTLY on different devices instead of back-to-back
    time-sharing the full mesh.  For a composed ``clients × model`` mesh the
    split slices only the leading ``clients`` axis — each sub-mesh keeps the
    full ``model`` axis.  Returns None when the clients axis has fewer slots
    than groups (callers fall back to the full mesh per group)."""
    devs = mesh.devices if mesh.devices.ndim > 1 else mesh.devices.reshape(-1)
    nd, g = devs.shape[0], len(ks)
    if g < 2 or nd < g:
        return None
    key = (tuple(d.id for d in devs.reshape(-1)), devs.shape, ks)
    sub = _SUBMESH_CACHE.get(key)
    if sub is None:
        total = max(sum(ks), 1)
        alloc = [1] * g
        quota = [k * nd / total for k in ks]
        for _ in range(nd - g):
            gi = max(range(g), key=lambda i: quota[i] - alloc[i])
            alloc[gi] += 1
        bounds = np.cumsum([0] + alloc)
        axes = mesh.axis_names if devs.ndim > 1 else ("clients",)
        sub = tuple(
            Mesh(devs[bounds[i] : bounds[i + 1]], axes)
            for i in range(g)
        )
        _SUBMESH_CACHE[key] = sub
    return sub


def make_group_layout(plans: Sequence[GroupPlan], global_trainable,
                      global_bn, frozen=None,
                      force_index: bool = False) -> GroupLayout:
    """Cached :class:`GroupLayout` for ``plans`` against the global trees,
    optionally compressed by a frozen-column epoch (``frozen``: a
    :class:`FrozenColumns`, or a raw ``[n]`` bool mask normalized through
    :func:`make_frozen_columns`).

    The cache key includes the epoch (digest-hashed), so two layouts
    identical up to frozen columns NEVER collide; building a frozen layout
    eagerly evicts superseded siblings — same structural key, strict-subset
    frozen mask (the unfrozen original included) — and drops their device
    buffers, so each freeze event releases the wider panel's
    gmask/stream/index memory instead of waiting for LRU pressure.
    (Un-freezing isn't a thing mid-run; an out-of-order epoch just rebuilds
    its layout from host metadata.)

    ``force_index=True`` disables the single-group identity fast path so
    the layout always carries the full scatter-index machinery — an ARMED
    fault plan needs the general fused/serial paths (per-row parking and
    injection, quarantine operands) even for a ProFL identity cohort.  The
    flag only changes the result when the layout WOULD be identity, and
    the computed ``identity`` bit joins the cache key, so forced and fast
    layouts never collide."""
    gspec_tr = make_pack_spec(global_trainable)
    gspec_bn = make_pack_spec(global_bn)
    group_specs = tuple(
        (make_pack_spec(p.trainable), make_pack_spec(p.bn_state))
        for p in plans
    )
    ks = tuple(int(p.xs.shape[0]) for p in plans)
    n = gspec_tr.n + gspec_bn.n
    if frozen is not None and not isinstance(frozen, FrozenColumns):
        frozen = make_frozen_columns(frozen)
    if frozen is not None and frozen.n != n:
        raise ValueError(
            f"frozen mask covers {frozen.n} columns, layout has {n}"
        )
    # identity (every unfrozen ProFL round): group specs ARE the global
    # specs, so the scatter is arange(n) — skip building the O(n) index
    # arrays entirely.  A frozen epoch always needs the index machinery,
    # and an armed fault plan forces it (force_index).
    identity = (not force_index and frozen is None and len(plans) == 1
                and group_specs[0] == (gspec_tr, gspec_bn))
    skey = (gspec_tr, gspec_bn, group_specs, ks)
    key = skey + (frozen, identity)
    layout = _LAYOUT_CACHE.get(key)
    if layout is not None:
        return layout

    if frozen is not None:
        # freeze-event invalidation (see docstring)
        for stale_key in [k for k, v in list(_LAYOUT_CACHE.items())
                          if k[:4] == skey and frozen.supersedes(v.frozen)]:
            _LAYOUT_CACHE.get(stale_key).drop_device_buffers()
            del _LAYOUT_CACHE[stale_key]

    n_active = n if frozen is None else frozen.n_active
    if frozen is None:
        col_map = None
    else:
        # global id -> compressed panel column; frozen ids -> the n_active
        # sentinel every scatter drops device-side
        col_map = np.full(n, n_active, np.int64)
        col_map[frozen.active_idx] = np.arange(n_active, dtype=np.int64)
    idx, dst, rows, row = [], [], [], 0
    for plan in plans:
        if not identity:
            idx_tr = _scatter_index(global_trainable, gspec_tr, plan.trainable)
            idx_bn = _scatter_index(global_bn, gspec_bn, plan.bn_state)
            ix = np.concatenate([idx_tr, gspec_tr.n + idx_bn])
            idx.append(ix)
            dst.append(ix if col_map is None else col_map[ix])
        rows.append(row)
        row += plan.xs.shape[0]
    layout = GroupLayout(
        gspec_tr, gspec_bn, n, row, tuple(rows), ks, tuple(idx), group_specs,
        identity, frozen, n_active, tuple(dst),
    )
    _LAYOUT_CACHE[key] = layout
    return layout


@functools.partial(
    jax.jit, static_argnames=("loss_fn", "lr", "local_steps", "batch_size")
)
def _group_local_pack(loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
                      *, lr, local_steps, batch_size):
    """vmapped local SGD for one group, packed to its [K_g, n_g] panel."""
    trs, bns, losses = _local_training(
        loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
        lr=lr, local_steps=local_steps, batch_size=batch_size,
    )
    k = losses.shape[0]
    panel_tr = make_pack_spec(trainable).pack_stacked(trs, k)
    panel_bn = make_pack_spec(bn_state).pack_stacked(bns, k)
    return jnp.concatenate([panel_tr, panel_bn], axis=1), losses


@functools.partial(
    jax.jit,
    static_argnames=("loss_fn", "lr", "local_steps", "batch_size", "mesh"),
)
def _group_local_pack_sharded(loss_fn, trainable, frozen, bn_state, xs, ys,
                              rngs, *, lr, local_steps, batch_size, mesh):
    return _sharded_local_panel(
        loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
        lr=lr, local_steps=local_steps, batch_size=batch_size, mesh=mesh,
    )


def _grouped_prev(layout: GroupLayout, global_trainable, global_bn):
    return jnp.concatenate(
        [layout.gspec_tr.pack(global_trainable), layout.gspec_bn.pack(global_bn)]
    )


def _grouped_unpack(layout: GroupLayout, flat, losses_w, w_total):
    new_tr = layout.gspec_tr.unpack(flat[: layout.gspec_tr.n])
    new_bn = layout.gspec_bn.unpack(flat[layout.gspec_tr.n :])
    loss = losses_w / jnp.maximum(w_total, 1e-9)
    return new_tr, new_bn, loss


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_group_panel(panel, gpanel, ix, row):
    """Scatter one group's [K_g, n_g] panel into its contiguous row block of
    the shared [K_total, n_active] panel, entirely under jit: the group
    columns gather-scatter into a zeroed row block, ``dynamic_update_slice``
    lands the rows.  The shared panel buffer is DONATED so XLA can update it
    in place instead of copying K_total·n floats per group, and nothing here
    touches the host — the per-group scatters pipeline behind the local-SGD
    dispatches.  ``ix`` is the layout's PANEL-space destination set
    (``idx_dev`` — live columns only under a frozen epoch, matching the
    ``_live_take``-narrowed ``gpanel``); ``mode='drop'`` guards any
    out-of-range index instead of jax's default CLAMP onto the last live
    column."""
    block = jnp.zeros((gpanel.shape[0], panel.shape[1]), panel.dtype)
    block = block.at[:, ix].set(gpanel, mode="drop")
    return jax.lax.dynamic_update_slice(panel, block, (row, 0))


def _align_for_mesh(mesh: Mesh, tree):
    """device_put (replicated, async) any leaf COMMITTED outside ``mesh``'s
    device set — a prior round's default-device output, an init jit's
    committed params — so it can enter the mesh's pjit; uncommitted leaves
    and leaves already on the mesh pass through untouched (pjit places
    those shard-wise itself, without a full replicate).  Without this,
    committed single-device inputs abort sharded local SGD with
    'Received incompatible devices' on any multi-device mesh.

    Replication is deliberately the one-size placement: data leaves the
    pjit would shard over ``clients`` pay a D-fold broadcast here, but
    alignment only fires for committed-off-mesh leaves (init outputs,
    fed-back round results) — host/numpy batches are uncommitted and never
    take this path — and per-leaf P('clients') placement can't be chosen
    pre-jit because K_g needn't divide the axis (ghost padding happens
    inside the jit)."""
    devset = set(mesh.devices.reshape(-1).tolist())
    sh = NamedSharding(mesh, P())

    def fix(l):
        if isinstance(l, jax.Array) and getattr(l, "committed", False) \
                and set(l.devices()) != devset:
            return jax.device_put(l, sh)
        return l

    return jax.tree.map(fix, tree)


@functools.lru_cache(maxsize=32)
def _sharded_zeros_fn(shape: Tuple[int, ...], sharding: NamedSharding,
                      dtype: str = "float32"):
    """Jitted zeros with explicit ``out_shardings``: the shared panel is
    BORN column-sharded — the full ``[K_total, n_padded]`` buffer never
    exists on any single device, not even at initialization.  ``dtype``
    (a string, for the cache key) follows the stream dtype: a quantized
    round's shared panel is born int8/bf16, so no device ever holds the
    panel at f32 width."""
    dt = jnp.dtype(dtype)
    return jax.jit(lambda: jnp.zeros(shape, dt), out_shardings=sharding)


@jax.jit
def _live_take(gpanel, pos):
    """Source-side gather of a group panel's live columns (frozen layouts,
    replicated agg): runs wherever the ``[K_g, n_g]`` panel already lives,
    so only the narrowed ``[K_g, n_live]`` panel ever streams to the
    aggregation device and the downstream scatter never sees a frozen
    column."""
    return jnp.take(gpanel, pos, axis=1)


@jax.jit
def _stream_gather(gpanel, src):
    """Source-side slice of one group's ``[K_g, n_g]`` panel for ONE stream
    pass: row ``d`` of the ``[D, K_g, m]`` result holds exactly the group
    columns column-shard ``d`` owns this pass (``src`` from
    :meth:`GroupLayout.stream_plan`).  Runs where the group panel already
    lives (the group's sub-mesh, or the default device in packed mode) —
    the full panel is never copied off its source; only these slices are
    transferred, shard-to-owner, by ``launch/mesh.py::put_model_sharded``.
    Padded ``src`` slots clip-gather garbage that the shard-local scatter
    drops via their out-of-range ``dst``."""
    return jnp.take(gpanel, src, axis=1, mode="clip").transpose(1, 0, 2)


@jax.jit
def _stream_gather_paced(gpanel, src, tok):
    """:func:`_stream_gather` gated on a pacing token: the gather (and so
    the pass's transfer) cannot execute until ``tok`` — a ``[D]`` slice of
    the per-shard panel blocks an EARLIER pass's scatter produced — has
    been computed and moved here.  ``optimization_barrier`` makes the
    dependency opaque to XLA (a ``0·sum(tok)`` arithmetic tie would be
    constant-folded away); no host sync anywhere."""
    gpanel, _ = jax.lax.optimization_barrier((gpanel, tok))
    return jnp.take(gpanel, src, axis=1, mode="clip").transpose(1, 0, 2)


@jax.jit
def _quantize_panel_ef(gpanel, ef):
    """Source-side int8 quantization of a finished ``[K_g, n_g]`` group
    panel with error feedback: the residual ``ef`` from this group's
    previous round is folded in before quantizing, and the new residual
    (what this round's wire dtype could not carry) is returned to be
    carried forward — over rounds the quantization error telescopes
    instead of accumulating.  Runs wherever the panel lives; only the int8
    panel and the packed scale exponents ever leave the device."""
    t = gpanel + ef
    q, scale, e, gbase = _kref.quantize_columns(t)
    return q, scale, e, gbase, t - _kref.dequantize_columns(q, scale)


@jax.jit
def _to_bf16(x):
    return x.astype(jnp.bfloat16)


@jax.jit
def _live_take_vec(v, pos):
    """Per-column vector counterpart of :func:`_live_take` (frozen layouts,
    replicated agg): narrows a group's ``[n_g]`` scale row to the live
    columns on the source device."""
    return jnp.take(v, pos)


@jax.jit
def _gather_exponents(e, src):
    """Source-side gather of per-column scale exponents for one stream
    pass: ``[n_g]`` int8 exponents → ``[D, m]`` matching ``src``'s column
    selection.  Pad slots clip-gather garbage that never ships —
    ``put_scales_ragged`` packs only each row's live prefix."""
    return jnp.take(e, src, axis=0, mode="clip")


# ===========================================================================
# Fault tolerance: straggler staging + merge (fl/faults.py has the plans)
# ===========================================================================


class StagedPanel(NamedTuple):
    """One straggler client's parked update (ISSUE 8): the client's finished
    f32 panel row — captured BEFORE wire quantization and frozen-column
    narrowing, so a later merge is exact regardless of that round's
    transport — plus the STABLE full-space column ids it covers, its raw
    weight, and its timing.  ``born`` is the fault round that parked it,
    ``due`` the earliest fault round it may merge; the merge weight is
    ``weight·beta**(merge_round - born)`` (staleness discount)."""

    vals: jax.Array  # [n_g] f32 update row (device)
    idx: np.ndarray  # [n_g] int64 STABLE global column ids (host)
    weight: float  # raw aggregation weight at parking
    born: int  # fault round the row was parked
    due: int  # earliest fault round it may merge (born + delay)
    n: int  # full column-space size at parking; a merge requires a match


def _collect_due_staged(staging: list, fault_round: int, n: int):
    """Partition the engine's staging buffer in place: entries due this
    fault round come back for merging; entries parked against a DIFFERENT
    full column space (the global packed space changed under them — their
    ids no longer apply) are evicted; the rest stay parked.  Returns
    ``(due_entries, evicted_count)``."""
    due, evicted, still = [], 0, []
    for ent in staging:
        if ent.n != n:
            evicted += 1
        elif ent.due <= fault_round:
            due.append(ent)
        else:
            still.append(ent)
    staging[:] = still
    return due, evicted


def _staged_side(due, beta: float, fault_round: int, n: int):
    """Fold due straggler rows into the associative full-space ``(snum,
    sden)`` side inputs the fused kernels add before the ratio, each row at
    the staleness-discounted weight ``w·beta**s`` (``s`` rounds late).
    Scatter-adds into two ``[n]`` f32 vectors — async device work, no sync.
    The SAME helper feeds the serial oracle's host num/den, so the two
    impls share one staleness semantics by construction."""
    snum = jnp.zeros((n,), jnp.float32)
    sden = jnp.zeros((n,), jnp.float32)
    dev0 = jax.devices()[0]
    for ent in due:
        disc = jnp.float32(ent.weight * (beta ** (fault_round - ent.born)))
        ixd = jnp.asarray(ent.idx)
        vals = jax.device_put(ent.vals, dev0)
        snum = snum.at[ixd].add(disc * vals.astype(jnp.float32))
        sden = sden.at[ixd].add(disc)
    return snum, sden


def _masked_group_w(gw, gverdicts, zero_kinds) -> jax.Array:
    """Zero the weights of clients whose verdict is in ``zero_kinds``;
    groups with no such verdict pass through UNTOUCHED (bit-equality of the
    fault-free plan never rides on a ``*1.0``)."""
    if not any(v.kind in zero_kinds for v in gverdicts):
        return gw
    keep = jnp.asarray(
        [0.0 if v.kind in zero_kinds else 1.0 for v in gverdicts],
        jnp.float32,
    )
    return gw * keep


def _grouped_fused(plans, global_trainable, global_bn, layout: GroupLayout,
                   mesh: Optional[Mesh], *, kernel: str = "grouped",
                   agg: str = "replicated",
                   agg_mesh: Optional[Mesh] = None,
                   stream_dtype: str = "f32", inflight: int = 2,
                   ef_state: Optional[dict] = None,
                   faults: Optional[FLT.FaultPlan] = None,
                   staging: Optional[list] = None, fault_round: int = 0):
    """Pipelined fused path: EVERY group's local-SGD dispatch launches
    without host blocking (jax async dispatch), each finished [K_g, n_g]
    panel streams into the shared panel via jitted donated-buffer scatters,
    and ONE logical group-compressed aggregation dispatch closes the round —
    the only ``block_until_ready`` sits at that aggregation barrier.

    ``kernel="masked"`` keeps the legacy dense-mask ``fedavg_masked``
    aggregation as an escape hatch / benchmark baseline.  ``agg`` places the
    aggregation: ``"replicated"`` collects the full [K_total, n] panel onto
    one device (the PR 3 behavior); ``"sharded"`` column-shards the panel
    over ``agg_mesh``'s ``model`` axis — the panel is created already
    sharded, the group-panel STREAM is sliced per shard on its source
    device(s) so each agg device only ever receives its own columns,
    scatters are shard-local, and the one logical dispatch lowers to one
    shard-local kernel launch per device (see the module docstring).

    A frozen layout runs the SAME pipeline over the ``n_active``-column
    compressed panel: the kernel sees ``prev`` gathered to the live columns
    and its output is expanded back to the stable full space (frozen
    columns keep their previous values) BEFORE the one aggregation
    barrier — still exactly one logical dispatch and one sync.

    ``stream_dtype`` picks the wire/panel dtype (module docstring,
    "Panels can be COMPRESSED on the wire"), ``inflight`` the token-paced
    transient pass residency of the sharded stream, and ``ef_state`` the
    engine-held per-group error-feedback residuals for ``"int8"`` (keyed
    ``(gi, panel shape)`` so a freeze epoch restarts the residual with the
    panel it applies to).

    ``faults``/``staging``/``fault_round`` arm the fault-tolerance layer
    (fl/faults.py; module docstring "Fault-tolerant rounds"): dropped and
    straggler clients become zero-weight panel rows, corrupt rows are
    injected after local SGD and quarantined INSIDE the one aggregation
    dispatch (``bound=`` on the grouped kernels), straggler rows park in
    ``staging`` (the engine-owned bounded buffer) and due entries merge as
    associative ``side=(snum, sden)`` inputs at ``w·beta**s``.  The round
    still issues one logical dispatch and one ``block_until_ready``.
    """
    if layout.identity:
        # degenerate single-group round (every ProFL round): the mask is all
        # ones, so skip the scatter/mask machinery and run the one-jit packed
        # (or sharded) round — still exactly one aggregation dispatch.  The
        # agg knob is a no-op here: the identity panel has no group
        # structure to column-shard.  grouped_round only routes an ARMED
        # fault plan (actual faults, staged rows, or a finite norm_bound)
        # to a full-index layout, so faults here is fault-free and the
        # fast path is bit-equal by construction.
        p = plans[0]
        kw = dict(lr=p.lr, local_steps=p.local_steps, batch_size=p.batch_size)
        if mesh is not None:
            args = _align_for_mesh(mesh, (p.trainable, p.frozen, p.bn_state,
                                          p.xs, p.ys, p.rngs, p.weights))
            return GroupedResult(*_round_sharded(
                p.loss_fn, *args, mesh=mesh, **kw,
            ))
        return GroupedResult(*_round_packed(
            p.loss_fn, p.trainable, p.frozen, p.bn_state, p.xs, p.ys,
            p.rngs, p.weights, **kw,
        ))
    sharded = agg == "sharded"
    if sharded and agg_mesh is None:
        raise ValueError("agg='sharded' needs an agg_mesh with a 'model' axis")
    if stream_dtype not in STREAM_DTYPES:
        raise ValueError(f"unknown stream_dtype {stream_dtype!r} "
                         f"(one of {STREAM_DTYPES})")
    if kernel != "grouped" and stream_dtype != "f32":
        raise ValueError("the masked kernel has no dequant variant: "
                         "fused_masked supports stream_dtype='f32' only")
    if inflight < 1:
        raise ValueError("inflight must be >= 1")
    pdt = _STREAM_JNP[stream_dtype]
    eb = STREAM_ELEM_BYTES[stream_dtype]
    quant = stream_dtype == "int8"
    submeshes = _group_submeshes(mesh, layout.ks) if mesh is not None else None
    dev0 = mesh.devices.reshape(-1)[0] if submeshes is not None else None
    scales_panel = None
    if sharded:
        from repro.launch.mesh import put_model_ragged, put_scales_ragged

        cs = layout.column_shards(agg_mesh.shape["model"])
        # replication sharding for the tiny [K_g] loss vectors ONLY — the
        # group panels themselves are never replicated across the agg mesh
        repl = NamedSharding(agg_mesh, P())
        col_sh = NamedSharding(agg_mesh, P(None, "model"))
        # the shared panel is born AT the wire dtype: with a quantized
        # stream no agg device ever holds an f32 panel block
        panel = _sharded_zeros_fn(
            (layout.k_total, cs.n_padded), col_sh, jnp.dtype(pdt).name,
        )()
        if quant:
            scales_panel = _sharded_zeros_fn(
                (layout.n_groups, cs.n_padded), col_sh, "bfloat16",
            )()
    else:
        panel = jnp.zeros((layout.k_total, layout.n_active), pdt)
        if quant:
            scales_panel = jnp.zeros((layout.n_groups, layout.n_active),
                                     jnp.bfloat16)
    group_w = [jnp.asarray(p.weights, jnp.float32).reshape(-1) for p in plans]
    fault_groups = None
    if faults is not None:
        fault_groups = faults.for_cohort(layout.ks)
        # dropped + straggler clients leave the round as ZERO-WEIGHT panel
        # rows — no re-trace, no new layout epoch; a group zeroed entirely
        # falls back to the kernels' zero-denominator -> prev passthrough.
        # (Corrupt rows KEEP their weight: the in-kernel quarantine gate
        # zeroes them per column inside the dispatch.)
        group_w = [
            _masked_group_w(gw, gv, ("dropped", "straggler"))
            for gw, gv in zip(group_w, fault_groups)
        ]
    losses = []
    stream_elems = 0  # max per-device footprint of any streamed group buffer
    stream_chunks = 0
    wire_bytes = 0  # logical interconnect payload (plan metadata, no sync)
    wire_bytes_uniform = 0  # counterfactual: the uniform axis-0 split
    tokens: collections.deque = collections.deque()  # pacing (sharded only)
    for gi, plan in enumerate(plans):
        kw = dict(lr=plan.lr, local_steps=plan.local_steps,
                  batch_size=plan.batch_size)
        gmesh = None
        if mesh is not None:
            # disjoint clients-axis slice per group when the mesh is large
            # enough: different structures train CONCURRENTLY on different
            # devices instead of back-to-back over the full mesh
            gmesh = submeshes[gi] if submeshes is not None else mesh
            tr_g, fro_g, bn_g, xs_g, ys_g, rngs_g = _align_for_mesh(
                gmesh, (plan.trainable, plan.frozen, plan.bn_state,
                        plan.xs, plan.ys, plan.rngs)
            )
            gpanel, loss = _group_local_pack_sharded(
                plan.loss_fn, tr_g, fro_g, bn_g, xs_g, ys_g, rngs_g,
                mesh=gmesh, **kw,
            )
            if submeshes is not None:
                loss = jax.device_put(loss, dev0 if not sharded
                                      else repl)
        else:
            gpanel, loss = _group_local_pack(
                plan.loss_fn, plan.trainable, plan.frozen, plan.bn_state,
                plan.xs, plan.ys, plan.rngs, **kw,
            )
        if fault_groups is not None:
            for r, v in enumerate(fault_groups[gi]):
                if v.kind == "straggler":
                    # park the CLEAN f32 row, before any wire quantization
                    # or frozen narrowing, with its STABLE global column
                    # ids — it merges ``delay`` fault rounds later at
                    # weight w·beta**s (async row gather, no sync)
                    staging.append(StagedPanel(
                        vals=gpanel[r].astype(jnp.float32),
                        idx=layout.idx[gi],
                        weight=float(plan.weights[r]),
                        born=fault_round,
                        due=fault_round + v.delay,
                        n=layout.n,
                    ))
                elif v.kind == "corrupt":
                    # the poisoned row RIDES the normal stream into the one
                    # dispatch; the fused quarantine gate zeroes it there
                    gpanel = FLT.inject_panel(gpanel, r, v)
        # wire-dtype conversion at the SOURCE, on the FULL [K_g, n_g]
        # panel — before any frozen-column narrowing, so the int8
        # error-feedback residual keeps one stable shape per group
        scale_row = e8 = gbase = None
        if quant:
            ekey = (gi, gpanel.shape)
            ef = None if ef_state is None else ef_state.get(ekey)
            if ef is None:
                ef = jnp.zeros(gpanel.shape, jnp.float32)
            elif ef.sharding != gpanel.sharding:
                # the group moved (a different sub-mesh split this
                # round): follow it — async device_put, no sync
                ef = jax.device_put(ef, gpanel.sharding)
            gpanel, scale_row, e8, gbase, ef_new = _quantize_panel_ef(
                gpanel, ef
            )
            if ef_state is not None:
                ef_state[ekey] = ef_new
        elif stream_dtype == "bf16":
            gpanel = _to_bf16(gpanel)
        if not sharded and layout.frozen is not None:
            # drop frozen columns ON THE SOURCE device(s): the stream
            # to the aggregation device only carries live columns
            gpanel = _live_take(gpanel, layout.live_pos_dev[gi])
            if quant:
                scale_row = _live_take_vec(scale_row,
                                           layout.live_pos_dev[gi])
        if not sharded and submeshes is not None:
            # stream the finished group panel off its sub-mesh onto the
            # aggregation device — device_put is async dispatch, so this
            # transfer pipelines behind the other groups' local SGD
            gpanel = jax.device_put(gpanel, dev0)
            if quant:
                scale_row = jax.device_put(scale_row, dev0)
        if sharded:
            # shard-local stream: slice the finished [K_g, n_g] panel per
            # column shard ON ITS SOURCE device(s), land each pass's
            # [D, K_g, m] selection axis-0-sharded over the agg mesh
            # RAGGED (launch/mesh.py::put_model_ragged — only each shard's
            # tile-aligned live width crosses the interconnect; each agg
            # device receives ONLY its own columns, never a full
            # group-panel replica), then scatter shard-locally.  All
            # passes pipeline behind the other groups' local SGD, with
            # successive passes token-paced to at most ``inflight``
            # resident (module docstring) — still no host sync anywhere.
            sp = layout.stream_plan(gi, agg_mesh.shape["model"])
            src_bufs, dst_bufs = layout.stream_buffers(gi, agg_mesh)
            tok_dst = (NamedSharding(gmesh, P()) if gmesh is not None
                       else jax.devices()[0])
            k_g = gpanel.shape[0]
            for c, (src_c, dst_c) in enumerate(zip(src_bufs, dst_bufs)):
                if len(tokens) >= inflight:
                    tok = jax.device_put(tokens.popleft(), tok_dst)
                    gathered = _stream_gather_paced(gpanel, src_c, tok)
                else:
                    gathered = _stream_gather(gpanel, src_c)
                widths = sp.widths[c]
                sel = put_model_ragged(gathered, widths, agg_mesh)
                stream_elems = max(stream_elems, math.prod(
                    sel.sharding.shard_shape(sel.shape)
                ))
                stream_chunks += 1
                live_w = [int(wd) for wd in widths]
                wire_bytes += k_g * sum(live_w) * eb
                wire_bytes_uniform += k_g * sp.n_shards * sp.m_chunk * eb
                panel, tok_out = ops.scatter_stream_sharded(
                    panel, sel, dst_c, layout.rows[gi], mesh=agg_mesh
                )
                tokens.append(tok_out)
                if quant:
                    # companion scale stream: packed 4-bit exponents plus
                    # the 2-byte group base per live slice, decoded to
                    # bf16 scale rows on the destination shards and
                    # scattered with the SAME dst plan into [G, n_padded]
                    egather = _gather_exponents(e8, src_c)
                    esel = put_scales_ragged(egather, gbase, widths,
                                             agg_mesh)
                    scales_panel, _ = ops.scatter_stream_sharded(
                        scales_panel, esel, dst_c, gi, mesh=agg_mesh
                    )
                    wire_bytes += sum(
                        -(-wd // 2) + 2 for wd in live_w if wd
                    )
                    wire_bytes_uniform += sp.n_shards * (
                        -(-sp.m_chunk // 2) + 2
                    )
        else:
            stream_elems = max(stream_elems,
                               gpanel.shape[0] * gpanel.shape[1])
            stream_chunks += 1
            wire_bytes += gpanel.shape[0] * gpanel.shape[1] * eb
            wire_bytes_uniform += gpanel.shape[0] * gpanel.shape[1] * eb
            panel = _scatter_group_panel(panel, gpanel, layout.idx_dev[gi],
                                         layout.rows[gi])
            if quant:
                # the bf16 scale row travels beside the int8 panel
                wire_bytes += 2 * gpanel.shape[1]
                wire_bytes_uniform += 2 * gpanel.shape[1]
                scales_panel = _scatter_group_panel(
                    scales_panel, scale_row[None], layout.idx_dev[gi], gi
                )
        losses.append(loss)
    w = jnp.concatenate(group_w)
    wsum = jnp.stack([jnp.sum(gw) for gw in group_w])
    prev = _grouped_prev(layout, global_trainable, global_bn)
    # compressed-space prev for the kernel: frozen columns never reach it
    prev_act = (prev if layout.frozen is None
                else jnp.take(prev, layout.active_idx_dev))
    # fault handling, part 2: quarantine arming + straggler merge.  The gate
    # and the side inputs ride the SAME dispatch below — no extra launch.
    bound = side = None
    merged_rows = evicted_rows = 0
    if faults is not None:
        if kernel == "grouped":
            bound = faults.norm_bound
        due, evicted_rows = _collect_due_staged(staging, fault_round,
                                                layout.n)
        # bounded buffer: whatever stays parked past this round is capped at
        # max_staged rows, oldest evicted first (the memory-model twin
        # prices exactly this bound)
        while len(staging) > faults.max_staged:
            staging.pop(0)
            evicted_rows += 1
        merged_rows = len(due)
        if due and layout.n_active > 0:
            snum, sden = _staged_side(due, faults.beta, fault_round,
                                      layout.n)
            if layout.frozen is not None:
                # frozen columns never reach the kernel: narrow the side
                # inputs to the live columns like every other operand (the
                # frozen expand below restores prev for the rest)
                snum = jnp.take(snum, layout.active_idx_dev)
                sden = jnp.take(sden, layout.active_idx_dev)
            side = (snum, sden)
    panel_dev_elems = math.prod(panel.sharding.shard_shape(panel.shape))
    AGG_STATS.clear()
    AGG_STATS.update(
        agg=agg, kernel=kernel, n=layout.n, k_total=layout.k_total,
        n_active=layout.n_active, n_frozen=layout.n - layout.n_active,
        n_shards=cs.n_shards if sharded else 1,
        n_padded=cs.n_padded if sharded else layout.n_active,
        per_device_panel_elems=panel_dev_elems,
        # transient-stream telemetry, from transfer-sharding metadata only:
        # the largest per-device footprint any streamed group buffer had
        # while scattering into the shared panel, and the number of PANEL
        # scatter passes it took (sharded streams of a concentrated group
        # split into multiple m_chunk-column passes to keep the bound; the
        # int8 scale-row companion scatters are not counted)
        stream="sharded" if sharded else "replicated",
        per_device_stream_elems=stream_elems,
        stream_chunks=stream_chunks,
        # transport telemetry (module docstring): everything below comes
        # from plan metadata + sharding metadata — never a device sync.
        # per_device_panel_bytes is the RESIDENT panel footprint at the
        # wire dtype: a quantized round's shared panel is born narrow, so
        # this shrinks by 4/eb versus f32 (the never-an-f32-panel claim
        # tests pin against the memory model).
        stream_dtype=stream_dtype,
        inflight=inflight,
        panel_elem_bytes=eb,
        per_device_panel_bytes=panel_dev_elems * eb,
        per_device_scales_bytes=(
            math.prod(scales_panel.sharding.shard_shape(scales_panel.shape))
            * 2 if quant else 0
        ),
        per_device_stream_bytes=stream_elems * eb,
        wire_bytes=wire_bytes,
        wire_bytes_uniform=wire_bytes_uniform,
    )
    # fault telemetry (module docstring, "Fault-tolerant rounds"): verdict
    # counts and staging occupancy from PLAN METADATA + shape metadata only
    # — never a device sync.  fl/memory_model.py::fault_counts /
    # fault_staging_bytes twin these fields exactly.
    fc = (faults.counts() if faults is not None
          else {k: 0 for k in FLT.KINDS})
    AGG_STATS.update(
        faults_armed=faults is not None,
        quarantine_bound=(float(faults.norm_bound) if faults is not None
                          else None),
        fault_ok=fc["ok"], fault_dropped=fc["dropped"],
        fault_stragglers=fc["straggler"], fault_corrupt=fc["corrupt"],
        fault_merged_rows=merged_rows,
        fault_evicted_rows=evicted_rows,
        fault_staged_rows=len(staging) if staging is not None else 0,
        fault_staging_bytes=(
            sum(4 * int(e.vals.shape[0]) for e in staging)
            if staging is not None else 0
        ),
    )
    if layout.n_active == 0:
        # fully frozen layout: nothing left to aggregate — the round's
        # output is prev verbatim (local SGD still ran for the loss)
        flat = prev
    elif sharded:
        pad = cs.n_padded - layout.n_active
        prev_p = jnp.pad(prev_act, (0, pad)) if pad else prev_act
        prev_p = jax.device_put(prev_p, NamedSharding(agg_mesh, P("model")))
        if side is not None:
            # the merge side inputs are per-column, so they column-shard
            # exactly like prev: pad to the tile-aligned width and land
            # each shard's slice on its owner (async device_put)
            sh_m = NamedSharding(agg_mesh, P("model"))
            sn = jnp.pad(side[0], (0, pad)) if pad else side[0]
            sd = jnp.pad(side[1], (0, pad)) if pad else side[1]
            side = (jax.device_put(sn, sh_m), jax.device_put(sd, sh_m))
        if kernel != "grouped":
            lmask = jnp.pad(layout.legacy_mask, ((0, 0), (0, pad)))
            lmask = jax.device_put(
                lmask, NamedSharding(agg_mesh, P(None, "model"))
            )
            flat = ops.fedavg_masked_sharded(panel, w, lmask, prev_p,
                                             mesh=agg_mesh)
        elif quant:
            # dequantization happens INSIDE the shard-local Pallas kernel:
            # the f32 panel never exists on any agg device
            flat = ops.fedavg_grouped_dequant_sharded(
                panel, w, layout.gmask_sharded(agg_mesh), wsum,
                layout.gsel, scales_panel, prev_p, mesh=agg_mesh,
                bound=bound, side=side,
            )
        else:
            flat = ops.fedavg_grouped_sharded(
                panel, w, layout.gmask_sharded(agg_mesh), wsum, prev_p,
                mesh=agg_mesh,
                out_dtype="float32" if stream_dtype == "bf16" else None,
                bound=bound, side=side,
            )
        # the round OUTPUT is the [n_active] aggregate, not the panel:
        # gather it to the default device (async) so the next round's
        # single-device local SGD jits see the same placement as the
        # replicated path
        flat = jax.device_put(flat[: layout.n_active], jax.devices()[0])
    elif kernel != "grouped":
        flat = ops.fedavg_masked(panel, w, layout.legacy_mask, prev_act)
    elif quant:
        flat = ops.fedavg_grouped_dequant(
            panel, w, layout.gmask, wsum, layout.gsel, scales_panel,
            prev_act, bound=bound, side=side,
        )
    else:
        flat = ops.fedavg_grouped(
            panel, w, layout.gmask, wsum, prev_act,
            out_dtype="float32" if stream_dtype == "bf16" else None,
            bound=bound, side=side,
        )
    if layout.frozen is not None and layout.n_active > 0:
        # expand back to the stable full coordinate space: frozen columns
        # keep their previous global values untouched.  Async dispatch —
        # the round still syncs exactly once, below.
        flat = prev.at[layout.active_idx_dev].set(flat)
    losses_w = sum(
        jnp.sum(gw * l) for gw, l in zip(group_w, losses)
    )
    flat = _barrier(flat)  # the round's ONE host sync
    new_tr, new_bn, loss = _grouped_unpack(layout, flat, losses_w, jnp.sum(w))
    return GroupedResult(new_tr, new_bn, loss, layout.gspec_tr.pack(new_tr))


def _shard_elems(x: jax.Array) -> int:
    """Per-device element count of ``x`` from sharding metadata (no sync)."""
    return math.prod(x.sharding.shard_shape(x.shape))


def _grouped_hier(plans, global_trainable, global_bn, layout: GroupLayout,
                  mesh: Optional[Mesh], *, edges: int,
                  agg: str = "replicated",
                  agg_mesh: Optional[Mesh] = None,
                  stream_dtype: str = "f32", inflight: int = 2,
                  ef_state: Optional[dict] = None,
                  faults: Optional[FLT.FaultPlan] = None,
                  staging: Optional[list] = None, fault_round: int = 0):
    """Two-tier hierarchical round (ISSUE 10; module docstring, "Two-tier
    hierarchical rounds"): local SGD and the per-group wire conversion run
    exactly as in :func:`_grouped_fused`, but instead of streaming every
    client row into one shared ``[K_total, n_active]`` panel, each of
    ``edges`` EDGE aggregators folds its round-robin slice of the cohort
    into an associative ``(num, den)`` partial
    (``ops.fedavg_grouped_edge`` — the flat kernel's per-row terms,
    quarantine gate and int8 dequant included).  The partials reduce
    tree-wise, straggler side inputs add on top, and ONE zero-weight
    single-row carrier ``fedavg_grouped`` dispatch closes the round with
    the reduced pair as its ``side`` operand — one logical dispatch, one
    ``block_until_ready``, same as flat.  Under ``agg="sharded"`` the
    partial pairs and the carrier operands column-shard over the agg
    mesh's ``model`` axis before the reduce; the per-column ratio has no
    cross-column coupling, so replicated and sharded hierarchies are
    bit-equal at any fan-in.

    Server peak memory is the FAN-IN, not the cohort: the top tier holds
    ``E`` partial pairs, the reduced pair, and the carrier operands —
    measured into ``AGG_STATS["hier_server_peak_bytes"]`` from array +
    sharding metadata only and twinned exactly by
    ``fl/memory_model.py::hier_server_peak_bytes``."""
    sharded = agg == "sharded"
    if sharded and agg_mesh is None:
        raise ValueError("agg='sharded' needs an agg_mesh with a 'model' axis")
    if edges < 1:
        raise ValueError("edges must be >= 1")
    eb = STREAM_ELEM_BYTES[stream_dtype]
    quant = stream_dtype == "int8"
    submeshes = _group_submeshes(mesh, layout.ks) if mesh is not None else None
    dev0 = jax.devices()[0]
    cs = layout.column_shards(agg_mesh.shape["model"]) if sharded else None
    repl = NamedSharding(agg_mesh, P()) if sharded else None
    group_w = [jnp.asarray(p.weights, jnp.float32).reshape(-1) for p in plans]
    fault_groups = None
    if faults is not None:
        fault_groups = faults.for_cohort(layout.ks)
        group_w = [
            _masked_group_w(gw, gv, ("dropped", "straggler"))
            for gw, gv in zip(group_w, fault_groups)
        ]
    # quarantine gate at the EDGE tier: same arming rule as the flat path
    # (an infinite bound still gates non-finite entries)
    bound = faults.norm_bound if faults is not None else None
    losses = []
    # per-edge entry lists: edge e folds its slice of every group panel
    entries: list = [[] for _ in range(edges)]
    stream_elems = 0  # largest edge-bound panel slice (per-entry elems)
    stream_chunks = 0  # entries shipped client-tier -> edge tier
    wire_bytes = 0  # client->edge rows + scales, then edge->server partials
    for gi, plan in enumerate(plans):
        kw = dict(lr=plan.lr, local_steps=plan.local_steps,
                  batch_size=plan.batch_size)
        if mesh is not None:
            gmesh = submeshes[gi] if submeshes is not None else mesh
            tr_g, fro_g, bn_g, xs_g, ys_g, rngs_g = _align_for_mesh(
                gmesh, (plan.trainable, plan.frozen, plan.bn_state,
                        plan.xs, plan.ys, plan.rngs)
            )
            gpanel, loss = _group_local_pack_sharded(
                plan.loss_fn, tr_g, fro_g, bn_g, xs_g, ys_g, rngs_g,
                mesh=gmesh, **kw,
            )
            if submeshes is not None:
                loss = jax.device_put(loss, dev0 if not sharded else repl)
        else:
            gpanel, loss = _group_local_pack(
                plan.loss_fn, plan.trainable, plan.frozen, plan.bn_state,
                plan.xs, plan.ys, plan.rngs, **kw,
            )
        if fault_groups is not None:
            for r, v in enumerate(fault_groups[gi]):
                if v.kind == "straggler":
                    staging.append(StagedPanel(
                        vals=gpanel[r].astype(jnp.float32),
                        idx=layout.idx[gi],
                        weight=float(plan.weights[r]),
                        born=fault_round,
                        due=fault_round + v.delay,
                        n=layout.n,
                    ))
                elif v.kind == "corrupt":
                    gpanel = FLT.inject_panel(gpanel, r, v)
        # wire-dtype conversion at the SOURCE, on the FULL [K_g, n_g]
        # panel — same EF keying as the flat path, so a mixed flat/hier
        # run carries ONE residual stream per group
        scale_row = None
        if quant:
            ekey = (gi, gpanel.shape)
            ef = None if ef_state is None else ef_state.get(ekey)
            if ef is None:
                ef = jnp.zeros(gpanel.shape, jnp.float32)
            elif ef.sharding != gpanel.sharding:
                ef = jax.device_put(ef, gpanel.sharding)
            gpanel, scale_row, _, _, ef_new = _quantize_panel_ef(gpanel, ef)
            if ef_state is not None:
                ef_state[ekey] = ef_new
        elif stream_dtype == "bf16":
            gpanel = _to_bf16(gpanel)
        if layout.frozen is not None:
            # frozen columns leave the wire before the edge tier
            gpanel = _live_take(gpanel, layout.live_pos_dev[gi])
            if quant:
                scale_row = _live_take_vec(scale_row,
                                           layout.live_pos_dev[gi])
        if mesh is not None:
            # the edge tier is simulated on the default device: stream the
            # finished group panel off its (sub-)mesh — async device_put,
            # pipelines behind the other groups' local SGD
            gpanel = jax.device_put(gpanel, dev0)
            if quant:
                scale_row = jax.device_put(scale_row, dev0)
        losses.append(loss)
        if layout.n_active == 0:
            continue
        k_g, n_live = int(gpanel.shape[0]), int(gpanel.shape[1])
        gw = group_w[gi]
        # deterministic edge assignment: global cohort row -> row % edges
        eids = (layout.rows[gi] + np.arange(k_g)) % edges
        edges_touched = 0
        for e in range(edges):
            rs = np.nonzero(eids == e)[0]
            if rs.size == 0:
                continue
            rsd = jnp.asarray(rs)
            entries[e].append((
                jnp.take(gpanel, rsd, axis=0),
                jnp.take(gw, rsd),
                layout.idx_dev[gi],
                scale_row,
            ))
            edges_touched += 1
            stream_elems = max(stream_elems, rs.size * n_live)
            stream_chunks += 1
        wire_bytes += k_g * n_live * eb
        if quant:
            # the bf16 scale row travels to every edge holding group rows
            wire_bytes += 2 * n_live * edges_touched
    # edge tier: one partial fold per (non-empty) edge, each counted under
    # DISPATCHES["fedavg_grouped_edges"] — async scatter-adds, no sync
    pairs = []
    if layout.n_active > 0:
        pairs = [
            ops.fedavg_grouped_edge(ent, layout.n_active, bound=bound)
            for ent in entries if ent
        ]
    edges_used = len(pairs)
    edge_pair_bytes = (4 * (pairs[0][0].size + pairs[0][1].size)
                       if pairs else 0)
    wire_bytes += edges_used * edge_pair_bytes  # edge->server partial uplink
    # fault handling, part 2: straggler merge side inputs add on top of the
    # reduced partials — same staging semantics as the flat path
    side = None
    merged_rows = evicted_rows = 0
    if faults is not None:
        due, evicted_rows = _collect_due_staged(staging, fault_round,
                                                layout.n)
        while len(staging) > faults.max_staged:
            staging.pop(0)
            evicted_rows += 1
        merged_rows = len(due)
        if due and layout.n_active > 0:
            snum, sden = _staged_side(due, faults.beta, fault_round,
                                      layout.n)
            if layout.frozen is not None:
                snum = jnp.take(snum, layout.active_idx_dev)
                sden = jnp.take(sden, layout.active_idx_dev)
            side = (snum, sden)
    prev = _grouped_prev(layout, global_trainable, global_bn)
    prev_act = (prev if layout.frozen is None
                else jnp.take(prev, layout.active_idx_dev))
    peak_elems = 2  # carrier w + wsum f32 scalars
    if layout.n_active == 0:
        # fully frozen layout: nothing left to aggregate
        flat = prev
        carrier_elems = 0
    else:
        if sharded:
            pad = cs.n_padded - layout.n_active
            sh_m = NamedSharding(agg_mesh, P("model"))
            col_sh = NamedSharding(agg_mesh, P(None, "model"))

            def _place(v):
                return jax.device_put(
                    jnp.pad(v, (0, pad)) if pad else v, sh_m
                )
        else:
            def _place(v):
                return v
        # the partial pairs ARRIVE at the top tier (column-sharded under
        # agg="sharded"), then reduce tree-wise — per-column adds, so the
        # shard decomposition stays bitwise exact at any fan-in
        pairs = [(_place(pn), _place(pd)) for pn, pd in pairs]
        peak_elems += sum(
            _shard_elems(a) for pair in pairs for a in pair
        )
        while len(pairs) > 1:
            nxt = [
                (pairs[i][0] + pairs[i + 1][0], pairs[i][1] + pairs[i + 1][1])
                for i in range(0, len(pairs) - 1, 2)
            ]
            if len(pairs) % 2:
                nxt.append(pairs[-1])
            pairs = nxt
        rnum, rden = pairs[0] if pairs else (
            _place(jnp.zeros((layout.n_active,), jnp.float32)),
            _place(jnp.zeros((layout.n_active,), jnp.float32)),
        )
        if side is not None:
            rnum = rnum + _place(side[0])
            rden = rden + _place(side[1])
        peak_elems += _shard_elems(rnum) + _shard_elems(rden)
        cw = jnp.zeros((1,), jnp.float32)
        cwsum = jnp.zeros((1,), jnp.float32)
        if sharded:
            # zero-weight single-row carrier, born column-sharded: the
            # reduced pair rides as the side operand, wsum=0 makes the
            # gmask term vanish, and padded columns (sden=0) pass prev
            # (also zero-padded) through — the _publish_side_only pattern
            carrier = _sharded_zeros_fn((1, cs.n_padded), col_sh,
                                        "float32")()
            cgmask = jax.device_put(
                jnp.ones((1, cs.n_padded), jnp.float32), col_sh
            )
            prev_p = jnp.pad(prev_act, (0, pad)) if pad else prev_act
            prev_p = jax.device_put(prev_p, sh_m)
            peak_elems += (_shard_elems(carrier) + _shard_elems(cgmask)
                           + _shard_elems(prev_p))
            carrier_elems = _shard_elems(carrier)
            flat = ops.fedavg_grouped_sharded(
                carrier, cw, cgmask, cwsum, prev_p, mesh=agg_mesh,
                side=(rnum, rden),
            )
            flat = jax.device_put(flat[: layout.n_active], dev0)
        else:
            carrier = jnp.zeros((1, layout.n_active), jnp.float32)
            cgmask = jnp.ones((1, layout.n_active), jnp.float32)
            peak_elems += (_shard_elems(carrier) + _shard_elems(cgmask)
                           + _shard_elems(prev_act))
            carrier_elems = _shard_elems(carrier)
            flat = ops.fedavg_grouped(
                carrier, cw, cgmask, cwsum, prev_act, side=(rnum, rden),
            )
    AGG_STATS.clear()
    AGG_STATS.update(
        agg=agg, kernel="grouped", n=layout.n, k_total=layout.k_total,
        n_active=layout.n_active, n_frozen=layout.n - layout.n_active,
        n_shards=cs.n_shards if sharded else 1,
        n_padded=cs.n_padded if sharded else layout.n_active,
        # the top tier's resident "panel" is the 1-row carrier — the
        # [K_total, n] cohort panel never exists on any server device
        per_device_panel_elems=carrier_elems,
        stream="hier",
        per_device_stream_elems=stream_elems,
        stream_chunks=stream_chunks,
        stream_dtype=stream_dtype,
        inflight=inflight,
        panel_elem_bytes=eb,
        per_device_panel_bytes=carrier_elems * 4,
        per_device_scales_bytes=0,
        per_device_stream_bytes=stream_elems * eb,
        # client->edge rows (+ int8 scale rows per receiving edge) plus the
        # edge->server f32 partial uplink; no uniform-split counterfactual
        # on this path, so both wire fields carry the same figure
        wire_bytes=wire_bytes,
        wire_bytes_uniform=wire_bytes,
        # hierarchy telemetry (ISSUE 10), from array/sharding metadata
        # only — fl/memory_model.py::edge_partial_bytes /
        # hier_server_peak_bytes twin these exactly
        hier_edges=edges,
        hier_edges_used=edges_used,
        hier_edge_partial_bytes=edge_pair_bytes,
        hier_server_peak_bytes=4 * peak_elems,
    )
    fc = (faults.counts() if faults is not None
          else {k: 0 for k in FLT.KINDS})
    AGG_STATS.update(
        faults_armed=faults is not None,
        quarantine_bound=(float(faults.norm_bound) if faults is not None
                          else None),
        fault_ok=fc["ok"], fault_dropped=fc["dropped"],
        fault_stragglers=fc["straggler"], fault_corrupt=fc["corrupt"],
        fault_merged_rows=merged_rows,
        fault_evicted_rows=evicted_rows,
        fault_staged_rows=len(staging) if staging is not None else 0,
        fault_staging_bytes=(
            sum(4 * int(e.vals.shape[0]) for e in staging)
            if staging is not None else 0
        ),
    )
    if layout.frozen is not None and layout.n_active > 0:
        flat = prev.at[layout.active_idx_dev].set(flat)
    w = jnp.concatenate(group_w)
    losses_w = sum(
        jnp.sum(gw * l) for gw, l in zip(group_w, losses)
    )
    flat = _barrier(flat)  # the round's ONE host sync
    new_tr, new_bn, loss = _grouped_unpack(layout, flat, losses_w, jnp.sum(w))
    return GroupedResult(new_tr, new_bn, loss, layout.gspec_tr.pack(new_tr))


def _grouped_serial(plans, global_trainable, global_bn, layout: GroupLayout,
                    faults: Optional[FLT.FaultPlan] = None,
                    staging: Optional[list] = None, fault_round: int = 0):
    """Serial per-group oracle: each group through ``client.cohort_round``
    (vmap + einsum tree-map), masked num/den accumulated host-side.  This is
    the semantics of record that the fused path is tested against.

    Fault semantics of record: a dropped, straggler, OR corrupt client is a
    zero-weight client of its group's ``cohort_round`` — corrupt equals
    dropped at the oracle level, because quarantining a whole poisoned row
    is exactly "aggregate without that client".  A straggler's update is
    additionally computed by a single-client ``cohort_round``, parked in
    ``staging``, and merged into a later round's num/den via the SAME
    :func:`_staged_side` helper the fused path uses."""
    if layout.identity:
        # degenerate single-group round == the plain oracle cohort round
        # (grouped_round routes armed fault plans to a full-index layout)
        p = plans[0]
        tr, bn, loss = CL.cohort_round(
            p.loss_fn, p.trainable, p.frozen, p.bn_state, p.xs, p.ys, p.rngs,
            p.weights, lr=p.lr, local_steps=p.local_steps,
            batch_size=p.batch_size,
        )
        return GroupedResult(tr, bn, loss, None)
    fault_groups = (faults.for_cohort(layout.ks)
                    if faults is not None else None)
    num = jnp.zeros((layout.n,), jnp.float32)
    den = jnp.zeros((layout.n,), jnp.float32)
    losses_w = jnp.zeros((), jnp.float32)
    w_total = jnp.zeros((), jnp.float32)
    for gi, (plan, ix, (spec_tr_g, spec_bn_g)) in enumerate(zip(
        plans, layout.idx, layout.group_specs
    )):
        weights = jnp.asarray(plan.weights, jnp.float32).reshape(-1)
        if fault_groups is not None:
            gv = fault_groups[gi]
            weights = _masked_group_w(
                weights, gv, ("dropped", "straggler", "corrupt")
            )
            for r, v in enumerate(gv):
                if v.kind != "straggler":
                    continue
                # the straggler's own update: a single-client cohort round
                # over its slice, packed to the group's flat row
                tr_1, bn_1, _ = CL.cohort_round(
                    plan.loss_fn, plan.trainable, plan.frozen,
                    plan.bn_state, plan.xs[r : r + 1], plan.ys[r : r + 1],
                    plan.rngs[r : r + 1], plan.weights[r : r + 1],
                    lr=plan.lr, local_steps=plan.local_steps,
                    batch_size=plan.batch_size,
                )
                staging.append(StagedPanel(
                    vals=jnp.concatenate(
                        [spec_tr_g.pack(tr_1), spec_bn_g.pack(bn_1)]
                    ),
                    idx=ix,
                    weight=float(plan.weights[r]),
                    born=fault_round,
                    due=fault_round + v.delay,
                    n=layout.n,
                ))
        wsum = float(jnp.sum(weights))
        if wsum <= 0.0:
            # zero-weight group: no contribution (its unique columns keep the
            # server's previous values via the zero-denominator passthrough)
            continue
        tr_g, bn_g, loss_g = CL.cohort_round(
            plan.loss_fn, plan.trainable, plan.frozen, plan.bn_state,
            plan.xs, plan.ys, plan.rngs, weights,
            lr=plan.lr, local_steps=plan.local_steps,
            batch_size=plan.batch_size,
        )
        flat_g = jnp.concatenate(
            [spec_tr_g.pack(tr_g), spec_bn_g.pack(bn_g)]
        )
        num = num.at[ix].add(wsum * flat_g)
        den = den.at[ix].add(wsum)
        losses_w = losses_w + wsum * loss_g
        w_total = w_total + wsum
    if faults is not None and staging is not None:
        due, _ = _collect_due_staged(staging, fault_round, layout.n)
        while len(staging) > faults.max_staged:
            staging.pop(0)
        if due:
            snum, sden = _staged_side(due, faults.beta, fault_round,
                                      layout.n)
            num = num + snum
            den = den + sden
    prev = _grouped_prev(layout, global_trainable, global_bn)
    flat = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), prev)
    if layout.frozen is not None:
        # the oracle semantics of a frozen column: the server simply stops
        # updating it, whatever the clients sent
        flat = jnp.where(layout.frozen_mask_dev, prev, flat)
    new_tr, new_bn, loss = _grouped_unpack(layout, flat, losses_w, w_total)
    return GroupedResult(new_tr, new_bn, loss, None)


class CohortEngine:
    """Executes FL rounds under one of the MODES.  Stateless apart from the
    meshes; safe to share across server + baselines.

    ``agg`` sets the default aggregation placement for grouped rounds (one
    of AGG_MODES; ``auto`` resolves to ``sharded`` when a multi-device
    ``model`` axis is available).  ``agg_mesh`` is the mesh whose ``model``
    axis the column-sharded aggregation splits over; it defaults to the
    engine mesh when that mesh carries a ``model`` axis (the composed
    ``clients × model`` mesh from ``launch/mesh.py::make_fl_cohort_mesh``),
    else to a 1-D ``model`` mesh over every local device.

    ``stream_dtype`` sets the default wire/panel dtype of the fused
    group-panel stream (one of STREAM_DTYPES; ``"f32"`` is bit-exact,
    ``"bf16"``/``"int8"`` compress the transport — module docstring) and
    ``inflight`` the token-paced transient pass residency of the sharded
    stream (default 2, double-buffering).  Under ``"int8"`` the engine
    carries per-group error-feedback residuals across rounds in
    ``_ef_state`` (:meth:`reset_ef` drops them) — it is otherwise
    stateless apart from the meshes."""

    def __init__(self, mode: str = "vmap", mesh: Optional[Mesh] = None, *,
                 agg: str = "auto", agg_mesh: Optional[Mesh] = None,
                 stream_dtype: str = "f32", inflight: int = 2):
        if mode == "auto":
            mode = "sharded" if len(jax.devices()) > 1 else "packed"
        if mode not in ("vmap", "packed", "sharded"):
            raise ValueError(f"unknown engine mode {mode!r} (one of {MODES})")
        if mode == "sharded" and mesh is None:
            from repro.launch.mesh import make_client_mesh

            mesh = make_client_mesh()
        if agg not in AGG_MODES:
            raise ValueError(f"unknown agg mode {agg!r} (one of {AGG_MODES})")
        if agg_mesh is not None and "model" not in agg_mesh.axis_names:
            raise ValueError("agg_mesh needs a 'model' axis")
        if agg_mesh is None:
            if mesh is not None and "model" in mesh.axis_names:
                agg_mesh = mesh
            elif agg == "sharded" or (agg == "auto" and len(jax.devices()) > 1):
                from repro.launch.mesh import make_model_mesh

                agg_mesh = make_model_mesh()
        if stream_dtype not in STREAM_DTYPES:
            raise ValueError(f"unknown stream_dtype {stream_dtype!r} "
                             f"(one of {STREAM_DTYPES})")
        if inflight < 1:
            raise ValueError("inflight must be >= 1")
        self.mode, self.mesh = mode, mesh
        self.agg, self.agg_mesh = agg, agg_mesh
        self.stream_dtype, self.inflight = stream_dtype, inflight
        self._ef_state: dict = {}
        # frozen-column epoch the EF residuals were accumulated under —
        # (n, digest) or None for unfrozen; a change clears _ef_state so a
        # stale residual can never land on a remapped column space
        self._ef_epoch = None
        # fault-tolerance state (fl/faults.py): the bounded straggler
        # staging buffer and the monotone fault-round clock that prices
        # staleness (w·beta**s); both advance only on faults-armed rounds
        self._staging: list = []
        self._fault_round: int = 0

    def reset_ef(self) -> None:
        """Drop the per-group int8 error-feedback residuals (e.g. between
        independent experiments sharing one engine)."""
        self._ef_state.clear()
        self._ef_epoch = None

    def reset_faults(self) -> None:
        """Drop the straggler staging buffer and rewind the fault-round
        clock (e.g. between independent experiments sharing one engine)."""
        self._staging.clear()
        self._fault_round = 0

    def round(
        self,
        loss_fn: Callable,
        trainable,
        frozen,
        bn_state,
        xs,
        ys,
        rngs,
        weights,
        *,
        lr: float,
        local_steps: int,
        batch_size: int,
    ) -> RoundResult:
        kw = dict(lr=lr, local_steps=local_steps, batch_size=batch_size)
        if self.mode == "vmap":
            tr, bn, loss = CL.cohort_round(
                loss_fn, trainable, frozen, bn_state, xs, ys, rngs, weights,
                **kw,
            )
            return RoundResult(tr, bn, loss, None)
        if self.mode == "packed":
            return RoundResult(
                *_round_packed(
                    loss_fn, trainable, frozen, bn_state, xs, ys, rngs,
                    weights, **kw,
                )
            )
        args = _align_for_mesh(
            self.mesh, (trainable, frozen, bn_state, xs, ys, rngs, weights)
        )
        return RoundResult(
            *_round_sharded(loss_fn, *args, mesh=self.mesh, **kw)
        )

    def grouped_round(
        self,
        plans: Sequence[GroupPlan],
        global_trainable,
        global_bn,
        *,
        impl: Optional[str] = None,
        agg: Optional[str] = None,
        frozen=None,
        stream_dtype: Optional[str] = None,
        inflight: Optional[int] = None,
        faults: Optional[FLT.FaultPlan] = None,
        edges: Optional[int] = None,
    ) -> GroupedResult:
        """One heterogeneous round over ``plans`` (see module docstring).

        ``impl`` is ``"serial"`` (per-group oracle), ``"fused"`` (pipelined
        group launches + ONE group-compressed ``fedavg_grouped`` dispatch),
        or ``"fused_masked"`` (same pipeline but the legacy dense-mask
        ``fedavg_masked`` aggregation — the benchmark comparison point);
        ``None`` picks serial under the ``vmap`` mode and fused otherwise
        (sharded local SGD when the engine mode is ``sharded``, with groups
        mapped to disjoint ``clients``-axis sub-meshes when the mesh is
        large enough, per-group ghost-client padding either way).

        ``agg`` places the fused aggregation: ``"replicated"`` (full panel
        on one device), ``"sharded"`` (column-sharded over the agg mesh's
        ``model`` axis — the panel never materializes on a single device),
        or ``"auto"``/``None`` for the engine default (``auto`` resolves to
        sharded exactly when the agg mesh has a multi-device ``model``
        axis).  The serial oracle ignores ``agg``.

        ``frozen`` is an optional frozen-column epoch (a
        :class:`FrozenColumns` or a raw ``[n]`` bool mask over the global
        ``[trainable | bn]`` packed space): frozen columns leave the
        panel, the stream, and the kernel, and keep their previous global
        values — see the module docstring's freezing-aware-layouts
        section.

        ``stream_dtype`` / ``inflight`` override the engine defaults for
        this round (see the class docstring and the module docstring's
        transport section).  ``fused_masked`` has no dequant kernel
        variant and rejects ``stream_dtype != "f32"``; the serial oracle
        and the single-group identity fast path have no transport and
        ignore both knobs.

        ``faults`` is an optional :class:`fl.faults.FaultPlan` covering
        the cohort's clients in concatenated group order: dropped and
        straggler clients become zero-weight panel rows (no re-trace, no
        new layout epoch), corrupt rows are injected after local SGD and
        quarantined inside the one fused dispatch, and straggler updates
        park in the engine's bounded staging buffer to merge into a later
        faults-armed round at the staleness-discounted weight
        ``w·beta**s``.  A fault-free plan at the default ``norm_bound=inf``
        is bit-equal to ``faults=None``.  ``fused_masked`` supports
        dropped-only plans (its kernel has no quarantine or merge
        operands); the serial oracle supports everything, with corrupt ≡
        zero-weight as the semantics of record.

        ``edges`` (ISSUE 10) sets the hierarchical fan-in: ``E > 1``
        routes the fused path through ``E`` edge aggregators whose
        associative ``(num, den)`` partials reduce tree-wise into a
        zero-weight carrier dispatch — still one logical
        ``fedavg_grouped`` dispatch and one sync per round (module
        docstring, "Two-tier hierarchical rounds").  ``None``/``1`` is
        the flat round VERBATIM; the serial oracle accepts and ignores
        the knob (host num/den accumulation is edge-order-free);
        ``fused_masked`` rejects ``E > 1`` (no side operands)."""
        if not plans:
            raise ValueError("grouped_round needs at least one GroupPlan")
        if impl is None:
            impl = "serial" if self.mode == "vmap" else "fused"
        if impl not in ("serial", "fused", "fused_masked"):
            raise ValueError(f"unknown grouped impl {impl!r}")
        stream_dtype = (self.stream_dtype if stream_dtype is None
                        else stream_dtype)
        if stream_dtype not in STREAM_DTYPES:
            raise ValueError(f"unknown stream_dtype {stream_dtype!r} "
                             f"(one of {STREAM_DTYPES})")
        inflight = self.inflight if inflight is None else inflight
        if inflight < 1:
            raise ValueError("inflight must be >= 1")
        if impl == "fused_masked" and stream_dtype != "f32":
            raise ValueError("the masked kernel has no dequant variant: "
                             "fused_masked supports stream_dtype='f32' only")
        edges = 1 if edges is None else edges
        if not isinstance(edges, int) or edges < 1:
            raise ValueError(f"edges must be a positive int, got {edges!r}")
        if impl == "fused_masked" and edges > 1:
            raise ValueError("the masked kernel has no side operands: "
                             "hierarchical aggregation (edges > 1) needs "
                             "impl='fused' or 'serial'")
        agg = self.agg if agg is None else agg
        if agg == "auto":
            agg = ("sharded" if self.agg_mesh is not None
                   and self.agg_mesh.shape["model"] > 1 else "replicated")
        if agg not in ("replicated", "sharded"):
            raise ValueError(f"unknown agg {agg!r} (one of {AGG_MODES})")
        armed = False
        if faults is not None:
            if not isinstance(faults, FLT.FaultPlan):
                raise TypeError(
                    f"faults must be a fl.faults.FaultPlan, got {faults!r}"
                )
            k_total = sum(int(p.xs.shape[0]) for p in plans)
            if faults.k_total != k_total:
                raise ValueError(
                    f"FaultPlan covers {faults.k_total} clients but the "
                    f"cohort has {k_total}"
                )
            # an UNARMED plan (all ok, nothing staged, infinite bound) is
            # defined to be bit-equal to faults=None — it may take every
            # fast path; anything else needs the full index machinery
            armed = (faults.any_faults or bool(self._staging)
                     or faults.norm_bound != math.inf)
            if impl == "fused_masked" and armed:
                bad = [v.kind for v in faults.verdicts
                       if v.kind in ("straggler", "corrupt")]
                if bad or self._staging or faults.norm_bound != math.inf:
                    raise ValueError(
                        "fused_masked supports dropped-only fault plans "
                        "(no quarantine bound, no stragglers, empty "
                        "staging buffer): the masked kernel has no "
                        "quarantine or merge operands"
                    )
        # a hierarchical round always needs the index machinery: the edge
        # folds scatter by panel-space column ids even for one group
        layout = make_group_layout(
            plans, global_trainable, global_bn, frozen=frozen,
            force_index=armed or (edges > 1 and impl != "serial"),
        )
        fault_round = 0
        if faults is not None:
            self._fault_round += 1
            fault_round = self._fault_round
        if impl == "serial":
            return _grouped_serial(
                plans, global_trainable, global_bn, layout,
                faults=faults, staging=self._staging,
                fault_round=fault_round,
            )
        mesh = self.mesh if self.mode == "sharded" else None
        agg_mesh = self.agg_mesh
        if agg == "sharded" and agg_mesh is None:
            from repro.launch.mesh import make_model_mesh

            agg_mesh = self.agg_mesh = make_model_mesh()
        if stream_dtype == "int8":
            # satellite fix (ISSUE 8): a FrozenColumns epoch change remaps
            # the column space the residuals were accumulated against —
            # clear them so a stale residual can't land on remapped columns
            ekey = (None if layout.frozen is None
                    else (layout.frozen.n, layout.frozen.digest))
            if ekey != self._ef_epoch:
                self._ef_state.clear()
                self._ef_epoch = ekey
        if edges > 1:
            return _grouped_hier(
                plans, global_trainable, global_bn, layout, mesh,
                edges=edges, agg=agg, agg_mesh=agg_mesh,
                stream_dtype=stream_dtype, inflight=inflight,
                ef_state=self._ef_state if stream_dtype == "int8" else None,
                faults=faults, staging=self._staging,
                fault_round=fault_round,
            )
        return _grouped_fused(
            plans, global_trainable, global_bn, layout, mesh,
            kernel="masked" if impl == "fused_masked" else "grouped",
            agg=agg, agg_mesh=agg_mesh,
            stream_dtype=stream_dtype, inflight=inflight,
            ef_state=self._ef_state if stream_dtype == "int8" else None,
            faults=faults, staging=self._staging, fault_round=fault_round,
        )


def make_engine(mode: str = "vmap", mesh: Optional[Mesh] = None, *,
                agg: str = "auto", agg_mesh: Optional[Mesh] = None,
                stream_dtype: str = "f32",
                inflight: int = 2) -> CohortEngine:
    return CohortEngine(mode, mesh, agg=agg, agg_mesh=agg_mesh,
                        stream_dtype=stream_dtype, inflight=inflight)


def ef_state_to_tree(engine: CohortEngine) -> dict:
    """Checkpointable view of the engine's int8 error-feedback residuals
    (``em_state_to_tree``-style, for train/checkpoint.py): the ``(gi,
    (K, n))`` dict keys become flat ``"gi:KxN"`` strings so the tree
    round-trips through an npz archive, and the residual arrays ride
    verbatim.  Restoring with :func:`ef_state_from_tree` and resuming
    training is equivalent to never having stopped — the residual IS the
    only cross-round quantization state (tests/test_contract.py pins the
    restore equivalence).

    The frozen-column epoch the residuals were accumulated under travels
    along (the ``__ef_epoch__`` entry — ``[n, digest]`` as uint64, the
    digest being FrozenColumns' 16-hex-char sha1 prefix; empty for the
    unfrozen epoch): without it a restore into a fresh engine would trip
    the stale-epoch reset on the next round and silently discard the
    residuals it just loaded."""
    tree = {
        f"{gi}:{shape[0]}x{shape[1]}": v
        for (gi, shape), v in engine._ef_state.items()
    }
    if engine._ef_epoch is None:
        tree["__ef_epoch__"] = np.zeros((0,), np.uint64)
    else:
        n, digest = engine._ef_epoch
        tree["__ef_epoch__"] = np.asarray(
            [n, int(digest, 16)], np.uint64
        )
    return tree


def ef_state_from_tree(engine: CohortEngine, tree: dict) -> None:
    """Restore :func:`ef_state_to_tree`'s view into ``engine`` (in place),
    replacing whatever residuals and epoch marker it held."""
    state = {}
    epoch = None
    for key, v in tree.items():
        if str(key) == "__ef_epoch__":
            e = np.asarray(v, np.uint64).reshape(-1)
            if e.size:
                epoch = (int(e[0]), format(int(e[1]), "016x"))
            continue
        gi, _, kn = str(key).partition(":")
        k, _, n = kn.partition("x")
        state[(int(gi), (int(k), int(n)))] = jnp.asarray(v, jnp.float32)
    engine._ef_state.clear()
    engine._ef_state.update(state)
    engine._ef_epoch = epoch
