"""The paper's four baselines (§4.1), sharing the ProFL client machinery:

* AllSmall    — global model width-scaled to the minimum client memory.
* ExclusiveFL — only clients that can train the full model participate
                (returns NA when none can, as in the paper's ResNet34/VGG16).
* HeteroFL    — static width scaling per client; channel-sliced sub-models;
                masked weighted aggregation.
* DepthFL     — depth scaling per client with a classifier per block and
                accompanied objectives; ensemble inference.  (The optional
                mutual self-distillation term of DepthFL is omitted — noted
                in DESIGN.md; the paper's comparison point stands.)
"""
from __future__ import annotations

import copy
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import progressive as P
from repro.fl import data as DATA
from repro.fl import engine as ENG
from repro.fl import memory_model as MM
from repro.fl.server import FLConfig
from repro.models import cnn as C
from repro.train.train_step import softmax_xent

RATIOS = (1.0, 0.5, 0.25, 0.125, 0.0625)

_LOSS_CACHE: dict = {}


def _full_loss(cfg: C.CNNConfig, ratio: float):
    key = ("full", cfg, ratio)
    if key not in _LOSS_CACHE:

        def loss_fn(trainable, frozen, bn_state, xb, yb):
            logits, new_bn = C.forward_cnn(
                cfg, trainable, bn_state, xb, train=True, ratio=ratio
            )
            return softmax_xent(logits, yb), new_bn

        _LOSS_CACHE[key] = loss_fn
    return _LOSS_CACHE[key]


class _Runner:
    """Shared cohort plumbing for baseline loops."""

    def __init__(self, cfg, fl: FLConfig, xtr, ytr, xte, yte, parts, budgets):
        self.cfg, self.fl = cfg, fl
        self.xtr, self.ytr, self.xte, self.yte = xtr, ytr, xte, yte
        self.parts, self.budgets = parts, budgets
        self.rng = np.random.default_rng(fl.seed)
        self._key = jax.random.PRNGKey(fl.seed + 1)
        self.engine = ENG.make_engine(fl.engine)

    def round(self, loss_fn, trainable, frozen, bn, xs, ys, rngs, w, *,
              lr=None, local_steps=None, batch_size=None):
        fl = self.fl
        res = self.engine.round(
            loss_fn, trainable, frozen, bn, xs, ys, rngs, w,
            lr=fl.lr if lr is None else lr,
            local_steps=fl.local_steps if local_steps is None else local_steps,
            batch_size=fl.batch_size if batch_size is None else batch_size,
        )
        return res.trainable, res.bn_state, res.loss

    def next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def cohort(self, sel):
        xs, ys, w = [], [], []
        for cid in sel:
            xb, yb = DATA.client_batch(
                self.xtr, self.ytr, self.parts[cid], self.fl.n_local_fixed, self.rng
            )
            xs.append(xb)
            ys.append(yb)
            w.append(len(self.parts[cid]))
        return (
            jnp.asarray(np.stack(xs)),
            jnp.asarray(np.stack(ys)),
            jnp.asarray(np.array(w, np.float32)),
        )


# ===========================================================================
# AllSmall
# ===========================================================================


def run_allsmall(cfg, fl: FLConfig, xtr, ytr, xte, yte, parts, budgets, rounds):
    r = next((x for x in RATIOS if MM.full_train_memory_mb(cfg, ratio=x)
              <= budgets.min()), RATIOS[-1])
    R = _Runner(cfg, fl, xtr, ytr, xte, yte, parts, budgets)
    params, bn = C.init_cnn(cfg, R.next_key(), r * fl.ratio)
    loss_fn = _full_loss(cfg, r * fl.ratio)
    accs = []
    for _ in range(rounds):
        sel = R.rng.choice(fl.n_clients, fl.clients_per_round, replace=False)
        xs, ys, w = R.cohort(sel)
        rngs = jax.random.split(R.next_key(), len(sel))
        params, bn, _ = R.round(loss_fn, params, {}, bn, xs, ys, rngs, w)
        accs.append(_acc_full(cfg, params, bn, xte, yte, r * fl.ratio))
    return {"acc": float(np.mean(accs[-10:])), "pr": 1.0, "ratio": r,
            "curve": accs}


# ===========================================================================
# ExclusiveFL
# ===========================================================================


def run_exclusivefl(cfg, fl: FLConfig, xtr, ytr, xte, yte, parts, budgets, rounds):
    elig = MM.eligible(budgets, MM.full_train_memory_mb(cfg))
    pr = len(elig) / fl.n_clients
    if len(elig) == 0:
        return {"acc": None, "pr": 0.0}  # NA — paper Tables 1–2
    R = _Runner(cfg, fl, xtr, ytr, xte, yte, parts, budgets)
    params, bn = C.init_cnn(cfg, R.next_key(), fl.ratio)
    loss_fn = _full_loss(cfg, fl.ratio)
    accs = []
    for _ in range(rounds):
        sel = R.rng.choice(elig, min(fl.clients_per_round, len(elig)),
                           replace=False)
        xs, ys, w = R.cohort(sel)
        rngs = jax.random.split(R.next_key(), len(sel))
        params, bn, _ = R.round(loss_fn, params, {}, bn, xs, ys, rngs, w)
        accs.append(_acc_full(cfg, params, bn, xte, yte, fl.ratio))
    return {"acc": float(np.mean(accs[-10:])), "pr": pr, "curve": accs}


# ===========================================================================
# HeteroFL
# ===========================================================================


def run_heterofl(cfg, fl: FLConfig, xtr, ytr, xte, yte, parts, budgets, rounds):
    levels = np.array([
        MM.width_ratio_for_budget(cfg, b, RATIOS[:-1]) or RATIOS[-1]
        for b in budgets
    ])
    R = _Runner(cfg, fl, xtr, ytr, xte, yte, parts, budgets)
    params, bn = C.init_cnn(cfg, R.next_key(), fl.ratio)  # global (full width)
    templates = {
        r: C.init_cnn(cfg, jax.random.PRNGKey(0), r * fl.ratio)
        for r in sorted(set(levels.tolist()))
    }
    accs = []
    for _ in range(rounds):
        sel = R.rng.choice(fl.n_clients, fl.clients_per_round, replace=False)
        num = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), params)
        den = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), params)
        bn_new = None
        for r in sorted(set(levels[sel].tolist())):
            group = sel[levels[sel] == r]
            sub_t, sub_bn_t = templates[r]
            sub = C.slice_cnn_params(params, sub_t)
            sub_bn = C.slice_cnn_params(bn, sub_bn_t)
            xs, ys, w = R.cohort(group)
            rngs = jax.random.split(R.next_key(), len(group))
            loss_fn = _full_loss(cfg, r * fl.ratio)
            sub, sub_bn, _ = R.round(loss_fn, sub, {}, sub_bn, xs, ys, rngs, w)
            wsum = float(np.sum([len(parts[c]) for c in group]))
            padded, mask = C.scatter_cnn_params(params, sub)
            num = jax.tree.map(lambda n, p: n + wsum * p.astype(jnp.float32),
                               num, padded)
            den = jax.tree.map(lambda d, m: d + wsum * m, den, mask)
            if r == max(levels[sel]):  # widest group defines bn stats
                bn_pad, bn_mask = C.scatter_cnn_params(bn, sub_bn)
                bn_new = jax.tree.map(
                    lambda old, newp, m: jnp.where(m > 0, newp, old),
                    bn, bn_pad, bn_mask,
                )
        params = jax.tree.map(
            lambda old, n, d: jnp.where(d > 0, n / jnp.maximum(d, 1e-9), old)
            .astype(old.dtype),
            params, num, den,
        )
        if bn_new is not None:
            bn = bn_new
        accs.append(_acc_full(cfg, params, bn, xte, yte, fl.ratio))
    return {"acc": float(np.mean(accs[-10:])), "pr": 1.0,
            "levels": levels.tolist(), "curve": accs}


# ===========================================================================
# DepthFL
# ===========================================================================


def _init_depth_heads(cfg, rng, ratio):
    chans = C.block_out_channels(cfg, ratio)
    return [
        {
            "w": jax.random.normal(jax.random.fold_in(rng, b), (c, cfg.n_classes))
            / np.sqrt(c),
            "b": jnp.zeros((cfg.n_classes,)),
        }
        for b, c in enumerate(chans)
    ]


def _depth_loss(cfg: C.CNNConfig, depth: int, ratio: float):
    key = ("depth", cfg, depth, ratio)
    if key not in _LOSS_CACHE:

        def loss_fn(trainable, frozen, bn_state, xb, yb):
            x = xb
            loss = 0.0
            new_bn = {"blocks": list(bn_state["blocks"])}
            for bi in range(depth):
                x, nbs = P.apply_cnn_block(
                    cfg, bi, trainable["blocks"][bi], bn_state["blocks"][bi],
                    x, True, ratio,
                )
                new_bn["blocks"][bi] = nbs
                h = trainable["heads"][bi]
                logits = jnp.mean(x, axis=(1, 2)) @ h["w"] + h["b"]
                loss = loss + softmax_xent(logits, yb)
            return loss / depth, new_bn

        _LOSS_CACHE[key] = loss_fn
    return _LOSS_CACHE[key]


def run_depthfl(cfg, fl: FLConfig, xtr, ytr, xte, yte, parts, budgets, rounds):
    depths = np.array([MM.depth_for_budget(cfg, b) for b in budgets])
    pr = float(np.mean(depths > 0))
    R = _Runner(cfg, fl, xtr, ytr, xte, yte, parts, budgets)
    params, bn = C.init_cnn(cfg, R.next_key(), fl.ratio)
    heads = _init_depth_heads(cfg, R.next_key(), fl.ratio)
    max_trained = int(depths.max()) if pr > 0 else 0
    accs = []
    for _ in range(rounds):
        cand = np.where(depths > 0)[0]
        if len(cand) == 0:
            break
        sel = R.rng.choice(cand, min(fl.clients_per_round, len(cand)),
                           replace=False)
        num_b = [jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), blk)
                 for blk in params["blocks"]]
        num_h = [jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), h)
                 for h in heads]
        den = np.zeros(cfg.n_prog_blocks)
        bn_cur = bn
        for d in sorted(set(depths[sel].tolist())):
            group = sel[depths[sel] == d]
            trainable = {
                "blocks": [params["blocks"][i] for i in range(d)],
                "heads": [heads[i] for i in range(d)],
            }
            xs, ys, w = R.cohort(group)
            rngs = jax.random.split(R.next_key(), len(group))
            out, bn_cur, _ = R.round(
                _depth_loss(cfg, d, fl.ratio), trainable, {}, bn_cur,
                xs, ys, rngs, w,
            )
            wsum = float(np.sum([len(parts[c]) for c in group]))
            for i in range(d):
                num_b[i] = jax.tree.map(
                    lambda n, p: n + wsum * p, num_b[i], out["blocks"][i]
                )
                num_h[i] = jax.tree.map(
                    lambda n, p: n + wsum * p, num_h[i], out["heads"][i]
                )
                den[i] += wsum
        new_blocks = []
        for i in range(cfg.n_prog_blocks):
            if den[i] > 0:
                new_blocks.append(
                    jax.tree.map(lambda n: n / den[i], num_b[i])
                )
                heads[i] = jax.tree.map(lambda n: n / den[i], num_h[i])
            else:
                new_blocks.append(params["blocks"][i])
        params = dict(params, blocks=new_blocks)
        bn = bn_cur
        accs.append(
            _acc_depth_ensemble(cfg, params, heads, bn, xte, yte,
                                max_trained, fl.ratio)
        )
    acc = float(np.mean(accs[-10:])) if accs else None
    return {"acc": acc, "pr": pr, "depths": depths.tolist(), "curve": accs}


# ===========================================================================
# eval helpers
# ===========================================================================


def _acc_full(cfg, params, bn, xte, yte, ratio):
    logits, _ = C.forward_cnn(
        cfg, params, bn, jnp.asarray(xte), train=True, ratio=ratio
    )
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yte)))


def _acc_depth_ensemble(cfg, params, heads, bn, xte, yte, max_trained, ratio):
    """DepthFL inference: average the logits of every trained classifier."""
    x = jnp.asarray(xte)
    logits_sum = 0.0
    n = 0
    for bi in range(cfg.n_prog_blocks):
        x, _ = P.apply_cnn_block(cfg, bi, params["blocks"][bi],
                                 bn["blocks"][bi], x, True, ratio)
        h = heads[bi]
        logits_sum = logits_sum + jax.nn.log_softmax(
            jnp.mean(x, axis=(1, 2)) @ h["w"] + h["b"]
        )
        n += 1
        if bi + 1 >= max(max_trained, 1):
            break
    return float(jnp.mean(jnp.argmax(logits_sum / n, -1) == jnp.asarray(yte)))
