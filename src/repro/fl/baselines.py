"""The paper's four baselines (§4.1), sharing the ProFL client machinery:

* AllSmall    — global model width-scaled to the minimum client memory.
* ExclusiveFL — only clients that can train the full model participate
                (returns NA when none can, as in the paper's ResNet34/VGG16).
* HeteroFL    — static width scaling per client; channel-sliced sub-models;
                masked weighted aggregation.
* DepthFL     — depth scaling per client with a classifier per block and
                accompanied objectives; ensemble inference.  (The optional
                mutual self-distillation term of DepthFL is omitted — noted
                in DESIGN.md; the paper's comparison point stands.)

HeteroFL and DepthFL run their multi-structure cohorts through
``CohortEngine.grouped_round``: every width/depth group becomes a
:class:`repro.fl.engine.GroupPlan` and the whole ragged cohort aggregates in
ONE fused group-compressed dispatch (``kernels.ops.fedavg_grouped``:
per-column ``Σ w·p / Σ_g wsum·gmask`` over a ``[G, n]`` group mask, with a
zero-denominator passthrough) instead of a serial per-group loop of rounds
with host-side num/den tree-maps; group launches pipeline without host
syncs until the aggregation barrier.  The plans themselves carry RAW
weights — the engine derives the per-group weight sums the compressed
denominator needs, so plan construction here stays unchanged whichever
aggregation (grouped / legacy dense-mask / serial) executes them.
``oracle=True`` forces the serial per-group path — the equivalence oracle
asserted in tests.  BN stats now
aggregate under the same per-column masked average as the weights (each
client contributes to exactly the bn columns its sub-model touched); for
DepthFL this replaces the old order-dependent serial bn threading, and for
HeteroFL the old "widest group defines bn" rule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import effective_movement as EM
from repro.core import progressive as P
from repro.fl import async_server as AS
from repro.fl import data as DATA
from repro.fl import engine as ENG
from repro.fl import faults as FLT
from repro.fl import memory_model as MM
from repro.fl.server import FLConfig
from repro.models import cnn as C
from repro.train.train_step import softmax_xent

RATIOS = (1.0, 0.5, 0.25, 0.125, 0.0625)

# bounded: loss closures are jit cache keys, but sweeps over many
# (cfg, depth, ratio) keys must not grow without limit
_LOSS_CACHE: ENG.BoundedCache = ENG.BoundedCache(maxsize=128)


def _full_loss(cfg: C.CNNConfig, ratio: float):
    key = ("full", cfg, ratio)
    if key not in _LOSS_CACHE:

        def loss_fn(trainable, frozen, bn_state, xb, yb):
            logits, new_bn = C.forward_cnn(
                cfg, trainable, bn_state, xb, train=True, ratio=ratio
            )
            return softmax_xent(logits, yb), new_bn

        _LOSS_CACHE[key] = loss_fn
    return _LOSS_CACHE[key]


class _Runner:
    """Shared cohort plumbing for baseline loops."""

    def __init__(self, cfg, fl: FLConfig, xtr, ytr, xte, yte, parts, budgets):
        self.cfg, self.fl = cfg, fl
        self.xtr, self.ytr, self.xte, self.yte = xtr, ytr, xte, yte
        self.parts, self.budgets = parts, budgets
        self.rng = np.random.default_rng(fl.seed)
        self._key = jax.random.PRNGKey(fl.seed + 1)
        self.engine = ENG.make_engine(fl.engine)
        # async aggregation state (fl.async_agg) — baseline global trees
        # keep one structure for the whole run, so one server suffices
        self._async_srv: AS.AsyncAggServer = None
        self._async_sim: AS.ArrivalSimulator = None
        self._async_round = 0

    def grouped(self, plans, global_tr, global_bn, *, impl=None, frozen=None,
                faults=None):
        """Route one round's grouped cohort: the sync ``grouped_round``
        call by default, or — under ``fl.async_agg`` — versioned
        submissions into an :class:`AsyncAggServer` on the config's seeded
        arrival schedule (one submission per structure group).  Returns the
        last publish's result, or None when nothing published this round.
        An explicit ``faults`` plan applies only to publishes whose fresh
        cohort matches its size (a partially-arrived cohort has no
        per-client verdict alignment)."""
        if self.fl.async_agg is None:
            return self.engine.grouped_round(
                plans, global_tr, global_bn, impl=impl, frozen=frozen,
                faults=faults,
            )
        ac = self.fl.async_agg
        if self._async_srv is None:
            k_total = sum(int(p.xs.shape[0]) for p in plans)
            publish_at = ac.publish_at or k_total
            self._async_srv = AS.AsyncAggServer(
                self.engine, global_tr, global_bn,
                publish_at=publish_at, beta=ac.beta,
                max_buffer=max(ac.max_buffer, publish_at),
                max_versions=ac.max_versions, impl=impl,
            )
            self._async_sim = AS.ArrivalSimulator(ac)
        srv = self._async_srv
        srv.frozen = frozen
        arrived = self._async_sim.step(
            self._async_round, [(p, srv.version) for p in plans]
        )
        self._async_round += 1
        for p, ver in arrived:
            srv.submit(p, ver)
        res = None
        while srv.ready():
            res = srv.publish(faults_fn=lambda k: (
                faults if faults is not None and faults.k_total == k
                else None
            ))
        return res

    def round(self, loss_fn, trainable, frozen, bn, xs, ys, rngs, w, *,
              lr=None, local_steps=None, batch_size=None):
        fl = self.fl
        res = self.engine.round(
            loss_fn, trainable, frozen, bn, xs, ys, rngs, w,
            lr=fl.lr if lr is None else lr,
            local_steps=fl.local_steps if local_steps is None else local_steps,
            batch_size=fl.batch_size if batch_size is None else batch_size,
        )
        return res.trainable, res.bn_state, res.loss

    def next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def cohort(self, sel):
        xs, ys, w = [], [], []
        for cid in sel:
            xb, yb = DATA.client_batch(
                self.xtr, self.ytr, self.parts[cid], self.fl.n_local_fixed, self.rng
            )
            xs.append(xb)
            ys.append(yb)
            w.append(len(self.parts[cid]))
        return (
            jnp.asarray(np.stack(xs)),
            jnp.asarray(np.stack(ys)),
            jnp.asarray(np.array(w, np.float32)),
        )


# ===========================================================================
# AllSmall
# ===========================================================================


def run_allsmall(cfg, fl: FLConfig, xtr, ytr, xte, yte, parts, budgets, rounds):
    r = next((x for x in RATIOS if MM.full_train_memory_mb(cfg, ratio=x)
              <= budgets.min()), RATIOS[-1])
    R = _Runner(cfg, fl, xtr, ytr, xte, yte, parts, budgets)
    params, bn = C.init_cnn(cfg, R.next_key(), r * fl.ratio)
    loss_fn = _full_loss(cfg, r * fl.ratio)
    accs = []
    for _ in range(rounds):
        sel = R.rng.choice(fl.n_clients, fl.clients_per_round, replace=False)
        xs, ys, w = R.cohort(sel)
        rngs = jax.random.split(R.next_key(), len(sel))
        params, bn, _ = R.round(loss_fn, params, {}, bn, xs, ys, rngs, w)
        accs.append(_acc_full(cfg, params, bn, xte, yte, r * fl.ratio))
    return {"acc": float(np.mean(accs[-10:])), "pr": 1.0, "ratio": r,
            "curve": accs}


# ===========================================================================
# ExclusiveFL
# ===========================================================================


def run_exclusivefl(cfg, fl: FLConfig, xtr, ytr, xte, yte, parts, budgets, rounds):
    elig = MM.eligible(budgets, MM.full_train_memory_mb(cfg))
    pr = len(elig) / fl.n_clients
    if len(elig) == 0:
        return {"acc": None, "pr": 0.0}  # NA — paper Tables 1–2
    R = _Runner(cfg, fl, xtr, ytr, xte, yte, parts, budgets)
    params, bn = C.init_cnn(cfg, R.next_key(), fl.ratio)
    loss_fn = _full_loss(cfg, fl.ratio)
    accs = []
    for _ in range(rounds):
        sel = R.rng.choice(elig, min(fl.clients_per_round, len(elig)),
                           replace=False)
        xs, ys, w = R.cohort(sel)
        rngs = jax.random.split(R.next_key(), len(sel))
        params, bn, _ = R.round(loss_fn, params, {}, bn, xs, ys, rngs, w)
        accs.append(_acc_full(cfg, params, bn, xte, yte, fl.ratio))
    return {"acc": float(np.mean(accs[-10:])), "pr": pr, "curve": accs}


# ===========================================================================
# HeteroFL
# ===========================================================================


def run_heterofl(cfg, fl: FLConfig, xtr, ytr, xte, yte, parts, budgets, rounds,
                 *, oracle: bool = False, freeze_em: "EM.EMConfig" = None,
                 fault_cfg: "FLT.FaultConfig" = None):
    """Static-width HeteroFL.  Every round builds one :class:`GroupPlan` per
    width level and hands the whole ragged cohort to ``grouped_round`` — one
    fused group-compressed aggregation dispatch regardless of how many width
    groups the selection produced.  ``oracle=True`` routes the identical
    plans through the serial per-group reference path instead.

    ``freeze_em`` (optional) enables freezing-aware layouts: a per-block
    :class:`~repro.core.effective_movement.FreezeTracker` over the
    aggregated global params; blocks whose effective movement converges
    leave the panel, the stream, and the kernel for the rest of the run
    (``grouped_round(frozen=...)``) — clients still train them locally, the
    server just stops aggregating them, so per-round bytes decay.

    ``fault_cfg`` (optional) injects seeded per-round faults — dropouts,
    stragglers, poisoned updates — via ``grouped_round(faults=...)``; see
    :mod:`repro.fl.faults`."""
    levels = np.array([
        MM.width_ratio_for_budget(cfg, b, RATIOS[:-1]) or RATIOS[-1]
        for b in budgets
    ])
    R = _Runner(cfg, fl, xtr, ytr, xte, yte, parts, budgets)
    params, bn = C.init_cnn(cfg, R.next_key(), fl.ratio)  # global (full width)
    templates = {
        r: C.init_cnn(cfg, jax.random.PRNGKey(0), r * fl.ratio)
        for r in sorted(set(levels.tolist()))
    }
    impl = "serial" if oracle else None
    tracker, fro = None, None
    if freeze_em is not None:
        tracker = EM.FreezeTracker(freeze_em, {
            f"['blocks'][{i}]": ENG.columns_for_paths(
                params, [f"['blocks'][{i}]"]
            )
            for i in range(len(params["blocks"]))
        })
    accs = []
    for rnd in range(rounds):
        sel = R.rng.choice(fl.n_clients, fl.clients_per_round, replace=False)
        plans = []
        for r in sorted(set(levels[sel].tolist())):
            group = sel[levels[sel] == r]
            sub_t, sub_bn_t = templates[r]
            xs, ys, w = R.cohort(group)
            plans.append(ENG.GroupPlan(
                _full_loss(cfg, r * fl.ratio),
                C.slice_cnn_params(params, sub_t), {},
                C.slice_cnn_params(bn, sub_bn_t),
                xs, ys, jax.random.split(R.next_key(), len(group)), w,
                fl.lr, fl.local_steps, fl.batch_size,
            ))
        fplan = (FLT.sample_fault_plan(fault_cfg, len(sel), rnd + 1)
                 if fault_cfg is not None else None)
        res = R.grouped(plans, params, bn, impl=impl, frozen=fro,
                        faults=fplan)
        if res is not None:  # async: None = no publish this round
            params, bn = res.trainable, res.bn_state
            if tracker is not None:
                flat = (res.packed if res.packed is not None
                        else EM.flatten_params(params))
                if tracker.update(flat):
                    fro = ENG.frozen_columns_for_paths(
                        params, bn, tracker.frozen_names
                    )
        accs.append(_acc_full(cfg, params, bn, xte, yte, fl.ratio))
    out = {"acc": float(np.mean(accs[-10:])), "pr": 1.0,
           "levels": levels.tolist(), "curve": accs,
           "params": params, "bn": bn}
    if tracker is not None:
        out["frozen_blocks"] = tracker.frozen_names
    return out


# ===========================================================================
# DepthFL
# ===========================================================================


def _init_depth_heads(cfg, rng, ratio):
    chans = C.block_out_channels(cfg, ratio)
    return [
        {
            "w": jax.random.normal(jax.random.fold_in(rng, b), (c, cfg.n_classes))
            / np.sqrt(c),
            "b": jnp.zeros((cfg.n_classes,)),
        }
        for b, c in enumerate(chans)
    ]


def _depth_loss(cfg: C.CNNConfig, depth: int, ratio: float):
    key = ("depth", cfg, depth, ratio)
    if key not in _LOSS_CACHE:

        def loss_fn(trainable, frozen, bn_state, xb, yb):
            x = xb
            loss = 0.0
            new_bn = {"blocks": list(bn_state["blocks"])}
            for bi in range(depth):
                x, nbs = P.apply_cnn_block(
                    cfg, bi, trainable["blocks"][bi], bn_state["blocks"][bi],
                    x, True, ratio,
                )
                new_bn["blocks"][bi] = nbs
                h = trainable["heads"][bi]
                logits = jnp.mean(x, axis=(1, 2)) @ h["w"] + h["b"]
                loss = loss + softmax_xent(logits, yb)
            return loss / depth, new_bn

        _LOSS_CACHE[key] = loss_fn
    return _LOSS_CACHE[key]


def run_depthfl(cfg, fl: FLConfig, xtr, ytr, xte, yte, parts, budgets, rounds,
                *, oracle: bool = False, freeze_em: "EM.EMConfig" = None,
                fault_cfg: "FLT.FaultConfig" = None):
    """Depth-scaled DepthFL.  Each depth level d becomes a :class:`GroupPlan`
    whose trainable is the {blocks[:d], heads[:d]} prefix of the global tree;
    ``grouped_round`` aggregates every depth group (plus bn) in one fused
    group-compressed dispatch, blocks nobody trained passing through
    untouched.  Every
    group starts from the round-start bn and bn aggregates under the same
    per-column masked average (order-independent, unlike the old serial
    threading).  ``oracle=True`` forces the serial per-group reference.

    ``freeze_em`` (optional) enables freezing-aware layouts per depth block:
    a converged block and its classifier head (plus its bn columns) leave
    the panel/stream/kernel via ``grouped_round(frozen=...)``.

    ``fault_cfg`` (optional) injects seeded per-round faults — dropouts,
    stragglers, poisoned updates — via ``grouped_round(faults=...)``; see
    :mod:`repro.fl.faults`."""
    depths = np.array([MM.depth_for_budget(cfg, b) for b in budgets])
    pr = float(np.mean(depths > 0))
    R = _Runner(cfg, fl, xtr, ytr, xte, yte, parts, budgets)
    params, bn = C.init_cnn(cfg, R.next_key(), fl.ratio)
    heads = _init_depth_heads(cfg, R.next_key(), fl.ratio)
    max_trained = int(depths.max()) if pr > 0 else 0
    impl = "serial" if oracle else None
    tracker, fro, prefixes = None, None, {}
    if freeze_em is not None:
        tr0 = {"blocks": list(params["blocks"]), "heads": list(heads)}
        prefixes = {
            f"d{i}": (f"['blocks'][{i}]", f"['heads'][{i}]")
            for i in range(cfg.n_prog_blocks)
        }
        tracker = EM.FreezeTracker(freeze_em, {
            name: np.concatenate([
                ENG.columns_for_paths(tr0, [p]) for p in pref
            ])
            for name, pref in prefixes.items()
        })
    accs = []
    for rnd in range(rounds):
        cand = np.where(depths > 0)[0]
        if len(cand) == 0:
            break
        sel = R.rng.choice(cand, min(fl.clients_per_round, len(cand)),
                           replace=False)
        plans = []
        for d in sorted(set(depths[sel].tolist())):
            group = sel[depths[sel] == d]
            trainable = {
                "blocks": [params["blocks"][i] for i in range(d)],
                "heads": [heads[i] for i in range(d)],
            }
            # bn PREFIX view: the membership mask must cover exactly the bn
            # columns this depth trains, so deeper blocks' running stats are
            # not diluted by shallow clients' unchanged round-start copies
            sub_bn = {"blocks": list(bn["blocks"][:d])}
            xs, ys, w = R.cohort(group)
            plans.append(ENG.GroupPlan(
                _depth_loss(cfg, d, fl.ratio), trainable, {}, sub_bn,
                xs, ys, jax.random.split(R.next_key(), len(group)), w,
                fl.lr, fl.local_steps, fl.batch_size,
            ))
        global_tr = {"blocks": list(params["blocks"]), "heads": list(heads)}
        fplan = (FLT.sample_fault_plan(fault_cfg, len(sel), rnd + 1)
                 if fault_cfg is not None else None)
        res = R.grouped(plans, global_tr, bn, impl=impl,
                        frozen=fro, faults=fplan)
        if res is not None:  # async: None = no publish this round
            params = dict(params, blocks=res.trainable["blocks"])
            heads = list(res.trainable["heads"])
            bn = res.bn_state
            if tracker is not None:
                flat = (res.packed if res.packed is not None
                        else EM.flatten_params(res.trainable))
                if tracker.update(flat):
                    pref = [p for nm in tracker.frozen_names
                            for p in prefixes[nm]]
                    fro = ENG.frozen_columns_for_paths(global_tr, bn, pref)
        accs.append(
            _acc_depth_ensemble(cfg, params, heads, bn, xte, yte,
                                max_trained, fl.ratio)
        )
    acc = float(np.mean(accs[-10:])) if accs else None
    out = {"acc": acc, "pr": pr, "depths": depths.tolist(), "curve": accs,
           "params": params, "bn": bn, "heads": heads}
    if tracker is not None:
        out["frozen_blocks"] = tracker.frozen_names
    return out


# ===========================================================================
# eval helpers
# ===========================================================================


def _acc_full(cfg, params, bn, xte, yte, ratio):
    logits, _ = C.forward_cnn(
        cfg, params, bn, jnp.asarray(xte), train=True, ratio=ratio
    )
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yte)))


def _acc_depth_ensemble(cfg, params, heads, bn, xte, yte, max_trained, ratio):
    """DepthFL inference: average the logits of every trained classifier."""
    x = jnp.asarray(xte)
    logits_sum = 0.0
    n = 0
    for bi in range(cfg.n_prog_blocks):
        x, _ = P.apply_cnn_block(cfg, bi, params["blocks"][bi],
                                 bn["blocks"][bi], x, True, ratio)
        h = heads[bi]
        logits_sum = logits_sum + jax.nn.log_softmax(
            jnp.mean(x, axis=(1, 2)) @ h["w"] + h["b"]
        )
        n += 1
        if bi + 1 >= max(max_trained, 1):
            break
    return float(jnp.mean(jnp.argmax(logits_sum / n, -1) == jnp.asarray(yte)))
