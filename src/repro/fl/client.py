"""Client-side local training, vmapped across the selected cohort.

Every selected client in a round trains the SAME sub-model structure
(paper §4.2: "synchronous training of the same parameters ... resolves
parameter mismatch"), so local SGD vmaps over (data, rng) with the global
trainable tree broadcast.  ``loss_fn`` is any callable
``(trainable, frozen, bn_state, xb, yb) -> (loss, new_bn_state)``.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp


def make_client_update(
    loss_fn: Callable, *, lr: float, local_steps: int, batch_size: int
) -> Callable:
    """Returns client_update(trainable, frozen, bn_state, xb, yb, rng)
    -> (new_trainable, new_bn_state, mean_loss) for ONE client."""

    def client_update(trainable, frozen, bn_state, xs, ys, rng):
        def step(carry, rng_i):
            tr, bn = carry
            idx = jax.random.randint(rng_i, (batch_size,), 0, xs.shape[0])
            (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                tr, frozen, bn, xs[idx], ys[idx]
            )
            tr = jax.tree.map(lambda p, g: p - lr * g, tr, grads)
            return (tr, new_bn), loss

        (tr, bn), losses = jax.lax.scan(
            step, (trainable, bn_state), jax.random.split(rng, local_steps)
        )
        return tr, bn, jnp.mean(losses)

    return client_update


@functools.partial(jax.jit, static_argnames=("loss_fn", "lr", "local_steps", "batch_size"))
def cohort_round(
    loss_fn,
    trainable,
    frozen,
    bn_state,
    xs,  # [K, n_local, ...]
    ys,  # [K, n_local]
    rngs,  # [K, 2]
    weights,  # [K] aggregation weights (|D_n| / |D|, renormalized)
    *,
    lr: float,
    local_steps: int,
    batch_size: int,
):
    """One FL round: vmapped local training + weighted FedAvg (Eq. 1).
    Returns (aggregated_trainable, aggregated_bn_state, mean_loss)."""
    upd = make_client_update(
        loss_fn, lr=lr, local_steps=local_steps, batch_size=batch_size
    )
    trs, bns, losses = jax.vmap(upd, in_axes=(None, None, None, 0, 0, 0))(
        trainable, frozen, bn_state, xs, ys, rngs
    )
    w = weights / jnp.sum(weights)
    agg = lambda leaf: jnp.einsum("k,k...->...", w, leaf.astype(jnp.float32)).astype(
        leaf.dtype
    )
    return (
        jax.tree.map(agg, trs),
        jax.tree.map(agg, bns),
        jnp.sum(w * losses),
    )
