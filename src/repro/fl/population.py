"""Client-population registry + memory-budgeted cohort admission (ISSUE 10).

Production FL samples a round's cohort of ~10²–10³ clients from a REGISTRY
of ~10⁶⁺ — this module is that registry plus the sampler, with
``fl/memory_model.py`` acting as the ADMISSION policy: a client enters a
round only if (a) its device budget covers the training footprint of its
structure group (:func:`repro.fl.memory_model.submodel_train_memory_mb`)
and (b) the server's configured peak budget still admits the grown cohort
(:func:`repro.fl.memory_model.server_aggregation_peak_bytes`).  The
paper's memory-wall constraint becomes a scheduler.

* :func:`build_population` — a columnar registry over a synthetic ``N ≥
  1M`` population: per-client structure-group assignment (budget-driven,
  HeteroFL-style tiers), memory budget in MB
  (``memory_model.assign_budgets_mb``), and aggregation weight drawn from
  the empirical shard-size distribution of an ``fl/data.py`` Dirichlet
  prototype partition — the registry scales to millions of clients
  without materializing millions of shards.
* :func:`sample_cohort` — seeded, weighted, stratified sampling: a PURE
  function of ``(seed, round_idx)`` (``np.random.default_rng((seed,
  round))``, the ``fl/faults.py`` idiom), so admission decisions are
  reproducible across processes and resumable mid-run.  Strata are the
  structure groups with largest-remainder proportional quotas; within a
  stratum candidates are drawn weighted-without-replacement via Gumbel
  top-k, then admitted in draw order through the two memory gates.
* :class:`CohortSampler` — the resumable cursor: ``next_cohort()``
  advances a round counter that round-trips through
  ``train/checkpoint.py`` (:meth:`CohortSampler.state_to_tree`), so a
  restored run continues the exact cohort sequence it would have drawn.

tests/test_population.py pins two-process determinism and the admission /
strata / resume invariants (hypothesis properties in
tests/test_properties.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from repro.fl import data as DATA
from repro.fl import memory_model as MM
from repro.models import cnn as C


@dataclass(frozen=True)
class PopulationConfig:
    """Knobs of the synthetic registry.  ``proto_clients``/``proto_samples``
    size the ``fl/data.py`` Dirichlet prototype partition whose empirical
    shard-size distribution the per-client weights are drawn from."""

    n_clients: int = 1_000_000
    n_groups: int = 4
    seed: int = 0
    budget_lo: float = 100.0
    budget_hi: float = 900.0
    proto_clients: int = 128
    proto_samples: int = 4096
    alpha: float = 1.0  # Dirichlet label-skew of the prototype partition


@dataclass(frozen=True)
class Population:
    """Columnar client registry: row ``c`` is client ``c``."""

    cfg: PopulationConfig
    groups: np.ndarray  # [N] int16 structure-group id (0 = smallest budget)
    budgets_mb: np.ndarray  # [N] f32 device memory budget
    weights: np.ndarray  # [N] f32 aggregation weight (shard size)
    thresholds: np.ndarray  # [n_groups-1] budget cut points of the tiers
    _strata: Tuple[np.ndarray, ...] = field(default=(), repr=False)

    @property
    def n_clients(self) -> int:
        return int(self.groups.shape[0])

    @property
    def strata(self) -> Tuple[np.ndarray, ...]:
        """Per-group client-id arrays (ascending ids), built once."""
        return self._strata


@dataclass(frozen=True)
class Cohort:
    """One round's admitted cohort, in deterministic admission order."""

    round_idx: int
    ids: np.ndarray  # [k] int64 client ids
    groups: np.ndarray  # [k] int16 group per admitted client
    weights: np.ndarray  # [k] f32 aggregation weights
    considered: int  # candidates drawn across all strata
    rejected_budget: int  # device-budget gate rejections
    rejected_server: int  # server-peak gate rejections (incl. quota spill)

    @property
    def k(self) -> int:
        return int(self.ids.shape[0])


def build_population(cfg: PopulationConfig) -> Population:
    """Materialize the registry: budgets, budget-tier group assignment, and
    weights from an ``fl/data.py`` shard-size distribution — all from
    ``cfg.seed`` alone (two processes build identical registries)."""
    if cfg.n_clients < 1:
        raise ValueError("n_clients must be >= 1")
    if cfg.n_groups < 1:
        raise ValueError("n_groups must be >= 1")
    rng = np.random.default_rng(cfg.seed)
    # empirical shard sizes: one Dirichlet prototype partition over a
    # synthetic label pool (fl/data.py), then resampled out to N clients —
    # the non-IID size spread at registry scale without N actual shards
    key = jax.random.PRNGKey(cfg.seed)
    labels = np.asarray(
        jax.random.randint(key, (cfg.proto_samples,), 0, 10)
    )
    parts = DATA.partition_dirichlet(
        key, labels, cfg.proto_clients, alpha=cfg.alpha, min_per_client=1
    )
    proto_sizes = np.asarray([len(p) for p in parts], np.float32)
    weights = rng.choice(proto_sizes, size=cfg.n_clients, replace=True)
    weights = np.maximum(weights, 1.0).astype(np.float32)
    budgets = MM.assign_budgets_mb(
        rng, cfg.n_clients, cfg.budget_lo, cfg.budget_hi
    ).astype(np.float32)
    # budget-driven structure tiers (HeteroFL-style): evenly spaced cut
    # points over [lo, hi]; group 0 is the tightest-budget tier
    thresholds = cfg.budget_lo + (cfg.budget_hi - cfg.budget_lo) * (
        np.arange(1, cfg.n_groups) / cfg.n_groups
    )
    groups = np.searchsorted(thresholds, budgets).astype(np.int16)
    strata = tuple(
        np.nonzero(groups == g)[0].astype(np.int64)
        for g in range(cfg.n_groups)
    )
    return Population(cfg, groups, budgets, weights,
                      thresholds.astype(np.float32), strata)


def group_train_need_mb(
    model_cfg: C.CNNConfig,
    n_groups: int,
    *,
    t: int = 0,
    batch: int = MM.PAPER_BATCH,
) -> np.ndarray:
    """Per-group device-side training footprint: group ``g`` trains the
    progressive sub-model at step ``t`` and HeteroFL width ratio
    ``2^-(n_groups-1-g)`` (group 0 = narrowest), evaluated by
    ``memory_model.submodel_train_memory_mb`` — the admission gate's
    device-side threshold vector."""
    return np.asarray([
        MM.submodel_train_memory_mb(
            model_cfg, t, batch=batch, ratio=2.0 ** -(n_groups - 1 - g)
        )
        for g in range(n_groups)
    ], np.float64)


def _quotas(shares: np.ndarray, cohort_size: int) -> np.ndarray:
    """Largest-remainder proportional quotas summing exactly to
    ``cohort_size`` (deterministic tie-break by stratum index)."""
    raw = shares / shares.sum() * cohort_size
    q = np.floor(raw).astype(np.int64)
    rem = cohort_size - int(q.sum())
    if rem > 0:
        order = np.lexsort((np.arange(len(raw)), -(raw - q)))
        q[order[:rem]] += 1
    return q


def sample_cohort(
    pop: Population,
    round_idx: int,
    *,
    cohort_size: int,
    need_mb: Sequence[float],
    seed: Optional[int] = None,
    server_peak_budget_bytes: Optional[int] = None,
    n_cols: Optional[int] = None,
    agg: str = "replicated",
    n_devices: int = 1,
    oversample: int = 4,
) -> Cohort:
    """Draw one round's cohort — a PURE function of ``(seed, round_idx)``
    (default seed: ``pop.cfg.seed``); nothing else mutates, so replaying a
    round re-derives the identical admission decisions.

    Sampling: per-stratum quotas proportional to stratum population
    (largest remainder), then weighted-without-replacement draw order
    within each stratum (Gumbel top-k over ``log w``), oversampled
    ``oversample×`` so budget rejections can backfill.  Admission walks
    the draw order: a candidate needs ``budget ≥ need_mb[group]``
    (:func:`group_train_need_mb` builds that vector from the memory
    model); with ``server_peak_budget_bytes`` set, candidates (interleaved
    round-robin across strata) are then cut off once
    ``memory_model.server_aggregation_peak_bytes(k+1, n_cols, G, ...)``
    would exceed the server budget — the two sides of the memory wall as
    one admission filter.  Raising a client's budget can only help that
    client (admission is monotone in budget; pinned by a hypothesis
    property)."""
    if cohort_size < 1:
        raise ValueError("cohort_size must be >= 1")
    need = np.asarray(need_mb, np.float64)
    if need.shape != (pop.cfg.n_groups,):
        raise ValueError(
            f"need_mb must have one entry per group "
            f"({pop.cfg.n_groups}), got shape {need.shape}"
        )
    if server_peak_budget_bytes is not None and n_cols is None:
        raise ValueError("server admission needs n_cols (the round's "
                         "packed column count)")
    seed = pop.cfg.seed if seed is None else seed
    rng = np.random.default_rng((seed, round_idx))
    shares = np.asarray([len(s) for s in pop.strata], np.float64)
    quotas = _quotas(np.maximum(shares, 1e-9), cohort_size)
    considered = rejected_budget = rejected_server = 0
    admitted: list = []  # per-stratum admitted id lists
    for g, ids in enumerate(pop.strata):
        # one gumbel draw per stratum member EVERY round regardless of the
        # quota, so the draw order of stratum g is independent of the
        # other knobs (budget edits never reshuffle the order)
        gum = rng.gumbel(size=len(ids))
        adm_g: list = []
        if len(ids) == 0 or quotas[g] == 0:
            admitted.append(adm_g)
            continue
        m = min(len(ids), int(quotas[g]) * oversample)
        keys = np.log(pop.weights[ids]) + gum
        top = np.argpartition(-keys, m - 1)[:m]
        order = top[np.argsort(-keys[top], kind="stable")]
        for c in ids[order]:
            if len(adm_g) >= quotas[g]:
                break
            considered += 1
            if pop.budgets_mb[c] < need[g]:
                rejected_budget += 1
                continue
            adm_g.append(int(c))
        admitted.append(adm_g)
    # server-side gate: interleave strata round-robin (the truncation hits
    # every tier evenly) and stop admitting once the NEXT client would push
    # the modeled flat-round server peak past the budget
    final_ids: list = []
    final_groups: list = []
    depth = max((len(a) for a in admitted), default=0)
    for pos in range(depth):
        for g, adm_g in enumerate(admitted):
            if pos >= len(adm_g):
                continue
            c = adm_g[pos]
            if server_peak_budget_bytes is not None:
                peak = MM.server_aggregation_peak_bytes(
                    len(final_ids) + 1, int(n_cols), pop.cfg.n_groups,
                    n_devices=n_devices, agg=agg,
                )
                if peak > server_peak_budget_bytes:
                    rejected_server += 1
                    continue
            final_ids.append(c)
            final_groups.append(g)
    ids = np.asarray(final_ids, np.int64)
    return Cohort(
        round_idx=int(round_idx),
        ids=ids,
        groups=np.asarray(final_groups, np.int16),
        weights=pop.weights[ids] if ids.size else np.zeros(0, np.float32),
        considered=considered,
        rejected_budget=rejected_budget,
        rejected_server=rejected_server,
    )


class CohortSampler:
    """Resumable sampler: a cursor over :func:`sample_cohort` rounds.

    The cursor is deliberately tiny — the next round index — because each
    round is a pure function of ``(seed, round)``: checkpointing the
    cursor checkpoints the whole sampling stream.  ``state_to_tree`` /
    ``state_from_tree`` speak ``train/checkpoint.py``'s flat string-keyed
    array trees (tests pin the save→load→continue round-trip equal to
    never having stopped)."""

    def __init__(self, pop: Population, *, cohort_size: int,
                 need_mb: Sequence[float], seed: Optional[int] = None,
                 server_peak_budget_bytes: Optional[int] = None,
                 n_cols: Optional[int] = None, agg: str = "replicated",
                 n_devices: int = 1, oversample: int = 4):
        self.pop = pop
        self.kw = dict(
            cohort_size=cohort_size, need_mb=np.asarray(need_mb, np.float64),
            seed=seed, server_peak_budget_bytes=server_peak_budget_bytes,
            n_cols=n_cols, agg=agg, n_devices=n_devices,
            oversample=oversample,
        )
        self.round = 0

    def next_cohort(self) -> Cohort:
        c = sample_cohort(self.pop, self.round, **self.kw)
        self.round += 1
        return c

    def state_to_tree(self) -> dict:
        return {"round": np.asarray([self.round], np.int64)}

    def state_from_tree(self, tree: dict) -> None:
        self.round = int(np.asarray(tree["round"]).reshape(-1)[0])
