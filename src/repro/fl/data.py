"""Federated data: synthetic image-classification sets + the paper's
partitioners (IID and Dirichlet non-IID, α=1).

No dataset downloads exist in this container (DESIGN.md §6), so we generate
a structured task: each class has a smooth random prototype image; samples
are prototype + per-sample smooth deformation + pixel noise.  The task is
learnable but non-trivial (Bayes error > 0 at the default noise), and the
accuracy *ordering* between FL methods is the reproduced signal.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _smooth(rng, shape, passes=2):
    x = jax.random.normal(rng, shape)
    k = jnp.ones((3, 3, 1, 1)) / 9.0
    for _ in range(passes):
        x = jax.lax.conv_general_dilated(
            x.transpose(0, 1, 2, 3), jnp.tile(k, (1, 1, 1, shape[-1])),
            (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=shape[-1],
        )
    return x


def make_synthetic(
    rng,
    *,
    n_classes: int = 10,
    n_train: int = 4000,
    n_test: int = 1000,
    size: int = 16,
    noise: float = 0.6,
):
    """Returns (x_train, y_train, x_test, y_test) as numpy arrays (NHWC)."""
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    protos = _smooth(k1, (n_classes, size, size, 3), passes=3) * 2.0

    def gen(k, n):
        ky, kd, kn = jax.random.split(k, 3)
        y = jax.random.randint(ky, (n,), 0, n_classes)
        deform = _smooth(kd, (n, size, size, 3), passes=1) * noise
        pix = jax.random.normal(kn, (n, size, size, 3)) * (noise * 0.5)
        x = protos[y] + deform + pix
        return np.asarray(x), np.asarray(y)

    xtr, ytr = gen(k2, n_train)
    xte, yte = gen(k3, n_test)
    return xtr, ytr, xte, yte


def partition_iid(rng, n_samples: int, n_clients: int) -> List[np.ndarray]:
    perm = np.asarray(jax.random.permutation(rng, n_samples))
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def partition_dirichlet(
    rng, labels: np.ndarray, n_clients: int, alpha: float = 1.0,
    min_per_client: int = 8,
) -> List[np.ndarray]:
    """Dirichlet(α) label-skew partition (paper: [37], α=1)."""
    rng = np.random.default_rng(int(jax.random.randint(rng, (), 0, 2**31 - 1)))
    n_classes = int(labels.max()) + 1
    while True:
        idx_per_client: List[list] = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx_c = np.where(labels == c)[0]
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for cid, part in enumerate(np.split(idx_c, cuts)):
                idx_per_client[cid].extend(part.tolist())
        sizes = [len(ix) for ix in idx_per_client]
        if min(sizes) >= min_per_client:
            return [np.sort(np.asarray(ix)) for ix in idx_per_client]


def client_batch(
    x: np.ndarray, y: np.ndarray, idx: np.ndarray, n_fixed: int, rng
) -> Tuple[np.ndarray, np.ndarray]:
    """Fixed-size local dataset view (resampled with replacement when a
    client holds fewer than ``n_fixed`` samples) so client training vmaps."""
    if len(idx) >= n_fixed:
        sel = rng.choice(idx, n_fixed, replace=False)
    else:
        sel = rng.choice(idx, n_fixed, replace=True)
    return x[sel], y[sel]
