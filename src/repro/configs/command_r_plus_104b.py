"""command-r-plus-104b [dense] — Cohere Command-R family.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000; GQA, no-bias,
parallel attention/FFN residual block, tied embeddings, LayerNorm.
[hf:CohereForAI/c4ai-command-r-v01]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="command-r-plus-104b",
        family="dense",
        source="hf:CohereForAI/c4ai-command-r-v01",
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=33792,
        vocab=256_000,
        parallel_block=True,
        norm="layernorm",
        norm_eps=1e-5,
        act="swiglu",
        rope_theta=75_000.0,
        tie_embeddings=True,
        n_prog_blocks=4,
        param_dtype="bfloat16",
    )
)
