"""qwen1.5-0.5b [dense] — Qwen1.5-0.5B.

24L d_model=1024 16H (MHA, kv=16) d_ff=2816 vocab=151936; QKV bias, tied
embeddings. [hf:Qwen/Qwen1.5-0.5B]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen1.5-0.5b",
        family="dense",
        source="hf:Qwen/Qwen1.5-0.5B",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab=151_936,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        n_prog_blocks=4,
        param_dtype="bfloat16",
        train_layout="fsdp",
    )
)
