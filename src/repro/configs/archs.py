"""Imports every architecture config module, registering them all."""
from repro.configs import (  # noqa: F401
    command_r_plus_104b,
    jamba_1_5_large_398b,
    llama4_maverick_400b_a17b,
    phi3_medium_14b,
    phi_3_vision_4_2b,
    qwen1_5_0_5b,
    qwen2_moe_a2_7b,
    qwen3_8b,
    rwkv6_7b,
    whisper_small,
)
