"""qwen2-moe-a2.7b [moe] — Qwen1.5-MoE-A2.7B.

24L d_model=2048 16H (GQA kv=16, i.e. MHA) d_expert=1408 vocab=151936;
60 routed experts top-4 + 4 shared experts, QKV bias.
[hf:Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.configs.base import ArchConfig, LayerSpec, MoECfg, register

CONFIG = register(
    ArchConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=151_936,
        pattern=(LayerSpec("attn", "moe"),),
        moe=MoECfg(n_experts=60, top_k=4, d_expert=1408, n_shared=4),
        qkv_bias=True,
        rope_theta=1_000_000.0,
        n_prog_blocks=4,
        param_dtype="bfloat16",
        train_layout="fsdp",
    )
)
