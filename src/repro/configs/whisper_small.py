"""whisper-small [audio] — OpenAI Whisper small.

Enc-dec, 12L each tower, d_model=768 12H (MHA) d_ff=3072 vocab=51865;
LayerNorm + GELU, attention biases, learned positional embeddings on the
decoder.  The mel-spectrogram + conv frontend is a STUB per the assignment:
``input_specs`` feeds precomputed frame embeddings [B, 1500, 768].
[arXiv:2212.04356]

long_500k is SKIPPED for this arch (see DESIGN.md §Arch-applicability): the
decoder is cross-attention-bound to a 1500-frame encoder and a 524k-token
transcript has no semantic analogue.
"""
from repro.configs.base import ArchConfig, EncoderCfg, LayerSpec, register

CONFIG = register(
    ArchConfig(
        name="whisper-small",
        family="audio",
        source="arXiv:2212.04356",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab=51_865,
        pattern=(LayerSpec("attn", "dense"),),
        encoder=EncoderCfg(n_layers=12, n_frames=1500),
        norm="layernorm",
        norm_eps=1e-5,
        act="gelu",
        qkv_bias=True,
        attn_bias=True,
        mlp_bias=True,
        use_rope=False,
        learned_pos=32_768,  # sized for the decode_32k shape
        tie_embeddings=True,
        n_prog_blocks=3,
        param_dtype="bfloat16",
        train_layout="fsdp",
    )
)
