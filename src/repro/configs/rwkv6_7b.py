"""rwkv6-7b [ssm] — RWKV-6 "Finch" 7B.

32L d_model=4096 (attention-free, 64 heads of 64) d_ff=14336 vocab=65536;
data-dependent per-channel decay, token-shift, channel-mix FFN, per-head
groupnorm.  O(1)-in-seq decode state makes this the native long_500k arch.
[arXiv:2404.05892]
"""
from repro.configs.base import ArchConfig, LayerSpec, RWKVCfg, register

CONFIG = register(
    ArchConfig(
        name="rwkv6-7b",
        family="ssm",
        source="arXiv:2404.05892",
        n_layers=32,
        d_model=4096,
        n_heads=64,  # d_model / rwkv head_dim
        n_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab=65_536,
        pattern=(LayerSpec("rwkv", "rwkv_cm"),),
        rwkv=RWKVCfg(head_dim=64, decay_lora=64),
        norm="layernorm",
        norm_eps=1e-5,
        use_rope=False,
        n_prog_blocks=4,
        param_dtype="bfloat16",
        train_layout="fsdp",
    )
)
