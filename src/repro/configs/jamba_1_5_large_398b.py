"""jamba-1.5-large-398b [hybrid] — AI21 Jamba 1.5 Large.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536; Mamba:attention 1:7
interleave, MoE 16 experts top-2 every other layer; no explicit positional
encoding (the Mamba layers carry position). [arXiv:2403.19887]

Group pattern (8 layers, 9 groups): attention leads the group, followed by 7
Mamba layers; MoE FFN on every other layer.
"""
from repro.configs.base import ArchConfig, LayerSpec, MoECfg, SSMCfg, register

_P = (
    LayerSpec("attn", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
)

CONFIG = register(
    ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        source="arXiv:2403.19887",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab=65_536,
        pattern=_P,
        moe=MoECfg(n_experts=16, top_k=2, d_expert=24576),
        ssm=SSMCfg(d_state=16, d_conv=4, expand=2),
        use_rope=False,
        n_prog_blocks=3,  # 9 groups -> 3 blocks of 3 groups (24 layers each)
        param_dtype="bfloat16",
    )
)
