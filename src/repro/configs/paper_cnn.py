"""The paper's own models (ResNet18/34, VGG11_bn/16_bn) as CNNConfig
instances, plus the reduced variants used by the CPU-scale faithful
reproduction experiments."""
from repro.models.cnn import CNNConfig

RESNET18 = CNNConfig("resnet18", n_classes=10, width_mult=1.0, in_size=32)
RESNET34 = CNNConfig("resnet34", n_classes=10, width_mult=1.0, in_size=32)
VGG11_BN = CNNConfig("vgg11", n_classes=10, width_mult=1.0, in_size=32)
VGG16_BN = CNNConfig("vgg16", n_classes=10, width_mult=1.0, in_size=32)

# CPU-scale variants for the FL simulation benchmarks (same family/partition,
# reduced width + image size so hundreds of FedAvg rounds run on CPU)
RESNET18_SMALL = CNNConfig("resnet18", n_classes=10, width_mult=0.25, in_size=16)
RESNET34_SMALL = CNNConfig("resnet34", n_classes=10, width_mult=0.25, in_size=16)
VGG11_SMALL = CNNConfig("vgg11", n_classes=10, width_mult=0.25, in_size=16)
VGG16_SMALL = CNNConfig("vgg16", n_classes=10, width_mult=0.25, in_size=16)

PAPER_CNNS = {
    "resnet18": RESNET18,
    "resnet34": RESNET34,
    "vgg11_bn": VGG11_BN,
    "vgg16_bn": VGG16_BN,
}
