"""phi3-medium-14b [dense] — Phi-3-medium.

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352; RoPE + SwiGLU +
GQA. [arXiv:2404.14219]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="phi3-medium-14b",
        family="dense",
        source="arXiv:2404.14219",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=10,
        d_ff=17920,
        vocab=100_352,
        rope_theta=10_000.0,
        n_prog_blocks=4,
        param_dtype="bfloat16",
        train_layout="fsdp",
    )
)
