"""phi-3-vision-4.2b [vlm] — Phi-3-vision (128k instruct).

32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064; phi3-mini backbone +
CLIP ViT-L/14 vision encoder.  The vision tower is a STUB per the
assignment: ``input_specs`` feeds precomputed patch embeddings
[B, 576, 1024]; the learned projector (1024 -> d_model) is part of this
model and is trained with ProFL block 1.
[hf:microsoft/Phi-3-vision-128k-instruct]
"""
from repro.configs.base import ArchConfig, FrontendCfg, register

CONFIG = register(
    ArchConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        source="hf:microsoft/Phi-3-vision-128k-instruct",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32_064,
        frontend=FrontendCfg(kind="vision", n_tokens=576, embed_dim=1024),
        rope_theta=10_000.0,
        n_prog_blocks=4,
        param_dtype="bfloat16",
        train_layout="fsdp",
    )
)
