"""llama4-maverick-400b-a17b [moe] — Llama 4 Maverick.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048; MoE 128 experts
top-1 interleaved every other layer (dense/MoE alternation, Maverick-style)
with 1 shared expert; early-fusion multimodal (vision frontend stubbed per
the assignment — this config is the language backbone).
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import ArchConfig, LayerSpec, MoECfg, register

CONFIG = register(
    ArchConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab=202_048,
        pattern=(LayerSpec("attn", "dense"), LayerSpec("attn", "moe")),
        moe=MoECfg(n_experts=128, top_k=1, d_expert=8192, n_shared=1),
        rope_theta=500_000.0,
        n_prog_blocks=4,
        param_dtype="bfloat16",
        # early fusion: the image-patch prepend path is exercised via the
        # phi-3-vision config; this entry lowers the language backbone with
        # the assigned text shapes (assignment: frontend is a stub).
    )
)
