"""Config system: architecture configs, layer patterns, input shapes.

Every assigned architecture is a ``ArchConfig`` built from a repeating
``group pattern`` of :class:`LayerSpec`s.  The decoder stack is ``lax.scan``
over ``n_groups`` repetitions of the pattern, so the HLO is O(len(pattern))
in depth, not O(n_layers).

Block partitioning for ProFL (the paper's technique) is expressed at group
granularity: ``block_boundaries`` lists the group index where each block
starts; block ``t`` covers groups ``[b[t], b[t+1])``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer / sub-config dataclasses
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoECfg:
    """Mixture-of-experts FFN configuration (sort-based dropping router)."""

    n_experts: int
    top_k: int
    d_expert: int  # per-expert hidden dim
    n_shared: int = 0  # always-on shared experts (qwen2-moe style)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0

    def reduced(self) -> "MoECfg":
        return dataclasses.replace(
            self,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            d_expert=min(self.d_expert, 256),
            n_shared=min(self.n_shared, 1),
        )


@dataclass(frozen=True)
class SSMCfg:
    """Mamba-style selective SSM dims (used by the jamba hybrid)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> d_model // 16
    chunk: int = 256  # time chunk for the chunked selective scan


@dataclass(frozen=True)
class RWKVCfg:
    """RWKV6 (Finch) token-mixing dims."""

    head_dim: int = 64
    decay_lora: int = 64  # low-rank dim of the data-dependent decay MLP


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating group pattern."""

    mixer: str  # 'attn' | 'mamba' | 'rwkv'
    ffn: str  # 'dense' | 'moe' | 'rwkv_cm' | 'none'


@dataclass(frozen=True)
class EncoderCfg:
    """Encoder tower for enc-dec models (whisper). Frontend is a stub that
    feeds precomputed frame embeddings of shape [B, n_frames, d_model]."""

    n_layers: int
    n_frames: int  # e.g. 1500 for whisper-small (30 s @ 50 Hz post-conv)


@dataclass(frozen=True)
class FrontendCfg:
    """Stubbed modality frontend: precomputed embeddings + learned projector."""

    kind: str  # 'vision' | 'audio'
    n_tokens: int  # patches / frames prepended to the text sequence
    embed_dim: int  # raw embedding dim coming out of the (stubbed) encoder


# ---------------------------------------------------------------------------
# ArchConfig
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    source: str  # citation (hf model card / arXiv)

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    pattern: Tuple[LayerSpec, ...] = (LayerSpec("attn", "dense"),)

    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    use_rope: bool = True
    sliding_window: int = 0  # 0 = full attention (config-selectable variant)
    parallel_block: bool = False  # cohere-style parallel attn+ffn residual
    logit_soft_cap: float = 0.0

    # norms / activations
    norm: str = "rmsnorm"  # 'rmsnorm' | 'layernorm'
    norm_eps: float = 1e-6
    act: str = "swiglu"  # 'swiglu' | 'gelu'
    attn_bias: bool = False  # bias on attention out proj (whisper)
    mlp_bias: bool = False

    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    rwkv: Optional[RWKVCfg] = None
    encoder: Optional[EncoderCfg] = None
    frontend: Optional[FrontendCfg] = None

    learned_pos: int = 0  # >0: learned positional embedding table (whisper)
    long_decode_window: int = 8192  # sliding window used for long_500k decode
    #   on archs whose native attention is full (see DESIGN.md)

    # ProFL block partition (group granularity; see blocks.py)
    n_prog_blocks: int = 4

    # precision
    param_dtype: str = "float32"

    # preferred TRAINING layout on the production mesh: '2d' (FSDP×TP,
    # required at >=100B for memory) or 'fsdp' (model axis joins data
    # parallelism — roofline-driven choice for small/mid models whose
    # per-layer compute cannot amortize TP collectives; EXPERIMENTS §Perf i9).
    # Serving shapes always use '2d' (TP is the latency layout).
    train_layout: str = "2d"

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern length {len(self.pattern)}"
            )

    # ------------------------------------------------------------------
    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self, d_model: int = 256, vocab: int = 512) -> "ArchConfig":
        """Smoke-test variant of the same family: <=2 pattern repeats,
        d_model<=512, <=4 experts, small vocab."""
        n_groups = min(self.n_groups, 2 if len(self.pattern) <= 4 else 1)
        d_model = min(d_model, self.d_model)
        n_heads = max(2, min(4, self.n_heads))
        n_kv = 1 if self.n_kv_heads < self.n_heads else n_heads
        head_dim = max(8, d_model // n_heads)
        kw = dict(
            name=self.name + "-smoke",
            n_layers=n_groups * len(self.pattern),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 2 * d_model),
            vocab=min(self.vocab, vocab),
            n_prog_blocks=min(self.n_prog_blocks, max(1, n_groups)),
            param_dtype="float32",
        )
        if self.moe is not None:
            kw["moe"] = self.moe.reduced()
        if self.rwkv is not None:
            kw["rwkv"] = RWKVCfg(head_dim=max(8, d_model // n_heads))
            kw["n_heads"] = d_model // max(8, d_model // n_heads)
            kw["n_kv_heads"] = kw["n_heads"]
            kw["head_dim"] = max(8, d_model // n_heads)
        if self.encoder is not None:
            kw["encoder"] = EncoderCfg(n_layers=2, n_frames=16)
        if self.frontend is not None:
            kw["frontend"] = dataclasses.replace(
                self.frontend, n_tokens=4, embed_dim=min(self.frontend.embed_dim, 64)
            )
        return self.with_(**kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Sequence[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # importing the modules registers their configs
    from repro.configs import archs  # noqa: F401
