"""qwen3-8b [dense] — Qwen3-8B.

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936; qk_norm (RMSNorm on
per-head q/k), head_dim=128, no QKV bias. [hf:Qwen/Qwen3-8B]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen3-8b",
        family="dense",
        source="hf:Qwen/Qwen3-8B",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12288,
        vocab=151_936,
        head_dim=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
        n_prog_blocks=4,
        param_dtype="bfloat16",
        train_layout="fsdp",
    )
)
