import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination with ShapeDtypeStruct stand-ins — no allocation.

For each combo this prints/records:
  * compiled.memory_analysis()  — proves the step fits per-device HBM;
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline;
  * collective byte counts parsed from the optimized HLO text.

Shapes → lowered step:
  train_4k     -> full-model train_step (baseline) and, with
                  --progressive T, the ProFL step-t train step (the paper's
                  memory claim, §Dry-run comparison);
  prefill_32k  -> prefill (flash attention + cache emission);
  decode_32k   -> serve_step: ONE token, KV cache of 32768;
  long_500k    -> serve_step with a 524288-token context: native for
                  rwkv6/jamba, sliding-window (8192) for full-attention
                  archs, SKIP for whisper (DESIGN.md).

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results.json
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import (  # noqa: E402
    INPUT_SHAPES,
    ArchConfig,
    InputShape,
    get_config,
    list_configs,
)
from repro.core import progressive as PROG  # noqa: E402
from repro.launch import sharding  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.train import serve  # noqa: E402
from repro.train.optimizer import AdamWCfg, adamw  # noqa: E402
from repro.train.train_step import init_train_state, make_train_step  # noqa: E402

SKIPS = {("whisper-small", "long_500k"): "enc-dec decoder is bound to a "
         "1500-frame encoder; 524k-token transcripts have no analogue "
         "(DESIGN.md §Arch-applicability)"}


# ===========================================================================
# input specs (ShapeDtypeStruct stand-ins; weak-type-correct, no allocation)
# ===========================================================================


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """Model inputs for one step of the given kind."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    dt = jnp.dtype(cfg.param_dtype)
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sds((B, S), jnp.int32)}
        if cfg.frontend is not None:
            batch["frontend_embeds"] = sds(
                (B, cfg.frontend.n_tokens, cfg.frontend.embed_dim), dt
            )
        if cfg.encoder is not None:
            batch["frames"] = sds((B, cfg.encoder.n_frames, cfg.d_model), dt)
        return batch
    # decode: ONE token + cache of S
    w = decode_window(cfg, shape)
    cache = jax.eval_shape(
        lambda: serve.init_cache(cfg, B, S, window=w)
    )
    return {
        "cache": cache,
        "tokens": sds((B,), jnp.int32),
        "pos": sds((), jnp.int32),
    }


def decode_window(cfg: ArchConfig, shape: InputShape) -> Optional[int]:
    """long_500k uses the sliding-window variant on full-attention archs;
    native (0 = full cache / O(1) state) otherwise."""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        return cfg.long_decode_window
    return None


def batch_shardings(env, batch):
    def spec(path, leaf):
        name = sharding._path_str(path)
        if name == "tokens" and leaf.ndim >= 2:
            return sharding._sanitize(env, P(env.dp_axes, None), leaf.shape)
        if name == "tokens":
            return sharding._sanitize(env, P(env.dp_axes), leaf.shape)
        if name in ("frontend_embeds", "frames"):
            return sharding._sanitize(env, P(env.dp_axes, None, None), leaf.shape)
        return P()

    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(env.mesh, spec(p, x)), batch
    )


def cache_shardings_env(cfg, env, cache):
    def spec(path, leaf):
        name = sharding._path_str(path)
        shape = leaf.shape
        if re.search(r"/(k|v|cross_k|cross_v)$", name) and leaf.ndim == 5:
            # [G, B, Kh, C, hd]: batch over dp (or cache seq when B==1),
            # head_dim over model (always divisible).
            if shape[1] % sharding._axis_size(env, env.dp_axes) == 0:
                return sharding._sanitize(
                    env, P(None, env.dp_axes, None, None, "model"), shape)
            return sharding._sanitize(
                env, P(None, None, None, env.dp_axes, "model"), shape)
        if "mamba/h" in name:
            return sharding._sanitize(env, P(None, env.dp_axes, "model", None), shape)
        if "mamba/conv" in name:
            return sharding._sanitize(env, P(None, env.dp_axes, None, "model"), shape)
        if "rwkv/S" in name:  # [G, B, H, hd, hd]
            if shape[1] % sharding._axis_size(env, env.dp_axes) == 0:
                return sharding._sanitize(
                    env, P(None, env.dp_axes, "model", None, None), shape)
            return sharding._sanitize(
                env, P(None, None, env.dp_axes, "model", None), shape)
        base = [None] * leaf.ndim
        if leaf.ndim >= 2 and shape[1] % sharding._axis_size(env, env.dp_axes) == 0:
            base[1] = env.dp_axes
        return P(*base)

    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(env.mesh, spec(p, x)), cache
    )


# ===========================================================================
# lowering
# ===========================================================================


_DTB = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
        "u8": 1, "f64": 8, "s64": 8, "pred": 1, "f8e4m3fn": 1,
        "f8e5m2": 1, "s16": 2, "u16": 2}
_SHAPE_PAT = re.compile(
    r"(f32|bf16|f16|f64|s8|u8|s16|u16|s32|u32|s64|pred|f8e4m3fn|f8e5m2)"
    r"\[([\d,]*)\]")
_COLL_PAT = re.compile(
    r"=\s*(?:\(([^)]*)\)|((?:f|bf|s|u|pred)[\w]*\[[\d,]*\][^\s]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)


def _split_computations(hlo: str) -> dict:
    """{computation_name: text} from optimized HLO."""
    comps = {}
    cur, buf = None, []
    for line in hlo.splitlines():
        # header: [ENTRY] %name (args...) -> type {   — args may nest parens
        m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
        if m and "->" in line and line.rstrip().endswith("{"):
            if cur:
                comps[cur] = "\n".join(buf)
            cur, buf = m.group(1), [line]
        else:
            buf.append(line)
    if cur:
        comps[cur] = "\n".join(buf)
    return comps


def _while_multipliers(hlo: str) -> dict:
    """{computation_name: effective_repeat_count} for while (lax.scan)
    bodies, with NESTED loops multiplying through their parents.  The trip
    count is recovered from the largest constant in the loop condition (the
    scan pattern).  XLA:CPU cost analysis counts loop bodies ONCE —
    collectives inside the layer scan must be scaled by these."""
    comps = _split_computations(hlo)
    trips, parent = {}, {}
    for cname, ctext in comps.items():
        for m in re.finditer(
            r"while\([^)]*\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)",
            ctext,
        ):
            cond, body = m.group(1), m.group(2)
            consts = [int(c) for c in re.findall(r"constant\((\d+)\)",
                                                 comps.get(cond, ""))]
            if consts:
                trips[body] = max(max(consts), 1)
                parent[body] = cname

    def mult(name, depth=0):
        if depth > 8 or name not in trips:
            return 1
        return trips[name] * mult(parent.get(name, ""), depth + 1)

    return {name: mult(name) for name in comps}


def _collective_bytes(hlo: str) -> dict:
    """Sum output-shape bytes of collective ops in optimized HLO text,
    multiplying ops inside while (scan) bodies by the loop trip count."""
    sizes = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
             "all-to-all": 0, "collective-permute": 0}
    mults = _while_multipliers(hlo)
    comps = _split_computations(hlo)

    for cname, ctext in comps.items():
        k = mults.get(cname, 1)
        for m in _COLL_PAT.finditer(ctext):
            shapes_str = m.group(1) or m.group(2)
            op = m.group(3)
            total = 0
            for sm in _SHAPE_PAT.finditer(shapes_str):
                dt, dims = sm.group(1), sm.group(2)
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                total += n * _DTB.get(dt, 4)
            sizes[op] += total * k
    return sizes


def lower_combo(
    cfg: ArchConfig,
    shape: InputShape,
    mesh,
    *,
    progressive_t: Optional[int] = None,
    layout: str = "2d",
):
    """Lower + compile one (arch, shape, mesh) combo.
    Returns result dict with cost/memory/collective stats."""
    env_ctx = sharding.axis_env(mesh, layout=layout)
    with env_ctx as env:
        params_struct = jax.eval_shape(
            lambda: T.init_model(cfg, jax.random.PRNGKey(0))
        )
        p_sh = sharding.param_shardings(env, params_struct)

        if shape.kind == "train":
            opt = adamw(AdamWCfg())
            if progressive_t is None:
                step_fn = make_train_step(cfg, opt)
                state_struct = jax.eval_shape(
                    lambda: init_train_state(cfg, params_struct, opt)
                )
                state_sh = _state_shardings(env, state_struct)
                batch = input_specs(cfg, shape)
                b_sh = batch_shardings(env, batch)
                jf = jax.jit(step_fn, in_shardings=(state_sh, b_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
                lowered = jf.lower(state_struct, batch)
            else:
                t = progressive_t
                frozen_s, trainable_s = _prog_structs(cfg, params_struct, t)
                step_fn = PROG.make_progressive_train_step(cfg, opt, t)
                state_struct = jax.eval_shape(
                    lambda: {"params": trainable_s,
                             "opt": opt.init(trainable_s),
                             "step": jnp.zeros((), jnp.int32)}
                )
                state_sh = _state_shardings(env, state_struct)
                f_sh = sharding.param_shardings(env, frozen_s)
                batch = input_specs(cfg, shape)
                b_sh = batch_shardings(env, batch)
                jf = jax.jit(step_fn, in_shardings=(state_sh, f_sh, b_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
                lowered = jf.lower(state_struct, frozen_s, batch)
        elif shape.kind == "prefill":
            batch = input_specs(cfg, shape)
            b_sh = batch_shardings(env, batch)

            def prefill_fn(params, batch):
                return serve.prefill(cfg, params, batch)

            jf = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh))
            lowered = jf.lower(params_struct, batch)
        else:  # decode
            spec = input_specs(cfg, shape)
            w = decode_window(cfg, shape)
            c_sh = cache_shardings_env(cfg, env, spec["cache"])
            tok_sh = NamedSharding(env.mesh, sharding._sanitize(
                env, P(env.dp_axes), spec["tokens"].shape))
            pos_sh = NamedSharding(env.mesh, P())

            def decode_fn(params, cache, tokens, pos):
                return serve.decode_step(cfg, params, cache, tokens, pos,
                                         window=w)

            jf = jax.jit(decode_fn,
                         in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                         out_shardings=(None, c_sh),
                         donate_argnums=(1,))
            lowered = jf.lower(params_struct, spec["cache"], spec["tokens"],
                               spec["pos"])

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # jax <= 0.4.x: one dict per device
            cost = cost[0] if cost else {}
        coll = _collective_bytes(compiled.as_text())
        n_dev = mesh.devices.size
        return {
            "arch": cfg.name,
            "shape": shape.name,
            "mesh": "x".join(map(str, mesh.devices.shape)),
            "progressive_t": progressive_t,
            "compile_s": round(compile_s, 1),
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
            "collective_bytes": coll,
            "per_device": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "peak_bytes": (mem.argument_size_in_bytes
                               + mem.temp_size_in_bytes),
            },
            "n_devices": n_dev,
        }


def _prog_structs(cfg, params_struct, t):
    return jax.eval_shape(
        lambda ps: PROG.submodel_init(cfg, ps, jax.random.PRNGKey(1), t),
        params_struct,
    )


def _state_shardings(env, state_struct):
    return {
        "params": sharding.param_shardings(env, state_struct["params"]),
        "opt": sharding.param_shardings(env, state_struct["opt"]),
        "step": NamedSharding(env.mesh, P()),
    }


# ===========================================================================
# CLI
# ===========================================================================


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--progressive", type=int, default=None,
                    help="lower the ProFL step-t train step instead of full")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    archs = list_configs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]

    results = []
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            shape = INPUT_SHAPES[s]
            if (a, s) in SKIPS:
                results.append({"arch": a, "shape": s, "skip": SKIPS[(a, s)]})
                print(f"SKIP  {a} × {s}: {SKIPS[(a, s)]}")
                continue
            try:
                # per-arch roofline-driven training layout; serving stays 2d
                layout = cfg.train_layout if shape.kind == "train" else "2d"
                r = lower_combo(cfg, shape, mesh,
                                progressive_t=args.progressive,
                                layout=layout)
                r["layout"] = layout
                results.append(r)
                pd = r["per_device"]
                print(f"OK    {a} × {s} [{r['mesh']}] "
                      f"flops={r['flops']:.3e} "
                      f"args={pd['argument_bytes']/2**30:.2f}GiB "
                      f"temp={pd['temp_bytes']/2**30:.2f}GiB "
                      f"compile={r['compile_s']}s")
            except Exception as e:
                traceback.print_exc()
                results.append({"arch": a, "shape": s, "error": str(e)[:500]})
                print(f"FAIL  {a} × {s}: {type(e).__name__}: {str(e)[:200]}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_fail = sum(1 for r in results if "error" in r)
    print(f"\n{len(results)} combos, {n_fail} failures")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
