"""Production training launcher: full-model or ProFL-progressive training
of any registered architecture under the production mesh (pjit/GSPMD), with
synthetic data when no corpus is mounted.

On real hardware:
    python -m repro.launch.train --arch qwen3-8b --progressive \
        --batch 256 --seq 4096 --steps-per-block 500
On this CPU container it runs reduced configs single-device (--reduced).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import blocks as B
from repro.core import progressive as P
from repro.launch import sharding
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.train.optimizer import AdamWCfg, adamw
from repro.train.train_step import init_train_state, make_train_step


def synth_batch(cfg, rng, batch, seq):
    out = {"tokens": jax.random.randint(rng, (batch, seq), 0, cfg.vocab)}
    if cfg.frontend is not None:
        out["frontend_embeds"] = jax.random.normal(
            rng, (batch, cfg.frontend.n_tokens, cfg.frontend.embed_dim),
            jnp.dtype(cfg.param_dtype))
    if cfg.encoder is not None:
        out["frames"] = jax.random.normal(
            rng, (batch, cfg.encoder.n_frames, cfg.d_model),
            jnp.dtype(cfg.param_dtype))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--progressive", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--steps-per-block", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    use_mesh = jax.device_count() >= 4
    mesh_ctx = (
        sharding.axis_env(make_production_mesh(multi_pod=args.multi_pod))
        if use_mesh else _null_ctx()
    )

    with mesh_ctx as env:
        rng = jax.random.PRNGKey(0)
        params = T.init_model(cfg, rng)
        if env is not None:
            params = jax.device_put(params, sharding.param_shardings(env, params))
        opt = adamw(AdamWCfg(lr=args.lr))
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"{cfg.name}: {n/1e6:.1f}M params on "
              f"{jax.device_count()} devices")

        schedule = (
            P.schedule(B.n_blocks(cfg), use_shrinking=False)
            if args.progressive else [("full", -1)]
        )
        for stage, t in schedule:
            if stage == "full":
                state = init_train_state(cfg, params, opt)
                step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
                run = lambda st, bt: step(st, bt)
                frozen = None
            else:
                frozen, trainable = P.submodel_init(
                    cfg, params, jax.random.PRNGKey(7 + t), t)
                state = {"params": trainable, "opt": opt.init(trainable),
                         "step": jnp.zeros((), jnp.int32)}
                pstep = jax.jit(P.make_progressive_train_step(cfg, opt, t),
                                donate_argnums=(0,))
                run = lambda st, bt: pstep(st, frozen, bt)
            print(f"--- stage={stage} t={t} ---")
            for i in range(args.steps_per_block):
                bt = synth_batch(cfg, jax.random.fold_in(rng, i), args.batch,
                                 args.seq)
                t0 = time.time()
                state, m = run(state, bt)
                if i % 5 == 0:
                    print(f"  step {i:4d} loss={float(m['loss']):.3f} "
                          f"({time.time()-t0:.2f}s)")
            if stage != "full":
                params = B.merge_block_into(cfg, params,
                                            state["params"]["active"], t)
                params["final_norm"] = state["params"]["op"]["final_norm"]
                if not cfg.tie_embeddings:
                    params["head"] = state["params"]["op"]["head"]
            else:
                params = state["params"]
        print("done.")


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
