"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benches must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (data, model); 2×16×16 = 512 chips when
    multi-pod (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_client_mesh(n_clients: int | None = None):
    """1-D mesh with a ``clients`` axis for the sharded FL cohort engine
    (fl/engine.py): local SGD shards the cohort's client dim across it.

    Uses every local device by default (CPU: set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before first jax
    init to emulate N devices; TPU: the real chips)."""
    n = len(jax.devices())
    if n_clients is not None:
        n = min(n, n_clients)
    return jax.make_mesh((n,), ("clients",))


def make_model_mesh(n_model: int | None = None):
    """1-D mesh with a ``model`` axis for the column-sharded server
    aggregation (fl/engine.py ``agg="sharded"``): ``fedavg_grouped`` runs
    under shard_map with the shared ``[K_total, n]`` panel split into
    tile-aligned column blocks across this axis, so no single device ever
    holds the whole panel.  Uses every local device by default."""
    n = len(jax.devices())
    if n_model is not None:
        n = min(n, n_model)
    return jax.make_mesh((n,), ("model",))


def make_fl_cohort_mesh(n_clients: int | None = None, n_model: int = 1):
    """Composed ``clients × model`` mesh for one heterogeneous round that is
    sharded on BOTH tiers: local SGD splits the cohort's client dim over
    ``clients`` (with per-group sub-meshes along that axis) while the fused
    aggregation column-shards the ``[K_total, n]`` panel over ``model`` —
    fl/engine.py picks the ``model`` axis up automatically when the engine
    mesh carries one.  ``n_clients`` defaults to every local device divided
    by ``n_model``."""
    n = len(jax.devices())
    n_model = max(1, min(n_model, n))
    nc = n // n_model
    if n_clients is not None:
        nc = min(nc, n_clients)
    return jax.make_mesh((max(1, nc), n_model), ("clients", "model"))


def model_stream_sharding(mesh, ndim: int = 3):
    """``NamedSharding`` that splits axis 0 of a ``[D, ...]`` stream buffer
    across ``mesh``'s ``model`` axis (the remaining axes replicated — each
    of the D devices owns exactly its own leading slice).  This is the
    transfer layout of the shard-local group-panel stream (fl/engine.py
    ``agg="sharded"``): the per-shard column selections gathered on a
    group's source device land with this sharding, so no agg device ever
    receives more than its own ``[1, ...]`` slice."""
    from jax.sharding import NamedSharding, PartitionSpec

    return NamedSharding(
        mesh, PartitionSpec("model", *([None] * (ndim - 1)))
    )


def put_model_sharded(x, mesh):
    """Sub-mesh → agg-mesh transfer helper for composed ``clients × model``
    rounds: land ``x`` (committed anywhere — a group's ``clients`` sub-mesh,
    the default device in packed mode) on ``mesh`` with axis 0 split over
    the ``model`` axis.  One async ``device_put``; jax moves each axis-0
    slice straight to its owning device, so the buffer is never replicated
    across the aggregation mesh the way a ``P()`` placement would."""
    return jax.device_put(x, model_stream_sharding(mesh, x.ndim))


def make_fl_production_mesh(*, n_client_shards: int = 16, n_model: int = 16):
    """Production FL mesh: cohort clients sharded across ``clients``,
    per-client training model-parallel across ``model`` (16×16 pod)."""
    return jax.make_mesh((n_client_shards, n_model), ("clients", "model"))


# TPU v5e hardware constants used by the roofline analysis (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
ICI_LINKS = 4  # usable links/chip in the 2D torus (collective bw = 4×50 GB/s)
