"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benches must keep seeing 1 device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (data, model); 2×16×16 = 512 chips when
    multi-pod (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_client_mesh(n_clients: int | None = None):
    """1-D mesh with a ``clients`` axis for the sharded FL cohort engine
    (fl/engine.py): local SGD shards the cohort's client dim across it.

    Uses every local device by default (CPU: set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before first jax
    init to emulate N devices; TPU: the real chips)."""
    n = len(jax.devices())
    if n_clients is not None:
        n = min(n, n_clients)
    return jax.make_mesh((n,), ("clients",))


def make_model_mesh(n_model: int | None = None):
    """1-D mesh with a ``model`` axis for the column-sharded server
    aggregation (fl/engine.py ``agg="sharded"``): ``fedavg_grouped`` runs
    under shard_map with the shared ``[K_total, n]`` panel split into
    tile-aligned column blocks across this axis, so no single device ever
    holds the whole panel.  Uses every local device by default."""
    n = len(jax.devices())
    if n_model is not None:
        n = min(n, n_model)
    return jax.make_mesh((n,), ("model",))


def make_fl_cohort_mesh(n_clients: int | None = None, n_model: int = 1):
    """Composed ``clients × model`` mesh for one heterogeneous round that is
    sharded on BOTH tiers: local SGD splits the cohort's client dim over
    ``clients`` (with per-group sub-meshes along that axis) while the fused
    aggregation column-shards the ``[K_total, n]`` panel over ``model`` —
    fl/engine.py picks the ``model`` axis up automatically when the engine
    mesh carries one.  ``n_clients`` defaults to every local device divided
    by ``n_model``."""
    n = len(jax.devices())
    n_model = max(1, min(n_model, n))
    nc = n // n_model
    if n_clients is not None:
        nc = min(nc, n_clients)
    return jax.make_mesh((max(1, nc), n_model), ("clients", "model"))


def model_stream_sharding(mesh, ndim: int = 3):
    """``NamedSharding`` that splits axis 0 of a ``[D, ...]`` stream buffer
    across ``mesh``'s ``model`` axis (the remaining axes replicated — each
    of the D devices owns exactly its own leading slice).  This is the
    transfer layout of the shard-local group-panel stream (fl/engine.py
    ``agg="sharded"``): the per-shard column selections gathered on a
    group's source device land with this sharding, so no agg device ever
    receives more than its own ``[1, ...]`` slice."""
    from jax.sharding import NamedSharding, PartitionSpec

    return NamedSharding(
        mesh, PartitionSpec("model", *([None] * (ndim - 1)))
    )


def put_model_sharded(x, mesh):
    """Sub-mesh → agg-mesh transfer helper for composed ``clients × model``
    rounds: land ``x`` (committed anywhere — a group's ``clients`` sub-mesh,
    the default device in packed mode) on ``mesh`` with axis 0 split over
    the ``model`` axis.  One async ``device_put``; jax moves each axis-0
    slice straight to its owning device, so the buffer is never replicated
    across the aggregation mesh the way a ``P()`` placement would."""
    return jax.device_put(x, model_stream_sharding(mesh, x.ndim))


@functools.lru_cache(maxsize=64)
def _model_device_grid(mesh):
    """``[R, D]`` device grid of ``mesh`` with the ``model`` axis last:
    column ``d`` lists every device holding model shard ``d`` (R = the
    product of the other axes — shard replicas on a composed
    ``clients × model`` mesh)."""
    ax = mesh.axis_names.index("model")
    d = mesh.shape["model"]
    return np.moveaxis(np.asarray(mesh.devices), ax, -1).reshape(-1, d)


@functools.lru_cache(maxsize=512)
def _zeros_on(shape, dtype, device):
    """Cached jitted zeros-constructor pinned to one device: an empty ragged
    shard is BORN on its destination, zero interconnect bytes."""
    from jax.sharding import SingleDeviceSharding

    return jax.jit(
        lambda: jnp.zeros(shape, dtype), out_shardings=SingleDeviceSharding(device)
    )


@functools.partial(jax.jit, static_argnames=("width",))
def _pad_stream_slice(x, *, width):
    """``[K, w] -> [1, K, width]`` zero-pad, executed ON the slice's own
    (destination) device — the pad columns never cross the interconnect."""
    return jnp.pad(x, ((0, 0), (0, width - x.shape[1])))[None]


def put_model_ragged(sel, widths, mesh):
    """Ragged counterpart of :func:`put_model_sharded` for one stream pass:
    ``sel`` is the source-side uniform ``[D, K, m]`` gather, but shard ``d``
    only has ``widths[d]`` live (tile-aligned) columns this pass — the rest
    is clip-gather pad the destination sentinel drops anyway.  Instead of
    shipping the uniform split (a pad row to EVERY shard, up to D× useful
    bytes for a concentrated DepthFL group), transfer exactly
    ``sel[d, :, :widths[d]]`` to each of shard ``d``'s devices, zero-pad
    back to ``m`` on the destination, and assemble the global ``[D, K, m]``
    axis-0-sharded array via ``jax.make_array_from_single_device_arrays`` —
    identical shape/sharding/values to the uniform transfer (bit-equal
    landing data), ragged WIRE bytes.  A ``widths[d] == 0`` shard receives
    nothing at all (its slice is zeros born on-device).  When every width
    equals ``m`` this degenerates to the single uniform ``device_put``."""
    D, K, m = sel.shape
    if all(int(w) >= m for w in widths):
        return jax.device_put(sel, model_stream_sharding(mesh, 3))
    grid = _model_device_grid(mesh)
    shards = [None] * grid.size
    movers, targets, slots = [], [], []
    for d in range(D):
        w = int(widths[d])
        for r in range(grid.shape[0]):
            i = r * D + d
            if w == 0:
                shards[i] = _zeros_on((1, K, m), jnp.dtype(sel.dtype), grid[r, d])()
            else:
                movers.append(sel[d, :, :w] if w < m else sel[d])
                targets.append(grid[r, d])
                slots.append(i)
    if movers:
        for i, mv in zip(slots, jax.device_put(movers, targets)):
            shards[i] = _pad_stream_slice(mv, width=m)
    return jax.make_array_from_single_device_arrays(
        (D, K, m), model_stream_sharding(mesh, 3), shards
    )


@jax.jit
def _pack_scale_slice(e):
    from repro.kernels import ref as _ref

    if e.shape[0] % 2:
        e = jnp.pad(e, (0, 1))
    return _ref.pack_scale_exponents(e)


@functools.partial(jax.jit, static_argnames=("m",))
def _decode_scale_slice(pk, gbase, *, m):
    from repro.kernels import ref as _ref

    e = _ref.unpack_scale_exponents(pk)
    sc = _ref.decode_scale_exponents(e, gbase)[:m]
    return jnp.pad(sc, (0, m - sc.shape[0]))[None, None]


def put_scales_ragged(egather, gbase, widths, mesh):
    """Scale-row companion of :func:`put_model_ragged` for the int8 stream:
    ``egather`` is the source-side ``[D, m]`` gather of 4-bit per-column
    scale exponents (``kernels/ref.py::quantize_columns``), ``gbase`` the
    group's scalar bf16 base.  Each live slice is PACKED two exponents per
    byte on the source (~0.5 B/column on the wire), shipped with the 2-byte
    base, then unpacked and decoded to bf16 scales on the destination
    device.  Returns the global ``[D, 1, m]`` bf16 axis-0-sharded scale
    slices, ready for the same shard-local scatter as the panel."""
    D, m = egather.shape
    grid = _model_device_grid(mesh)
    shards = [None] * grid.size
    movers, targets, slots = [], [], []
    for d in range(D):
        w = int(widths[d])
        packed = None if w == 0 else _pack_scale_slice(egather[d, :w])
        for r in range(grid.shape[0]):
            i = r * D + d
            if w == 0:
                shards[i] = _zeros_on((1, 1, m), jnp.dtype(jnp.bfloat16), grid[r, d])()
            else:
                movers.extend([packed, gbase])
                targets.extend([grid[r, d]] * 2)
                slots.append(i)
    if movers:
        moved = jax.device_put(movers, targets)
        for j, i in enumerate(slots):
            shards[i] = _decode_scale_slice(moved[2 * j], moved[2 * j + 1], m=m)
    return jax.make_array_from_single_device_arrays(
        (D, 1, m), model_stream_sharding(mesh, 3), shards
    )


def make_fl_production_mesh(*, n_client_shards: int = 16, n_model: int = 16):
    """Production FL mesh: cohort clients sharded across ``clients``,
    per-client training model-parallel across ``model`` (16×16 pod)."""
    return jax.make_mesh((n_client_shards, n_model), ("clients", "model"))


# TPU v5e hardware constants used by the roofline analysis (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
ICI_LINKS = 4  # usable links/chip in the 2D torus (collective bw = 4×50 GB/s)
