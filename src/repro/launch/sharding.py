"""Sharding environment: mesh axes + activation/param partition rules.

The model code calls the ``constrain_*`` helpers at the points where GSPMD
needs guidance (post-projection activations, MoE dispatch buffers).  When no
mesh env is active (CPU smoke tests, single-device examples) they are
identities, so the same model code runs everywhere.

Axis convention
---------------
* ``data`` (+ ``pod`` when multi-pod): batch / FSDP axis.
* ``model``: tensor-parallel axis (attention heads, d_ff, experts, vocab).
"""
from __future__ import annotations

import contextlib
import re
import threading
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_tls = threading.local()


@dataclass(frozen=True)
class AxisEnv:
    mesh: Mesh
    dp_axes: Tuple[str, ...]  # ('pod', 'data') or ('data',) (+'model' in fsdp layout)
    tp_axis: Optional[str]  # 'model' (None in pure-FSDP layout)
    fsdp: bool = True  # shard params over the dp axes too

    @property
    def fsdp_axis(self):
        if not self.fsdp:
            return None
        # pure-FSDP layout: shard params over the whole dp tuple
        return self.dp_axes if self.tp_axis is None else self.dp_axes[-1]


def current_env() -> Optional[AxisEnv]:
    return getattr(_tls, "env", None)


@contextlib.contextmanager
def axis_env(mesh: Mesh, *, fsdp: bool = True, layout: str = "2d"):
    """layout='2d': data×model (FSDP × Megatron-TP).  layout='fsdp': the
    'model' axis joins data parallelism (pure FSDP) — the right call for
    small models whose per-layer compute cannot amortize TP collective
    traffic (EXPERIMENTS.md §Perf i9)."""
    names = mesh.axis_names
    if layout == "fsdp":
        dp = tuple(a for a in ("pod", "data", "model") if a in names)
        env = AxisEnv(mesh=mesh, dp_axes=dp, tp_axis=None, fsdp=fsdp)
    else:
        dp = tuple(a for a in ("pod", "data") if a in names)
        env = AxisEnv(mesh=mesh, dp_axes=dp, tp_axis="model", fsdp=fsdp)
    prev = getattr(_tls, "env", None)
    _tls.env = env
    try:
        # jax >= 0.5 spells this jax.sharding.set_mesh; on 0.4.x the Mesh
        # context manager sets the same global mesh for jit/shard_map
        set_mesh = getattr(jax.sharding, "set_mesh", None)
        with (set_mesh(mesh) if set_mesh is not None else mesh):
            yield env
    finally:
        _tls.env = prev


def _axis_size(env: AxisEnv, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= env.mesh.shape[a]
        return n
    return env.mesh.shape[axis]


def _sanitize(env: AxisEnv, spec: P, shape) -> P:
    """Drop spec axes whose mesh size does not divide the dim (e.g. 40 heads
    or vocab 51865 over a 16-way model axis) — GSPMD propagation fills the
    gap from the (always-divisible) weight-matrix shardings."""
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        n = _axis_size(env, axis)
        out.append(axis if (n > 1 and dim > 0 and dim % n == 0) else None)
    return P(*out)


def _constrain(x, spec: P):
    env = current_env()
    if env is None:
        return x
    return jax.lax.with_sharding_constraint(x, _sanitize(env, spec, x.shape))


# -- activation constraints -------------------------------------------------


def constrain_tokens(x):
    """[B, S, ...] activations: batch over dp axes, rest replicated."""
    env = current_env()
    if env is None:
        return x
    return _constrain(x, P(env.dp_axes, *([None] * (x.ndim - 1))))


def constrain_hidden(x):
    """[B, S, D] residual stream at layer boundaries: batch over dp and the
    SEQUENCE dim over 'model' (Megatron-style sequence parallelism).  The
    scan over layers saves one carry per group — sequence-sharding it cuts
    the dominant stored-activation term by the TP degree; GSPMD inserts the
    per-layer all-gather/reduce-scatter pair.  Falls back to replicated dims
    whenever sizes do not divide (decode S=1, batch 1, ...)."""
    env = current_env()
    if env is None or x.ndim != 3:
        return constrain_tokens(x)
    return _constrain(x, P(env.dp_axes, env.tp_axis, None))


def constrain_heads(x):
    """[B, H, S, hd]: batch over dp, heads over model."""
    env = current_env()
    if env is None:
        return x
    return _constrain(x, P(env.dp_axes, env.tp_axis, None, None))


def constrain_ff(x):
    """[B, S, F] MLP hidden: batch over dp, F over model."""
    env = current_env()
    if env is None:
        return x
    return _constrain(x, P(env.dp_axes, None, env.tp_axis))


def constrain_time_state(x):
    """[B, C, F, ...] recurrent-chunk tensors (mamba a/b/h, rwkv r/k/v/w):
    batch over dp, the channel/head dim (axis 2) over model."""
    env = current_env()
    if env is None:
        return x
    spec = [env.dp_axes, None, env.tp_axis] + [None] * (x.ndim - 3)
    return _constrain(x, P(*spec))


def constrain_expert_buf(x):
    """[E, C, D] MoE dispatch buffer: experts over model."""
    env = current_env()
    if env is None:
        return x
    return _constrain(x, P(env.tp_axis, *([None] * (x.ndim - 1))))


def constrain_vocab_logits(x):
    """[B, S, V]: batch over dp, vocab over model."""
    env = current_env()
    if env is None:
        return x
    return _constrain(x, P(env.dp_axes, None, env.tp_axis))


# ---------------------------------------------------------------------------
# Param partition rules (path-pattern -> PartitionSpec factory)
#
# Leaf paths look like: layers/0/attn/wq, embed/tok, head/w, ...
# All stacked layer params carry a leading [G] dim -> spec gets a leading None.
# ---------------------------------------------------------------------------

# (regex on leaf path, spec builder taking (env, ndim) -> P). Specs are for
# the UNSTACKED trailing dims; a leading None is prepended for stacked leaves.
_RULES = [
    # attention projections
    (r"attn.*/wq$", lambda e: P(e.fsdp_axis, e.tp_axis)),
    (r"attn.*/wk$", lambda e: P(e.fsdp_axis, e.tp_axis)),
    (r"attn.*/wv$", lambda e: P(e.fsdp_axis, e.tp_axis)),
    (r"attn.*/wo$", lambda e: P(e.tp_axis, e.fsdp_axis)),
    (r"attn.*/b[qkv]$", lambda e: P(e.tp_axis)),
    (r"attn.*/bo$", lambda e: P(None)),
    (r"attn.*/[qk]_norm$", lambda e: P(None)),
    # dense mlp
    (r"(mlp|ffn|shared)/w_gate$", lambda e: P(e.fsdp_axis, e.tp_axis)),
    (r"(mlp|ffn|shared)/w_up$", lambda e: P(e.fsdp_axis, e.tp_axis)),
    (r"(mlp|ffn|shared)/w_down$", lambda e: P(e.tp_axis, e.fsdp_axis)),
    (r"(mlp|ffn|shared)/b_up$", lambda e: P(e.tp_axis)),
    (r"(mlp|ffn|shared)/b_down$", lambda e: P(None)),
    # moe: experts over model, inner dims fsdp
    (r"moe/router$", lambda e: P(e.fsdp_axis, None)),
    (r"moe/w_gate$", lambda e: P(e.tp_axis, e.fsdp_axis, None)),
    (r"moe/w_up$", lambda e: P(e.tp_axis, e.fsdp_axis, None)),
    (r"moe/w_down$", lambda e: P(e.tp_axis, None, e.fsdp_axis)),
    # mamba
    (r"mamba/w_in$", lambda e: P(e.fsdp_axis, e.tp_axis)),
    (r"mamba/w_(x|dt2)$", lambda e: P(e.tp_axis, None)),
    (r"mamba/w_out$", lambda e: P(e.tp_axis, e.fsdp_axis)),
    (r"mamba/(a_log|d|conv_w|conv_b|dt_bias)$", lambda e: P(e.tp_axis)),
    # rwkv
    (r"rwkv/w_(r|k|v|g)$", lambda e: P(e.fsdp_axis, e.tp_axis)),
    (r"rwkv/w_o$", lambda e: P(e.tp_axis, e.fsdp_axis)),
    (r"rwkv/(decay_w1|mix_w1)$", lambda e: P(e.fsdp_axis, None)),
    (r"rwkv/(decay_w2|mix_w2)$", lambda e: P(None)),
    (r"rwkv/(u|decay_base|ln_scale|ln_bias)$", lambda e: P(e.tp_axis)),
    (r"rwkv_cm/w_k$", lambda e: P(e.fsdp_axis, e.tp_axis)),
    (r"rwkv_cm/w_v$", lambda e: P(e.tp_axis, e.fsdp_axis)),
    (r"rwkv_cm/w_r$", lambda e: P(e.fsdp_axis, None)),
    # embeddings / head: vocab over model, d over fsdp
    (r"embed/tok$", lambda e: P(e.tp_axis, e.fsdp_axis)),
    (r"head/w$", lambda e: P(e.fsdp_axis, e.tp_axis)),
    (r"projector/w$", lambda e: P(e.fsdp_axis, None)),
    (r"projector/b$", lambda e: P(None)),
    # norms & everything small: replicated
    (r".*", lambda e: P()),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for_path(env: AxisEnv, path, leaf) -> P:
    ps = _path_str(path)
    for pat, fn in _RULES:
        if re.search(pat, ps):
            spec = fn(env)
            # stacked layer params have one more leading dim than the rule
            ndim = getattr(leaf, "ndim", 0)
            if len(spec) < ndim:
                spec = P(*([None] * (ndim - len(spec)) + list(spec)))
            elif len(spec) > ndim:  # scalar-ish leaves
                spec = P(*([s for s in spec][: ndim]))
            return _sanitize(env, spec, getattr(leaf, "shape", ()))
    return P()


def param_shardings(env: AxisEnv, params):
    """Pytree of NamedSharding matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(env.mesh, spec_for_path(env, path, leaf)),
        params,
    )


def param_specs(env: AxisEnv, params):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_path(env, path, leaf), params
    )
