"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: kernel tests sweep shapes/dtypes and
``assert_allclose`` against these functions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Attention (naive, materializes the [S, S] logits)
# ---------------------------------------------------------------------------


def attention(
    q: jax.Array,  # [B, H, Sq, hd]
    k: jax.Array,  # [B, K, Skv, hd]
    v: jax.Array,  # [B, K, Skv, hd]
    *,
    causal: bool = True,
    window: int = 0,  # 0 = full; else sliding window (positions > row-window)
    q_offset: int = 0,  # global position of q row 0 (decode: pos of the token)
) -> jax.Array:
    """Reference GQA attention. Returns [B, H, Sq, hd]."""
    B, H, Sq, hd = q.shape
    Kh = k.shape[1]
    g = H // Kh
    qr = q.reshape(B, Kh, g, Sq, hd)
    logits = jnp.einsum(
        "bkgqd,bksd->bkgqs", qr.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(hd))
    rows = jnp.arange(Sq)[:, None] + q_offset
    cols = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), dtype=bool)
    if causal:
        mask &= rows >= cols
    if window > 0:
        mask &= cols > rows - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Effective movement (paper §3.3) — fused accumulation pass
# ---------------------------------------------------------------------------


def effective_movement_update(
    p_new: jax.Array,  # [n] current scalars of a block (flattened)
    p_old: jax.Array,  # [n] scalars at the previous evaluation
    net: jax.Array,  # [n] running net movement  Σ_h U_{k-h}
):
    """One evaluation-step update of the EM accumulators.

    Returns (net_new, path_increment, net_abs_sum):
      net_new   = net + (p_new - p_old)
      path_inc  = Σ_s |p_new - p_old|            (adds to the path-length denom)
      net_abs   = Σ_s |net_new|                  (numerator  D^H_{B,k})
    """
    u = p_new.astype(jnp.float32) - p_old.astype(jnp.float32)
    net_new = net.astype(jnp.float32) + u
    path_inc = jnp.sum(jnp.abs(u))
    net_abs = jnp.sum(jnp.abs(net_new))
    return net_new, path_inc, net_abs


# ---------------------------------------------------------------------------
# Weighted FedAvg aggregation (paper Eq. 1)
# ---------------------------------------------------------------------------


def fedavg(params: jax.Array, weights: jax.Array) -> jax.Array:
    """params: [K, n] stacked client vectors; weights: [K] (sum to 1).
    Returns [n] = Σ_k w_k · params_k, accumulated in f32."""
    out = jnp.einsum(
        "k,kn->n", weights.astype(jnp.float32), params.astype(jnp.float32)
    )
    return out.astype(params.dtype)


def fedavg_masked(
    params: jax.Array,  # [K, n] stacked client vectors (panel)
    weights: jax.Array,  # [K] raw (NOT normalized) aggregation weights
    mask: jax.Array,  # [K, n] per-column membership (1 = client trains col)
    prev: jax.Array | None = None,  # [n] passthrough where nobody covers a col
) -> jax.Array:
    """Per-column masked weighted average (heterogeneous cohorts):

        out[j] = Σ_k w_k·m_kj·p_kj / Σ_k w_k·m_kj      if the denom > 0
        out[j] = prev[j] (or 0 if prev is None)         otherwise

    The per-column denominator makes HeteroFL's num/den masking and DepthFL's
    per-block averaging plain kernel math; weights need no normalization
    because it cancels in the ratio.  Accumulated in f32."""
    w = weights.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    num = jnp.einsum("k,kn->n", w, m * params.astype(jnp.float32))
    den = jnp.einsum("k,kn->n", w, m)
    base = jnp.zeros_like(num) if prev is None else prev.astype(jnp.float32)
    out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), base)
    return out.astype(params.dtype)


# jitted (static out_dtype): the armed quarantine variant adds half a dozen
# elementwise ops — run op-by-op they each pay a full CPU dispatch, which
# alone blows the bench's x1.15 faulted-round gate; under jit they fuse into
# the einsum pass and the armed call stays one dispatch like the clean one
@functools.partial(jax.jit, static_argnames=("out_dtype",))
def fedavg_grouped(
    params: jax.Array,  # [K, n] stacked client vectors, zero outside groups
    weights: jax.Array,  # [K] raw (NOT normalized) aggregation weights
    gmask: jax.Array,  # [G, n] per-GROUP column membership
    wsum: jax.Array,  # [G] per-group weight sums
    prev: jax.Array | None = None,  # [n] passthrough where nobody covers a col
    *,
    out_dtype=None,  # result dtype; None = params.dtype (wire dtype ≠ result)
    bound=None,  # quarantine gate: finite check + |p| > bound zeroes weight
    side=None,  # (snum, sden) [n] associative merge inputs (stale panels)
) -> jax.Array:
    """Group-compressed ``fedavg_masked``: membership is identical within a
    structure group, so the per-client ``[K, n]`` mask collapses to a
    ``[G, n]`` group mask and the per-column denominator to
    ``Σ_g wsum_g·gmask_gj``.  The numerator needs NO mask because the panel
    is zero outside each group's columns (the engine's scatter invariant):

        out[j] = Σ_k w_k·p_kj / Σ_g wsum_g·gmask_gj    if the denom > 0
        out[j] = prev[j] (or 0 if prev is None)        otherwise

    Accumulated in f32; equals ``fedavg_masked`` with the expanded per-client
    mask up to f32 reduction order.  ``out_dtype`` decouples the result dtype
    from the panel's: a bf16-streamed panel (stream_dtype="bf16") still
    aggregates to an f32 server vector.

    ``bound`` (ISSUE 8) arms the ON-DEVICE QUARANTINE GATE: any entry that
    is non-finite or exceeds ``bound`` in magnitude is treated as if its
    client had not covered that column — the entry contributes 0 to the
    numerator and its weight is SUBTRACTED from the denominator, so the
    surviving clients renormalize exactly as if the bad client's weight were
    zero.  With ``bound=inf`` and an all-finite panel the gate degenerates
    bitwise (all-false mask, ``den - 0.0``).  ``side`` adds associative
    ``(num, den)`` pairs — the staleness-discounted straggler merge and the
    seed of FedBuff-style partial aggregation: the per-column ratio is a
    pure num/den pair, so late panels fold in by addition."""
    w = weights.astype(jnp.float32)
    val = params.astype(jnp.float32)
    den = jnp.einsum(
        "g,gn->n", wsum.astype(jnp.float32), gmask.astype(jnp.float32)
    )
    if bound is not None:
        bad = ~jnp.isfinite(val) | (jnp.abs(val) > bound)
        val = jnp.where(bad, 0.0, val)
        den = den - jnp.einsum("k,kn->n", w, bad.astype(jnp.float32))
    num = jnp.einsum("k,kn->n", w, val)
    if side is not None:
        snum, sden = side
        num = num + snum.astype(jnp.float32)
        den = den + sden.astype(jnp.float32)
    base = jnp.zeros_like(num) if prev is None else prev.astype(jnp.float32)
    out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), base)
    return out.astype(params.dtype if out_dtype is None else out_dtype)


# ---------------------------------------------------------------------------
# Quantized panel transport (the stream_dtype="int8" wire format)
# ---------------------------------------------------------------------------
#
# The cohort engine streams group panels int8 with PER-COLUMN scales carried
# as 4-bit power-of-two exponents against one bf16 per-group base:
#
#     scale_j = gbase · 2^(-e_j),   e_j ∈ [0, 15],   gbase = max_j a_j / 127
#
# with a_j the column absmax of the (error-feedback-corrected) panel.  The
# exponent row packs two columns per byte, so the whole scale side costs
# ~0.5 B/column on the wire — the int8 stream stays ≤ 0.30× the f32 wire
# bytes even at 4 clients per group, where a 2-byte bf16 scale row would
# blow the budget.  Quantization error per column is ≤ scale_j (the
# power-of-two ceiling doubles the exact-absmax step at worst); the
# error-feedback residual carried across rounds makes it unbiased in time.
# These functions are the semantics of record: the engine's jitted
# source-side quantizer and the Pallas dequant kernel both compose them, so
# source dequant (for the residual) and agg dequant are bitwise identical.


def quantize_columns(t: jax.Array):
    """Per-column int8 quantization of a ``[K, n]`` f32 panel.

    Returns ``(q, scale, e, gbase)``: int8 values, the DECODED per-column
    bf16 scales (``gbase · 2^-e``, exactly what :func:`decode_scale_exponents`
    reconstructs on the receiving shard), the 4-bit exponents (int8, values
    0..15), and the per-group bf16 base.  ``q`` is clipped to ±127, so a
    bf16 down-rounding of ``gbase`` can never overflow int8."""
    t = t.astype(jnp.float32)
    a = jnp.max(jnp.abs(t), axis=0)  # [n] column absmax
    gbase = (jnp.max(a) / 127.0).astype(jnp.bfloat16)
    gb = gbase.astype(jnp.float32)
    ratio = jnp.where(a > 0, gb / jnp.maximum(a / 127.0, 1e-38), 1.0)
    e = jnp.clip(
        jnp.floor(jnp.log2(jnp.maximum(ratio, 1.0))), 0, 15
    ).astype(jnp.int8)
    scale = decode_scale_exponents(e, gbase)
    sf = scale.astype(jnp.float32)
    q = jnp.clip(
        jnp.round(t / jnp.where(sf > 0, sf, 1.0)), -127, 127
    ).astype(jnp.int8)
    return q, scale, e, gbase


def dequantize_columns(q: jax.Array, scale: jax.Array) -> jax.Array:
    """f32 reconstruction ``q · scale`` — the exact expression the fused
    dequant prologue evaluates inside the kernel."""
    return q.astype(jnp.float32) * scale.astype(jnp.float32)


def decode_scale_exponents(e: jax.Array, gbase: jax.Array) -> jax.Array:
    """``[n]`` bf16 per-column scales from 4-bit exponents + group base."""
    return (
        gbase.astype(jnp.float32) * jnp.exp2(-e.astype(jnp.float32))
    ).astype(jnp.bfloat16)


def pack_scale_exponents(e: jax.Array) -> jax.Array:
    """Pack an EVEN-length ``[n]`` exponent row (values 0..15) two columns
    per byte: ``out[i] = e[2i] | e[2i+1] << 4`` — the 0.5 B/column wire
    format of the scale side of the int8 stream."""
    ei = e.astype(jnp.int32)
    return (ei[0::2] | (ei[1::2] << 4)).astype(jnp.int8)


def unpack_scale_exponents(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_scale_exponents` (exact for 4-bit values)."""
    pi = packed.astype(jnp.int32) & 0xFF
    return jnp.stack([pi & 0xF, (pi >> 4) & 0xF], axis=1).reshape(-1)


@jax.jit  # see fedavg_grouped: the armed variants must not pay op-by-op
def fedavg_grouped_dequant(
    params: jax.Array,  # [K, n] int8 panel, zero outside groups
    weights: jax.Array,  # [K] raw weights
    gmask: jax.Array,  # [G, n] per-group column membership
    wsum: jax.Array,  # [G] per-group weight sums
    gsel: jax.Array,  # [K, G] one-hot row→group selector
    scales: jax.Array,  # [G, n] per-group per-column bf16 scales
    prev: jax.Array | None = None,  # [n] f32 passthrough
    *,
    bound=None,  # quarantine gate on the DEQUANTIZED values
    side=None,  # (snum, sden) [n] associative merge inputs
) -> jax.Array:
    """Dequantizing :func:`fedavg_grouped`: the panel arrives int8 and the
    f32 values are reconstructed INSIDE the contraction — row ``k`` of group
    ``g`` dequantizes with ``scales[g]``, selected by the one-hot
    ``gsel @ scales`` matmul:

        out[j] = Σ_k w_k·(p_kj·scales[g(k), j]) / Σ_g wsum_g·gmask_gj

    (zero-denominator passthrough to ``prev`` as ever).  The f32 panel never
    exists as a buffer — only per-tile registers inside the kernel this
    oracle specifies.  Output is f32 (the aggregate, not the wire dtype).
    ``bound``/``side`` follow :func:`fedavg_grouped`'s quarantine/merge
    semantics, with the gate applied to the DEQUANTIZED values (a poisoned
    row can poison its group's scales — see fl/faults.py — so int8 corrupt
    equivalence is finiteness, not 1e-5)."""
    w = weights.astype(jnp.float32)
    ps = jnp.dot(gsel.astype(jnp.float32), scales.astype(jnp.float32))
    val = params.astype(jnp.float32) * ps
    den = jnp.einsum(
        "g,gn->n", wsum.astype(jnp.float32), gmask.astype(jnp.float32)
    )
    if bound is not None:
        bad = ~jnp.isfinite(val) | (jnp.abs(val) > bound)
        val = jnp.where(bad, 0.0, val)
        den = den - jnp.einsum("k,kn->n", w, bad.astype(jnp.float32))
    num = jnp.einsum("k,kn->n", w, val)
    if side is not None:
        snum, sden = side
        num = num + snum.astype(jnp.float32)
        den = den + sden.astype(jnp.float32)
    base = jnp.zeros_like(num) if prev is None else prev.astype(jnp.float32)
    out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), base)
    return out


def fedavg_grouped_sharded(
    params: jax.Array,  # [K, n] stacked client vectors, zero outside groups
    weights: jax.Array,  # [K] raw weights
    gmask: jax.Array,  # [G, n] per-group column membership
    wsum: jax.Array,  # [G] per-group weight sums
    prev: jax.Array | None = None,  # [n] passthrough
    *,
    n_shards: int = 1,
    tile: int = 128,
    out_dtype=None,
    bound=None,
    side=None,
) -> jax.Array:
    """Column-shard decomposition oracle for the sharded aggregation
    (kernels/ops.py::fedavg_grouped_sharded / fl/engine.py): pad ``n`` up to
    ``n_shards`` tile-aligned column blocks, run :func:`fedavg_grouped` on
    each block independently, and concatenate.  The per-column ratio has no
    cross-column coupling — and the quarantine gate and side num/den merge
    are per-column too — so this is BITWISE identical to the unsharded
    oracle — the invariant the shard_map path and the hypothesis property
    tests rely on."""
    K, n = params.shape
    n_cols = -(-n // n_shards)
    n_shard = -(-n_cols // tile) * tile
    pad = n_shard * n_shards - n
    if prev is None:
        prev = jnp.zeros((n,), params.dtype)
    p = jnp.pad(params, ((0, 0), (0, pad)))
    gm = jnp.pad(gmask, ((0, 0), (0, pad)))
    pv = jnp.pad(prev, (0, pad))
    if side is not None:
        sn = jnp.pad(side[0], (0, pad))
        sd = jnp.pad(side[1], (0, pad))
    outs = [
        fedavg_grouped(
            p[:, o : o + n_shard], weights, gm[:, o : o + n_shard], wsum,
            pv[o : o + n_shard], out_dtype=out_dtype, bound=bound,
            side=None if side is None
            else (sn[o : o + n_shard], sd[o : o + n_shard]),
        )
        for o in range(0, n_shard * n_shards, n_shard)
    ]
    return jnp.concatenate(outs)[:n]
