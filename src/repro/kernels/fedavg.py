"""Weighted FedAvg aggregation (paper Eq. 1) as a Pallas TPU kernel.

The server aggregates K client copies of the active block + output module:
``out = Σ_k w_k · params_k``.  Naively that is K reads of the full vector with
a growing f32 accumulator held in HBM.  The kernel tiles the parameter axis:
each grid step stages a [K, bt] panel into VMEM and contracts the K axis with
an f32 accumulator entirely on-chip — one HBM pass over the stacked params,
one write of the result.

``fedavg_masked`` is the heterogeneous-cohort variant: clients train
*different* sub-structures, so each column j carries a membership mask and
the contraction computes a per-column ratio ``Σ w·m·p / Σ w·m`` with a
zero-denominator passthrough to ``prev`` (the server's current value).  One
fused pass aggregates a whole multi-structure cohort (HeteroFL widths,
DepthFL depths, ProFL phases) regardless of how many groups it contains.

``fedavg_grouped`` is the group-compressed formulation of the same math:
mask rows are identical within a structure group, so instead of staging a
dense ``[K, n]`` membership mask the kernel takes a compact ``[G, n]`` group
mask plus per-group weight sums ``[G]``.  The panel is zero outside each
group's columns (the cohort engine's scatter guarantees it), so the
numerator needs no mask at all — ``Σ_k w_k·p_kj`` — and the denominator
collapses to the tiny contraction ``Σ_g wsum_g·gmask_gj``.  Mask traffic
drops from ``K·n`` to ``G·n + G`` elements (a factor of K/G) while the
output stays bit-comparable to ``fedavg_masked`` up to f32 reduction order.

Every kernel here is SHARD-LOCAL by construction: the per-column ratio has
no cross-column coupling, so the same ``pallas_call`` runs unchanged on a
``[K, n/D]`` column shard of the panel inside a ``shard_map`` over a
``model`` mesh axis (kernels/ops.py::fedavg_grouped_sharded) — that is how
the cohort engine keeps the full ``[K_total, n]`` panel from ever
materializing on one device.  Column shards are aligned to :data:`AGG_TILE`
(the TPU lane width) so shard boundaries never split a Pallas tile.

``interpret`` defaults to platform-aware: compiled on TPU, interpret mode
everywhere else.  Pass an explicit bool to override.

Oracles: kernels/ref.py::fedavg / fedavg_masked / fedavg_grouped (+ the
column-shard decomposition oracle ``fedavg_grouped_sharded``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_util import default_interpret

# Column-shard alignment for the sharded aggregation (fl/engine.py and
# kernels/ops.py::fedavg_grouped_sharded): the TPU lane width, so a per-device
# column block always starts on a (8, 128) f32 tile boundary and the
# shard-local pallas_call never sees a tile split across devices.
AGG_TILE = 128


def _fedavg_kernel(p_ref, w_ref, o_ref):
    p = p_ref[...].astype(jnp.float32)  # [K, bt]
    w = w_ref[...].astype(jnp.float32)  # [K]
    o_ref[...] = jnp.einsum("k,kn->n", w, p).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def fedavg(
    params: jax.Array,  # [K, n] stacked client vectors
    weights: jax.Array,  # [K]
    *,
    bt: int = 65536,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    K, n = params.shape
    bt = min(bt, n)
    pad = (-n) % bt
    if pad:
        params = jnp.pad(params, ((0, 0), (0, pad)))
    nt = (n + pad) // bt
    out = pl.pallas_call(
        _fedavg_kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((K, bt), lambda i: (0, i)),
            pl.BlockSpec((K,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), params.dtype),
        interpret=interpret,
    )(params, weights)
    return out[:n]


def _fedavg_masked_kernel(p_ref, w_ref, m_ref, prev_ref, o_ref):
    p = p_ref[...].astype(jnp.float32)  # [K, bt]
    w = w_ref[...].astype(jnp.float32)  # [K]
    m = m_ref[...].astype(jnp.float32)  # [K, bt]
    prev = prev_ref[...].astype(jnp.float32)  # [bt]
    num = jnp.einsum("k,kn->n", w, m * p)
    den = jnp.einsum("k,kn->n", w, m)
    out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), prev)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def fedavg_masked(
    params: jax.Array,  # [K, n] stacked client vectors (zero where unmasked)
    weights: jax.Array,  # [K] raw (NOT normalized) weights
    mask: jax.Array,  # [K, n] column membership
    prev: Optional[jax.Array] = None,  # [n] passthrough for uncovered columns
    *,
    bt: int = 65536,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Tiled like ``fedavg``: each grid step stages [K, bt] panel + mask
    blocks into VMEM and emits ``Σ w·m·p / Σ w·m`` for its columns, falling
    back to ``prev`` where no client covers a column."""
    if interpret is None:
        interpret = default_interpret()
    K, n = params.shape
    if prev is None:
        prev = jnp.zeros((n,), params.dtype)
    bt = min(bt, n)
    pad = (-n) % bt
    if pad:
        # padded mask columns are zero -> den 0 -> prev padding (also zero)
        params = jnp.pad(params, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        prev = jnp.pad(prev, (0, pad))
    nt = (n + pad) // bt
    out = pl.pallas_call(
        _fedavg_masked_kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((K, bt), lambda i: (0, i)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K, bt), lambda i: (0, i)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), params.dtype),
        interpret=interpret,
    )(params, weights, mask, prev)
    return out[:n]


@functools.lru_cache(maxsize=None)
def _make_grouped_kernel(dequant: bool, quar: bool, side: bool):
    """Kernel-body factory for the fault-tolerant ``fedavg_grouped``
    variants (ISSUE 8).  The clean kernels below stay untouched — a round
    with ``faults=None`` traces the exact PR 7 bodies — and each armed
    combination of (dequant, quarantine, side-merge) gets its own body with
    the extra operands spliced into the same tiled layout:

    * ``quar`` — the on-device quarantine gate: entries that are non-finite
      or exceed the ``bound`` operand in magnitude contribute 0 to the
      numerator and have their client's weight SUBTRACTED from the
      denominator, all inside the one kernel pass (no host sync, no second
      dispatch).  At ``bound=inf`` on a finite panel the gate degenerates
      bitwise (all-false mask; ``den - 0.0``).
    * ``side`` — associative ``(snum, sden)`` [bt] column blocks added into
      the ratio: the staleness-discounted straggler merge (num/den pairs
      are associative, so a parked panel folds in by addition — the
      stepping stone to FedBuff-style buffered aggregation).

    Shard-local like everything here: the gate and the merge are per-column,
    so the same body runs unchanged on a column shard inside shard_map."""

    def kernel(*refs):
        it = iter(refs)
        p = next(it)[...].astype(jnp.float32)  # [K, bt]
        w = next(it)[...].astype(jnp.float32)  # [K]
        gm = next(it)[...].astype(jnp.float32)  # [G, bt]
        ws = next(it)[...].astype(jnp.float32)  # [G]
        if dequant:
            gsel = next(it)[...].astype(jnp.float32)  # [K, G]
            sc = next(it)[...].astype(jnp.float32)  # [G, bt]
            val = p * jnp.dot(gsel, sc)
        else:
            val = p
        den = jnp.einsum("g,gn->n", ws, gm)
        if quar:
            bnd = next(it)[...].astype(jnp.float32)  # [1]
            bad = ~jnp.isfinite(val) | (jnp.abs(val) > bnd[0])
            val = jnp.where(bad, 0.0, val)
            den = den - jnp.einsum("k,kn->n", w, bad.astype(jnp.float32))
        num = jnp.einsum("k,kn->n", w, val)
        if side:
            num = num + next(it)[...].astype(jnp.float32)  # snum [bt]
            den = den + next(it)[...].astype(jnp.float32)  # sden [bt]
        prev = next(it)[...].astype(jnp.float32)  # [bt]
        out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), prev)
        o_ref = next(it)
        o_ref[...] = out.astype(o_ref.dtype)

    return kernel


def _fedavg_grouped_kernel(p_ref, w_ref, gm_ref, ws_ref, prev_ref, o_ref):
    p = p_ref[...].astype(jnp.float32)  # [K, bt]
    w = w_ref[...].astype(jnp.float32)  # [K]
    gm = gm_ref[...].astype(jnp.float32)  # [G, bt]
    ws = ws_ref[...].astype(jnp.float32)  # [G]
    prev = prev_ref[...].astype(jnp.float32)  # [bt]
    num = jnp.einsum("k,kn->n", w, p)  # panel zero outside groups: no mask
    den = jnp.einsum("g,gn->n", ws, gm)
    out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), prev)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bt", "interpret", "out_dtype")
)
def fedavg_grouped(
    params: jax.Array,  # [K, n] stacked client vectors (zero outside groups)
    weights: jax.Array,  # [K] raw (NOT normalized) weights
    gmask: jax.Array,  # [G, n] per-GROUP column membership
    wsum: jax.Array,  # [G] per-group weight sums (Σ of that group's weights)
    prev: Optional[jax.Array] = None,  # [n] passthrough for uncovered columns
    *,
    bt: int = 65536,
    interpret: Optional[bool] = None,
    out_dtype: Optional[str] = None,  # result dtype; None = params.dtype
    bound: Optional[jax.Array] = None,  # quarantine gate magnitude bound
    side: Optional[tuple] = None,  # (snum, sden) [n] associative merge
) -> jax.Array:
    """Group-compressed ``fedavg_masked``: per grid step stage the [K, bt]
    panel plus only a [G, bt] group-mask block and emit
    ``Σ_k w_k·p_kj / Σ_g wsum_g·gmask_gj``, falling back to ``prev`` where no
    group covers a column.  Requires the panel to be zero outside each
    group's columns — exactly what the cohort engine's scatter produces.

    ``out_dtype`` (a dtype name string, static) decouples the result dtype
    from the panel's wire dtype: a bf16-streamed panel still aggregates to an
    f32 server vector (the kernel accumulates in f32 regardless).

    ``bound``/``side`` (ISSUE 8) arm the fault-tolerant variants of the
    kernel body (see :func:`_make_grouped_kernel`; oracle:
    kernels/ref.py::fedavg_grouped with the same kwargs): ``bound`` fuses
    the per-entry quarantine gate into the pass, ``side`` adds staged
    ``(num, den)`` side inputs for the staleness-discounted straggler
    merge.  With both None this traces the exact clean kernel."""
    if interpret is None:
        interpret = default_interpret()
    K, n = params.shape
    G = gmask.shape[0]
    od = jnp.dtype(params.dtype if out_dtype is None else out_dtype)
    if prev is None:
        prev = jnp.zeros((n,), od)
    bt = min(bt, n)
    pad = (-n) % bt
    snum = sden = None
    if side is not None:
        snum = side[0].astype(jnp.float32)
        sden = side[1].astype(jnp.float32)
    if pad:
        # padded gmask columns are zero -> den 0 -> prev padding (also zero)
        params = jnp.pad(params, ((0, 0), (0, pad)))
        gmask = jnp.pad(gmask, ((0, 0), (0, pad)))
        prev = jnp.pad(prev, (0, pad))
        if side is not None:
            # zero side padding: den stays 0 there -> prev passthrough
            snum = jnp.pad(snum, (0, pad))
            sden = jnp.pad(sden, (0, pad))
    nt = (n + pad) // bt
    operands = [params, weights, gmask, wsum]
    in_specs = [
        pl.BlockSpec((K, bt), lambda i: (0, i)),
        pl.BlockSpec((K,), lambda i: (0,)),
        pl.BlockSpec((G, bt), lambda i: (0, i)),
        pl.BlockSpec((G,), lambda i: (0,)),
    ]
    if bound is not None:
        operands.append(jnp.asarray(bound, jnp.float32).reshape(1))
        in_specs.append(pl.BlockSpec((1,), lambda i: (0,)))
    if side is not None:
        operands += [snum, sden]
        in_specs += [pl.BlockSpec((bt,), lambda i: (i,)),
                     pl.BlockSpec((bt,), lambda i: (i,))]
    operands.append(prev)
    in_specs.append(pl.BlockSpec((bt,), lambda i: (i,)))
    if bound is None and side is None:
        kernel = _fedavg_grouped_kernel  # the clean PR 7 body, untouched
    else:
        kernel = _make_grouped_kernel(False, bound is not None,
                                      side is not None)
    out = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), od),
        interpret=interpret,
    )(*operands)
    return out[:n]


def _fedavg_grouped_dequant_kernel(
    p_ref, w_ref, gm_ref, ws_ref, gs_ref, sc_ref, prev_ref, o_ref
):
    p = p_ref[...].astype(jnp.float32)  # [K, bt] int8 wire values
    w = w_ref[...].astype(jnp.float32)  # [K]
    gm = gm_ref[...].astype(jnp.float32)  # [G, bt]
    ws = ws_ref[...].astype(jnp.float32)  # [G]
    gsel = gs_ref[...].astype(jnp.float32)  # [K, G] one-hot row→group
    sc = sc_ref[...].astype(jnp.float32)  # [G, bt] per-column scales
    prev = prev_ref[...].astype(jnp.float32)  # [bt]
    # Dequant prologue fused into the contraction: per-row scales via the
    # one-hot matmul (MXU-friendly, no gather), f32 only in registers/VMEM.
    ps = jnp.dot(gsel, sc)  # [K, bt]
    num = jnp.einsum("k,kn->n", w, p * ps)
    den = jnp.einsum("g,gn->n", ws, gm)
    out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), prev)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bt", "interpret", "out_dtype")
)
def fedavg_grouped_dequant(
    params: jax.Array,  # [K, n] int8 panel (zero outside groups)
    weights: jax.Array,  # [K] raw (NOT normalized) weights
    gmask: jax.Array,  # [G, n] per-GROUP column membership
    wsum: jax.Array,  # [G] per-group weight sums
    gsel: jax.Array,  # [K, G] one-hot row→group selector
    scales: jax.Array,  # [G, n] per-group per-column bf16 scales
    prev: Optional[jax.Array] = None,  # [n] passthrough for uncovered columns
    *,
    bt: int = 65536,
    interpret: Optional[bool] = None,
    out_dtype: Optional[str] = "float32",
    bound: Optional[jax.Array] = None,  # quarantine gate magnitude bound
    side: Optional[tuple] = None,  # (snum, sden) [n] associative merge
) -> jax.Array:
    """:func:`fedavg_grouped` over a QUANTIZED int8 panel: each grid step
    stages the [K, bt] int8 block plus a [G, bt] bf16 scale block and
    reconstructs f32 values inside the contraction (``p · (gsel @ scales)``),
    so the f32 group panel never exists as an HBM buffer — per-tile VMEM
    registers only.  Oracle: kernels/ref.py::fedavg_grouped_dequant.
    Shard-local like every kernel here (no cross-column coupling): the same
    pallas_call runs on a column shard inside shard_map.  ``bound``/``side``
    arm the fault-tolerant body variants (quarantine on the DEQUANTIZED
    values + staged num/den merge) exactly as in :func:`fedavg_grouped`."""
    if interpret is None:
        interpret = default_interpret()
    K, n = params.shape
    G = gmask.shape[0]
    od = jnp.dtype(params.dtype if out_dtype is None else out_dtype)
    if prev is None:
        prev = jnp.zeros((n,), od)
    bt = min(bt, n)
    pad = (-n) % bt
    snum = sden = None
    if side is not None:
        snum = side[0].astype(jnp.float32)
        sden = side[1].astype(jnp.float32)
    if pad:
        # padded gmask columns are zero -> den 0 -> prev padding (also zero)
        params = jnp.pad(params, ((0, 0), (0, pad)))
        gmask = jnp.pad(gmask, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad)))
        prev = jnp.pad(prev, (0, pad))
        if side is not None:
            snum = jnp.pad(snum, (0, pad))
            sden = jnp.pad(sden, (0, pad))
    nt = (n + pad) // bt
    operands = [params, weights, gmask, wsum, gsel, scales]
    in_specs = [
        pl.BlockSpec((K, bt), lambda i: (0, i)),
        pl.BlockSpec((K,), lambda i: (0,)),
        pl.BlockSpec((G, bt), lambda i: (0, i)),
        pl.BlockSpec((G,), lambda i: (0,)),
        pl.BlockSpec((K, G), lambda i: (0, 0)),
        pl.BlockSpec((G, bt), lambda i: (0, i)),
    ]
    if bound is not None:
        operands.append(jnp.asarray(bound, jnp.float32).reshape(1))
        in_specs.append(pl.BlockSpec((1,), lambda i: (0,)))
    if side is not None:
        operands += [snum, sden]
        in_specs += [pl.BlockSpec((bt,), lambda i: (i,)),
                     pl.BlockSpec((bt,), lambda i: (i,))]
    operands.append(prev)
    in_specs.append(pl.BlockSpec((bt,), lambda i: (i,)))
    if bound is None and side is None:
        kernel = _fedavg_grouped_dequant_kernel  # clean PR 7 body, untouched
    else:
        kernel = _make_grouped_kernel(True, bound is not None,
                                      side is not None)
    out = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), od),
        interpret=interpret,
    )(*operands)
    return out[:n]
