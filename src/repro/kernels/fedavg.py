"""Weighted FedAvg aggregation (paper Eq. 1) as a Pallas TPU kernel.

The server aggregates K client copies of the active block + output module:
``out = Σ_k w_k · params_k``.  Naively that is K reads of the full vector with
a growing f32 accumulator held in HBM.  The kernel tiles the parameter axis:
each grid step stages a [K, bt] panel into VMEM and contracts the K axis with
an f32 accumulator entirely on-chip — one HBM pass over the stacked params,
one write of the result.

Oracle: kernels/ref.py::fedavg.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fedavg_kernel(p_ref, w_ref, o_ref):
    p = p_ref[...].astype(jnp.float32)  # [K, bt]
    w = w_ref[...].astype(jnp.float32)  # [K]
    o_ref[...] = jnp.einsum("k,kn->n", w, p).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def fedavg(
    params: jax.Array,  # [K, n] stacked client vectors
    weights: jax.Array,  # [K]
    *,
    bt: int = 65536,
    interpret: bool = True,
) -> jax.Array:
    K, n = params.shape
    bt = min(bt, n)
    pad = (-n) % bt
    if pad:
        params = jnp.pad(params, ((0, 0), (0, pad)))
    nt = (n + pad) // bt
    out = pl.pallas_call(
        _fedavg_kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((K, bt), lambda i: (0, i)),
            pl.BlockSpec((K,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), params.dtype),
        interpret=interpret,
    )(params, weights)
    return out[:n]
