"""Public kernel API used by the model layers.

``attention`` dispatches between:
  * ``pallas``  — the Pallas TPU kernel (flash_attention.py). Forward-only;
                  on this CPU container it runs in interpret mode.
  * ``chunked`` — a differentiable pure-JAX flash-attention (two-level
                  lax.scan over q/kv chunks with online softmax). This is the
                  default for training/prefill: bounded O(bq·bk) temporaries
                  instead of the O(S²) logits tensor, and XLA can remat it.
  * ``naive``   — the ref.py oracle (small shapes / tests).

``effective_movement_update`` / ``fedavg`` / ``fedavg_masked`` dispatch
kernel vs ref the same way.  On TPU the pallas paths are selected
automatically, and the Pallas kernels' ``interpret`` flag resolves
platform-aware (compiled on TPU, interpret mode elsewhere).

``DISPATCHES`` counts aggregation dispatches issued through this module
(python-level calls; for callers under ``jax.jit`` that means trace-time
calls).  The grouped cohort engine asserts "one aggregation dispatch per
round regardless of group count" against it.  The column-sharded variants
(``fedavg_grouped_sharded`` / ``fedavg_masked_sharded``) still count ONE
logical ``fedavg_grouped``/``fedavg_masked`` dispatch per call — the
round-level contract is unchanged — and additionally record the per-shard
kernel launches that one logical dispatch lowers to (one per device of the
``model`` mesh axis) under the ``*_shards`` keys, so benchmarks can report
fan-out without weakening the one-dispatch assertion.  The shard-local
group-panel stream scatters (``scatter_stream_sharded``) are counted under
``stream_scatter``/``stream_scatter_shards`` — data movement, never part of
the one-aggregation-dispatch contract.  Each scatter also RETURNS a tiny
per-shard pacing token alongside the updated panel: a ``[D]`` slice of the
written block that the engine threads into a later pass's source-side
gather through ``jax.lax.optimization_barrier``, so at most ``inflight``
stream passes can be resident on the agg devices at once — a pure
data-dependency, no host sync (the one-``block_until_ready`` round
contract is untouched).  ``STAGED`` counts
membership metadata elements staged per aggregation kernel (the dense
``[K, n]`` mask for ``fedavg_masked``; the compact ``[G, n]`` group mask +
``[G]`` weight sums for ``fedavg_grouped``, padded-to-tile for the sharded
variants; gmask + wsum + the ``[K, G]`` one-hot selector + the ``[G, n]``
scale rows for the dequantizing variants) — the benchmark smoke gate
asserts the grouped path stays within ``G·n + K`` elements against it.

The dequantizing variants (``fedavg_grouped_dequant`` /
``fedavg_grouped_dequant_sharded``) take an int8 panel plus per-group
per-column bf16 scales and reconstruct f32 INSIDE the kernel contraction —
they count under the SAME ``fedavg_grouped`` DISPATCHES key because they
are the same logical aggregation dispatch, just over the compressed wire
format (``stream_dtype="int8"``).

Fault tolerance (ISSUE 8): every grouped variant takes optional ``bound``
and ``side`` operands that arm the fault-tolerant kernel bodies — ``bound``
fuses a per-entry quarantine gate (non-finite or ``|update| > bound``
entries contribute 0 to the numerator and subtract their client's weight
from the denominator) into the SAME kernel pass, and ``side`` adds
associative ``(snum, sden)`` column vectors carrying the
staleness-discounted straggler merge.  Both ride the one logical dispatch:
an armed round counts exactly like a clean one under ``DISPATCHES``, and
``bound=None, side=None`` traces the unchanged clean bodies (bit-equal to
the pre-fault path).
"""
from __future__ import annotations

import collections
import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import effective_movement as _em
from repro.kernels import fedavg as _fedavg
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref

Impl = Literal["auto", "pallas", "chunked", "naive"]

DISPATCHES: collections.Counter = collections.Counter()

# membership metadata elements staged per aggregation kernel, keyed like
# DISPATCHES (mask elements for fedavg_masked; gmask + wsum elements for
# fedavg_grouped — client weights [K] are common to both and not counted)
STAGED: collections.Counter = collections.Counter()


def reset_dispatches() -> None:
    DISPATCHES.clear()
    STAGED.clear()


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention(
    q: jax.Array,  # [B, H, Sq, hd]
    k: jax.Array,  # [B, K, Skv, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    impl: Impl = "auto",
    bq: int = 512,
    bk: int = 512,
) -> jax.Array:
    if impl == "auto":
        if q.shape[2] <= 256:
            impl = "naive"
        elif _on_tpu() and q_offset == 0:
            impl = "pallas"
        else:
            impl = "chunked"
    if impl == "pallas":
        return _fa.flash_attention_fwd(
            q, k, v, causal=causal, window=window, bq=bq, bk=bk,
            interpret=not _on_tpu(),
        )
    if impl == "chunked":
        return _chunked_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset, bq=bq, bk=bk
        )
    return _ref.attention(q, k, v, causal=causal, window=window, q_offset=q_offset)


def _chunked_attention(
    q, k, v, *, causal: bool, window: int, q_offset: int, bq: int, bk: int
):
    """Differentiable flash attention: outer scan over q chunks, inner scan
    over kv chunks with running (m, l, acc). Accumulation in f32.

    Sharding: q/k/v are constrained ONCE here — batch over dp, q heads over
    'model', kv heads replicated, SEQ UNSHARDED — so every chunk slice
    inside the scans is device-local.  Without this, the Megatron-SP
    seq-sharding of the residual stream propagates into the scan and GSPMD
    inserts a collective-permute/all-gather per (q, kv) chunk pair — ~2300
    collectives per step at 36L/8×8 chunks (EXPERIMENTS.md §Perf i8)."""
    from repro.launch import sharding as _sh

    q = _sh.constrain_heads(q)
    k = _sh.constrain_heads(k)
    v = _sh.constrain_heads(v)
    B, H, Sq, hd = q.shape
    Kh, Skv = k.shape[1], k.shape[2]
    g = H // Kh
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    # pad seq lens up to multiples
    pq, pk = (-Sq) % bq, (-Skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk = (Sq + pq) // bq, (Skv + pk) // bk
    scale = 1.0 / (hd**0.5)

    qc = q.reshape(B, H, nq, bq, hd).transpose(2, 0, 1, 3, 4)  # [nq,B,H,bq,hd]
    kc = k.reshape(B, Kh, nk, bk, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Kh, nk, bk, hd).transpose(2, 0, 1, 3, 4)

    def q_step(_, args):
        iq, qb = args  # qb: [B,H,bq,hd]
        qb32 = qb.astype(jnp.float32) * scale
        qr = qb32.reshape(B, Kh, g, bq, hd)

        def kv_step(carry, args2):
            m, l, acc = carry
            ik, kb, vb = args2  # [B,Kh,bk,hd]
            s = jnp.einsum(
                "bkgqd,bksd->bkgqs", qr, kb.astype(jnp.float32)
            )  # [B,Kh,g,bq,bk]
            rows = q_offset + iq * bq + jnp.arange(bq)[:, None]
            cols = ik * bk + jnp.arange(bk)[None, :]
            mask = cols < Skv  # mask kv padding
            if causal:
                mask &= rows >= cols
            if window > 0:
                mask &= cols > rows - window
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, vb.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Kh, g, bq, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Kh, g, bq, 1), jnp.float32)
        a0 = jnp.zeros((B, Kh, g, bq, hd), jnp.float32)
        # flash-backward memory behavior: recompute the [bq, bk] softmax
        # block in the backward pass instead of saving it per (q, kv) pair
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step, prevent_cse=False),
            (m0, l0, a0), (jnp.arange(nk), kc, vc),
        )
        l = jnp.where(l == 0.0, 1.0, l)
        ob = (acc / l).reshape(B, H, bq, hd).astype(q.dtype)
        return None, ob

    _, oc = jax.lax.scan(
        jax.checkpoint(q_step, prevent_cse=False), None, (jnp.arange(nq), qc)
    )  # [nq,B,H,bq,hd]
    out = oc.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq + pq, hd)
    return out[:, :, :Sq]


# ---------------------------------------------------------------------------
# Effective movement / FedAvg
# ---------------------------------------------------------------------------


def effective_movement_update(p_new, p_old, net, *, impl: Impl = "auto"):
    if impl == "auto":
        impl = "pallas" if (_on_tpu() or p_new.size >= 4096) else "naive"
    if impl == "pallas":
        return _em.effective_movement_update(p_new, p_old, net)
    return _ref.effective_movement_update(p_new, p_old, net)


def fedavg(params, weights, *, impl: Impl = "auto"):
    DISPATCHES["fedavg"] += 1
    if impl == "auto":
        impl = "pallas" if (_on_tpu() or params.shape[-1] >= 4096) else "naive"
    if impl == "pallas":
        return _fedavg.fedavg(params, weights)
    return _ref.fedavg(params, weights)


def fedavg_masked(
    params,  # [K, n] panel
    weights,  # [K] raw weights (normalization cancels in num/den)
    mask,  # [K, n] column membership
    prev: Optional[jax.Array] = None,  # [n] passthrough for uncovered columns
    *,
    impl: Impl = "auto",
):
    """Masked per-column weighted average: Σ w·m·p / Σ w·m with a
    zero-denominator passthrough to ``prev``.  One dispatch aggregates a
    whole heterogeneous cohort (HeteroFL/DepthFL/ProFL groups)."""
    DISPATCHES["fedavg_masked"] += 1
    STAGED["fedavg_masked"] += int(mask.size)
    if impl == "auto":
        impl = "pallas" if (_on_tpu() or params.shape[-1] >= 4096) else "naive"
    if impl == "pallas":
        return _fedavg.fedavg_masked(params, weights, mask, prev)
    return _ref.fedavg_masked(params, weights, mask, prev)


def fedavg_grouped(
    params,  # [K, n] panel, zero outside each group's columns
    weights,  # [K] raw weights (normalization cancels in num/den)
    gmask,  # [G, n] per-GROUP column membership
    wsum,  # [G] per-group weight sums
    prev: Optional[jax.Array] = None,  # [n] passthrough for uncovered columns
    *,
    impl: Impl = "auto",
    out_dtype: Optional[str] = None,  # result dtype; None = params.dtype
    bound=None,  # quarantine gate: finite check + |p| > bound zeroes weight
    side=None,  # (snum, sden) [n] associative staleness-merge inputs
):
    """Group-compressed masked average: ``Σ_k w·p / Σ_g wsum·gmask`` with a
    zero-denominator passthrough to ``prev``.  Same math as ``fedavg_masked``
    when mask rows repeat within structure groups (they always do for the
    cohort engine), but stages ``G·n + G`` membership elements instead of
    ``K·n`` — a K/G cut in mask HBM traffic per dispatch.  ``out_dtype``
    decouples the result dtype from the panel's wire dtype (a bf16-streamed
    panel still aggregates to an f32 server vector).

    ``bound``/``side`` (ISSUE 8) arm the fault-tolerant kernel variants —
    the fused per-entry quarantine gate and the staged num/den straggler
    merge (see kernels/fedavg.py::_make_grouped_kernel); both ride the SAME
    logical dispatch, so the round-level one-dispatch contract holds under
    fault injection."""
    DISPATCHES["fedavg_grouped"] += 1
    STAGED["fedavg_grouped"] += int(gmask.size) + int(wsum.size)
    if impl == "auto":
        impl = "pallas" if (_on_tpu() or params.shape[-1] >= 4096) else "naive"
    if impl == "pallas":
        return _fedavg.fedavg_grouped(
            params, weights, gmask, wsum, prev, out_dtype=out_dtype,
            bound=bound, side=side,
        )
    return _ref.fedavg_grouped(
        params, weights, gmask, wsum, prev, out_dtype=out_dtype,
        bound=bound, side=side,
    )


def fedavg_grouped_dequant(
    params,  # [K, n] int8 panel, zero outside each group's columns
    weights,  # [K] raw weights
    gmask,  # [G, n] per-GROUP column membership
    wsum,  # [G] per-group weight sums
    gsel,  # [K, G] one-hot row→group selector
    scales,  # [G, n] per-group per-column bf16 scales
    prev: Optional[jax.Array] = None,  # [n] passthrough for uncovered columns
    *,
    impl: Impl = "auto",
    out_dtype: Optional[str] = "float32",
    bound=None,  # quarantine gate on the DEQUANTIZED values
    side=None,  # (snum, sden) [n] associative staleness-merge inputs
):
    """``fedavg_grouped`` over a quantized int8 panel with the dequant fused
    into the kernel contraction (``p · (gsel @ scales)``) — the f32 panel
    never materializes as a buffer.  Same logical dispatch, same DISPATCHES
    key as ``fedavg_grouped``; the extra scale/selector staging is counted.
    ``bound``/``side`` arm the fault-tolerant variants as in
    :func:`fedavg_grouped`."""
    DISPATCHES["fedavg_grouped"] += 1
    STAGED["fedavg_grouped"] += (
        int(gmask.size) + int(wsum.size) + int(gsel.size) + int(scales.size)
    )
    if impl == "auto":
        impl = "pallas" if (_on_tpu() or params.shape[-1] >= 4096) else "naive"
    if impl == "pallas":
        return _fedavg.fedavg_grouped_dequant(
            params, weights, gmask, wsum, gsel, scales, prev,
            out_dtype=out_dtype, bound=bound, side=side,
        )
    return _ref.fedavg_grouped_dequant(
        params, weights, gmask, wsum, gsel, scales, prev,
        bound=bound, side=side,
    ).astype(jnp.dtype(out_dtype or jnp.float32))


def fedavg_grouped_edge(
    entries,  # per-group slices: (vals [k, n_g], w [k], idx [n_g], scale|None)
    n: int,  # compressed panel width the partial covers (layout.n_active)
    *,
    bound=None,  # quarantine gate, applied at the edge (same semantics)
):
    """One EDGE aggregator's partial fold (ISSUE 10, two-tier rounds): the
    edge's slice of each group panel folds into one associative ``(num,
    den)`` pair over the ``[n]`` compressed column space — exactly the
    per-row terms of ``fedavg_grouped`` (``num += w·val``, ``den += w``
    over the group's live columns, the quarantine gate subtracting ``w``
    per bad entry), so summing the edge pairs over any fan-in reproduces
    the flat kernel's num/den before the ratio.  ``scale`` dequantizes an
    int8 slice at the edge (``val = q·scale``) — bitwise the dequant the
    fused kernel performs, just earlier in the tree.

    Counted under ``DISPATCHES["fedavg_grouped_edges"]`` — one entry per
    edge launch, like the sharded per-shard counters: the round-level
    one-``fedavg_grouped``-dispatch contract stays with the top-tier
    carrier dispatch, and the per-edge launches report fan-out without
    weakening it.  All device work is async scatter-adds — no host sync."""
    DISPATCHES["fedavg_grouped_edges"] += 1
    num = jnp.zeros((n,), jnp.float32)
    den = jnp.zeros((n,), jnp.float32)
    for vals, w, idx, scale in entries:
        val = vals.astype(jnp.float32)
        if scale is not None:
            val = val * scale.astype(jnp.float32)[None, :]
        wf = w.astype(jnp.float32)
        dloc = jnp.full((val.shape[1],), jnp.sum(wf), jnp.float32)
        if bound is not None:
            bad = ~jnp.isfinite(val) | (jnp.abs(val) > bound)
            val = jnp.where(bad, 0.0, val)
            dloc = dloc - jnp.einsum("k,kn->n", wf, bad.astype(jnp.float32))
        num = num.at[idx].add(jnp.einsum("k,kn->n", wf, val))
        den = den.at[idx].add(dloc)
    return num, den


# ---------------------------------------------------------------------------
# Column-sharded aggregation: shard_map the kernels over the ``model`` axis
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _sharded_agg_call(mesh: Mesh, kind: str, impl: str, out_dtype=None,
                      quar: bool = False, side: bool = False):
    """Cached jitted shard_map of a shard-local aggregation kernel over the
    ``model`` mesh axis.  The kernels are shard-local by construction (the
    per-column ratio has no cross-column coupling), so each device runs the
    UNCHANGED kernel on its ``[K, n/D]`` column block — no collectives.
    ``out_dtype`` (a dtype name string, part of the cache key) is forwarded
    to the grouped kernels so quantized/bf16 panels aggregate to f32.

    ``quar``/``side`` (cache-key flags, ISSUE 8) splice the fault-tolerant
    operands into the grouped signatures: the quarantine ``bound`` rides
    replicated (``P()``, one f32 scalar) and the ``(snum, sden)`` staleness
    side vectors ride column-sharded (``P("model")``) like ``prev`` — the
    gate and the merge are per-column, so the shard decomposition stays
    bitwise exact (kernels/ref.py::fedavg_grouped_sharded is the oracle)."""
    if kind == "grouped":
        base = (_fedavg.fedavg_grouped if impl == "pallas"
                else _ref.fedavg_grouped)

        def fn(p, w, gm, ws, *rest, _base=base, _od=out_dtype):
            rest = list(rest)
            bnd = rest.pop(0) if quar else None
            sd = (rest.pop(0), rest.pop(0)) if side else None
            return _base(p, w, gm, ws, rest[0], out_dtype=_od,
                         bound=bnd, side=sd)

        in_specs = [P(None, "model"), P(), P(None, "model"), P()]
    elif kind == "grouped_dequant":
        if impl == "pallas":
            base = functools.partial(
                _fedavg.fedavg_grouped_dequant, out_dtype=out_dtype
            )
        else:
            od = jnp.dtype(out_dtype or jnp.float32)

            def base(*a, _od=od, **kw):
                return _ref.fedavg_grouped_dequant(*a, **kw).astype(_od)

        def fn(p, w, gm, ws, gs, sc, *rest, _base=base):
            rest = list(rest)
            bnd = rest.pop(0) if quar else None
            sd = (rest.pop(0), rest.pop(0)) if side else None
            return _base(p, w, gm, ws, gs, sc, rest[0],
                         bound=bnd, side=sd)

        in_specs = [
            P(None, "model"), P(), P(None, "model"), P(), P(),
            P(None, "model"),
        ]
    else:
        fn = (_fedavg.fedavg_masked if impl == "pallas"
              else _ref.fedavg_masked)
        in_specs = [P(None, "model"), P(), P(None, "model"), P("model")]
    if kind in ("grouped", "grouped_dequant"):
        if quar:
            in_specs.append(P())  # bound: one replicated f32 scalar
        if side:
            in_specs += [P("model"), P("model")]  # snum, sden like prev
        in_specs.append(P("model"))  # prev
    return jax.jit(shard_map(
        fn, mesh=mesh, in_specs=tuple(in_specs), out_specs=P("model"),
        check_rep=False,
    ))


@functools.lru_cache(maxsize=32)
def _stream_scatter_call(mesh: Mesh):
    """Cached jitted shard_map of the shard-local group-panel stream scatter
    over the ``model`` mesh axis (see :func:`scatter_stream_sharded`)."""

    def scatter(panel, sel, dst, row):
        def shard(pnl, gp, dl, rowl):
            # gp [1, K_g, m]: this device's pre-sliced group columns for the
            # pass; dl [1, m]: their local columns inside this shard's
            # block (pad = n_shard -> dropped).  Read-modify-write of the
            # group's row block so multi-pass streams compose — the donated
            # panel makes it an in-place update.  The returned token is a
            # one-element slice of the WRITTEN block: anything data-dependent
            # on it (the engine barriers a later pass's gather on it) cannot
            # start before this shard's landing completed — the pacing
            # primitive, with zero transfer cost (one element per shard).
            blk = jax.lax.dynamic_slice(
                pnl, (rowl, 0), (gp.shape[1], pnl.shape[1])
            )
            blk = blk.at[:, dl[0]].set(gp[0], mode="drop")
            return (
                jax.lax.dynamic_update_slice(pnl, blk, (rowl, 0)),
                blk[0, :1],
            )

        return shard_map(
            shard, mesh=mesh,
            in_specs=(P(None, "model"), P("model"), P("model"), P()),
            out_specs=(P(None, "model"), P("model")), check_rep=False,
        )(panel, sel, dst, row)

    # only the panel is donated: sel has no matching output to alias into
    # (XLA frees it after the read anyway), and dst is a cached buffer
    return jax.jit(scatter, donate_argnums=(0,))


def scatter_stream_sharded(
    panel,  # [K_total, n_padded] shared panel, column-sharded P(None, "model")
    sel,  # [D, K_g, m] pre-sliced group columns, axis-0-sharded P("model")
    dst,  # [D, m] local destination columns per shard, axis-0-sharded
    row: int,  # the group's row offset in the shared panel
    *,
    mesh: Mesh,
):
    """Shard-local scatter of one stream pass of a group panel into the
    column-sharded shared panel: each device of ``mesh``'s ``model`` axis
    receives ONLY the group columns it owns (``sel`` row ``d``, sliced on
    the group panel's source device by fl/engine.py::_stream_gather) and
    lands them at ``dst`` inside its own block — no ``[K_g, n_g]`` replica
    ever exists on an agg device.  The panel is donated (in-place update);
    ``dst`` is the layout's cached per-mesh index buffer and must NOT be
    donated.

    Returns ``(panel, token)``: ``token`` is a ``[D]`` pacing carry (one
    element per shard, sliced from the written row block) that the engine
    feeds back into a later pass's source-side gather via
    ``jax.lax.optimization_barrier`` — a pure device-side data dependency
    that caps the number of in-flight stream passes without any host sync.
    Accounting: one ``stream_scatter`` entry
    per pass plus ``stream_scatter_shards`` += D for the per-shard updates
    (scatters are data movement, not aggregation dispatches — the
    one-``fedavg_grouped``-dispatch round contract does not count them)."""
    DISPATCHES["stream_scatter"] += 1
    DISPATCHES["stream_scatter_shards"] += mesh.shape["model"]
    return _stream_scatter_call(mesh)(panel, sel, dst, row)


def clear_shard_caches() -> None:
    """Drop the cached shard_map'd aggregation + stream-scatter executables
    (they hold mesh references).  Wired into fl/engine.py::clear_caches."""
    _sharded_agg_call.cache_clear()
    _stream_scatter_call.cache_clear()


def fedavg_grouped_sharded(
    params,  # [K, n_padded] panel, column-sharded P(None, "model")
    weights,  # [K] raw weights
    gmask,  # [G, n_padded] group mask, column-sharded P(None, "model")
    wsum,  # [G] per-group weight sums
    prev,  # [n_padded] passthrough, column-sharded P("model")
    *,
    mesh: Mesh,
    impl: Impl = "auto",
    out_dtype: Optional[str] = None,
    bound=None,  # quarantine gate (python float or f32 scalar)
    side=None,  # (snum, sden) [n_padded] column-sharded P("model")
):
    """Column-sharded ``fedavg_grouped``: ONE logical aggregation dispatch
    that lowers to one shard-local kernel launch per device of ``mesh``'s
    ``model`` axis, each over its own ``[K, n_padded/D]`` column block — the
    full panel never exists on a single device.  The caller (fl/engine.py)
    pads ``n`` to a tile-aligned multiple of the axis size and commits the
    operands with the shardings above.  Accounting: one ``fedavg_grouped``
    DISPATCHES entry (the round-level one-dispatch contract is agg-mode
    independent) plus ``fedavg_grouped_shards`` += D for the per-shard
    launches under that single logical round.  ``bound``/``side`` arm the
    fault-tolerant kernel variants inside the SAME logical dispatch."""
    d = mesh.shape["model"]
    DISPATCHES["fedavg_grouped"] += 1
    DISPATCHES["fedavg_grouped_shards"] += d
    STAGED["fedavg_grouped"] += int(gmask.size) + int(wsum.size)
    if impl == "auto":
        impl = ("pallas" if (_on_tpu() or params.shape[-1] // d >= 4096)
                else "naive")
    call = _sharded_agg_call(mesh, "grouped", impl, out_dtype,
                             bound is not None, side is not None)
    operands = [params, weights, gmask, wsum]
    if bound is not None:
        operands.append(jnp.full((1,), bound, jnp.float32))
    if side is not None:
        operands += [side[0], side[1]]
    return call(*operands, prev)


def fedavg_grouped_dequant_sharded(
    params,  # [K, n_padded] int8 panel, column-sharded P(None, "model")
    weights,  # [K] raw weights
    gmask,  # [G, n_padded] group mask, column-sharded P(None, "model")
    wsum,  # [G] per-group weight sums
    gsel,  # [K, G] one-hot row→group selector (replicated)
    scales,  # [G, n_padded] bf16 scales, column-sharded P(None, "model")
    prev,  # [n_padded] passthrough, column-sharded P("model")
    *,
    mesh: Mesh,
    impl: Impl = "auto",
    out_dtype: Optional[str] = "float32",
    bound=None,  # quarantine gate (python float or f32 scalar)
    side=None,  # (snum, sden) [n_padded] column-sharded P("model")
):
    """Column-sharded :func:`fedavg_grouped_dequant`: each device
    dequantizes and contracts its own ``[K, n_padded/D]`` int8 block against
    its ``[G, n_padded/D]`` scale block — neither the f32 panel nor the full
    int8 panel ever exists on a single device.  Same DISPATCHES key, round
    contract, and ``bound``/``side`` fault variants as
    :func:`fedavg_grouped_sharded`."""
    d = mesh.shape["model"]
    DISPATCHES["fedavg_grouped"] += 1
    DISPATCHES["fedavg_grouped_shards"] += d
    STAGED["fedavg_grouped"] += (
        int(gmask.size) + int(wsum.size) + int(gsel.size) + int(scales.size)
    )
    if impl == "auto":
        impl = ("pallas" if (_on_tpu() or params.shape[-1] // d >= 4096)
                else "naive")
    call = _sharded_agg_call(mesh, "grouped_dequant", impl, out_dtype,
                             bound is not None, side is not None)
    operands = [params, weights, gmask, wsum, gsel, scales]
    if bound is not None:
        operands.append(jnp.full((1,), bound, jnp.float32))
    if side is not None:
        operands += [side[0], side[1]]
    return call(*operands, prev)


def fedavg_masked_sharded(
    params,  # [K, n_padded] panel, column-sharded P(None, "model")
    weights,  # [K] raw weights
    mask,  # [K, n_padded] per-client mask, column-sharded P(None, "model")
    prev,  # [n_padded] passthrough, column-sharded P("model")
    *,
    mesh: Mesh,
    impl: Impl = "auto",
):
    """Column-sharded ``fedavg_masked`` (the legacy dense-mask escape hatch
    under sharded aggregation) — same contract as
    :func:`fedavg_grouped_sharded`."""
    d = mesh.shape["model"]
    DISPATCHES["fedavg_masked"] += 1
    DISPATCHES["fedavg_masked_shards"] += d
    STAGED["fedavg_masked"] += int(mask.size)
    if impl == "auto":
        impl = ("pallas" if (_on_tpu() or params.shape[-1] // d >= 4096)
                else "naive")
    return _sharded_agg_call(mesh, "masked", impl)(params, weights, mask, prev)
