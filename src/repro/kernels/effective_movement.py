"""Fused effective-movement accumulation as a Pallas TPU kernel.

The paper's block-freezing metric (§3.3) needs, per evaluation step, for a
block's flattened parameter vector:

    net'      = net + (p_new - p_old)        (vector, written back)
    path_inc  = Σ |p_new - p_old|            (scalar)
    net_abs   = Σ |net'|                     (scalar)

Done naively this is 4 HBM passes over the block (read p_new, p_old, net;
write net; two reductions).  The kernel fuses everything into ONE tiled pass:
each grid step stages a [bt] tile of the three vectors into VMEM, writes the
updated net tile, and emits per-tile partial sums which are reduced outside
(tiny [n_tiles] arrays).  On the server this runs over every scalar of the
active block each round, so the fusion matters at 100B-parameter scale.

Oracle: kernels/ref.py::effective_movement_update.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_util import default_interpret


def _em_kernel(pn_ref, po_ref, net_ref, net_out_ref, path_ref, netabs_ref):
    u = pn_ref[...].astype(jnp.float32) - po_ref[...].astype(jnp.float32)
    net_new = net_ref[...].astype(jnp.float32) + u
    net_out_ref[...] = net_new
    path_ref[0] = jnp.sum(jnp.abs(u))
    netabs_ref[0] = jnp.sum(jnp.abs(net_new))


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def effective_movement_update(
    p_new: jax.Array,  # [n]
    p_old: jax.Array,  # [n]
    net: jax.Array,  # [n] float32
    *,
    bt: int = 65536,
    interpret: bool | None = None,
):
    """Returns (net_new [n] f32, path_inc scalar f32, net_abs scalar f32).

    ``interpret=None`` resolves platform-aware: compiled on TPU, interpret
    mode on every other backend."""
    if interpret is None:
        interpret = default_interpret()
    (n,) = p_new.shape
    bt = min(bt, n)
    pad = (-n) % bt
    if pad:
        p_new = jnp.pad(p_new, (0, pad))
        p_old = jnp.pad(p_old, (0, pad))
        net = jnp.pad(net, (0, pad))
    nt = (n + pad) // bt
    net_new, path_p, netabs_p = pl.pallas_call(
        _em_kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((nt,), jnp.float32),
            jax.ShapeDtypeStruct((nt,), jnp.float32),
        ],
        interpret=interpret,
    )(p_new, p_old, net)
    return net_new[:n], jnp.sum(path_p), jnp.sum(netabs_p)
