"""Flash attention forward as a Pallas TPU kernel.

Block-wise online-softmax attention (Rabe & Staats / FlashAttention) adapted
to the TPU memory hierarchy:

* grid = (B, H, nQ, nKV) — the innermost grid dim walks KV blocks so the
  running (m, l, acc) scratch lives in VMEM across KV steps;
* BlockSpecs stage [bq, hd] query tiles and [bk, hd] key/value tiles
  HBM→VMEM; hd is the lane dim (128-aligned for the MXU), bq/bk the sublane;
* GQA is handled in the index_map (kv head = q head // group) — no
  materialized head repetition;
* causal + sliding-window masks are applied from block coordinates; fully
  masked KV blocks still iterate but short-circuit the FLOPs via pl.when.

Validated in interpret mode on CPU against kernels/ref.py::attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_util import default_interpret

NEG_INF = -1e30


def _fwd_kernel(
    q_ref,  # [1, 1, bq, hd]
    k_ref,  # [1, 1, bk, hd]
    v_ref,  # [1, 1, bk, hd]
    o_ref,  # [1, 1, bq, hd]
    m_ref,  # scratch [bq, 1] running max
    l_ref,  # scratch [bq, 1] running denom
    acc_ref,  # scratch [bq, hd] running numerator
    *,
    bq: int,
    bk: int,
    n_kv: int,
    causal: bool,
    window: int,
    sm_scale: float,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = iq * bq
    k_lo = ik * bk
    # block-level reachability: any (row, col) with row >= col (causal) and
    # col > row - window can exist in this tile pair?
    live = True
    if causal:
        live = q_lo + bq - 1 >= k_lo  # some row can see some col
    if window > 0:
        live = jnp.logical_and(live, k_lo + bk - 1 > q_lo - window)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [bq, bk]
        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask &= rows >= cols
        if window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # rescale of old accumulators
        p = jnp.exp(s - m_new)  # [bq, bk]
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros, not NaN
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret")
)
def flash_attention_fwd(
    q: jax.Array,  # [B, H, S, hd]
    k: jax.Array,  # [B, K, S, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = 256,
    bk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:  # platform-aware: compile on TPU, interpret elsewhere
        interpret = default_interpret()
    B, H, Sq, hd = q.shape
    Kh, Skv = k.shape[1], k.shape[2]
    g = H // Kh
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    if Sq % bq or Skv % bk:
        raise ValueError(f"seq lens ({Sq},{Skv}) must divide blocks ({bq},{bk})")
    n_q, n_kv = Sq // bq, Skv // bk
    grid = (B, H, n_q, n_kv)

    kern = functools.partial(
        _fwd_kernel,
        bq=bq,
        bk=bk,
        n_kv=n_kv,
        causal=causal,
        window=window,
        sm_scale=1.0 / (hd**0.5),
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            _vmem((bq, 1), jnp.float32),  # m
            _vmem((bq, 1), jnp.float32),  # l
            _vmem((bq, hd), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
