"""Shared helpers for the Pallas kernels in this package."""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Platform-aware ``interpret`` default for every Pallas kernel: compile
    on a real TPU backend, interpret mode everywhere else.  Single source of
    truth — kernels resolve ``interpret=None`` through this."""
    return jax.default_backend() != "tpu"
