"""Loss + train-step builders (full model; the progressive per-block step is
assembled in core/progressive.py from the same primitives)."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.train.optimizer import Optimizer

MOE_AUX_COEF = 0.01


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits [..., V] (any dtype), labels [...] int. Mean f32 xent."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def blockwise_lm_xent(
    cfg: ArchConfig,
    head_w: jax.Array,  # [D, V]
    x: jax.Array,  # [B, S', D] final-norm'ed hidden
    tokens: jax.Array,  # [B, S]
    n_prefix: int,
    *,
    chunk: int = 2048,
) -> jax.Array:
    """Next-token xent with the [B, S, V] logits computed CHUNK-AT-A-TIME
    over the sequence inside a checkpointed scan — the full f32 logits tensor
    (the dominant train-step temp at 100k+ vocab) never materializes
    (EXPERIMENTS.md §Perf i4)."""
    x_tok = x[:, n_prefix:][:, :-1]
    labels = tokens[:, 1:]
    B, S, Dm = x_tok.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x_tok = jnp.pad(x_tok, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    n = (S + pad) // chunk
    xc = x_tok.reshape(B, n, chunk, Dm).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(acc, args):
        ci, xb, lb = args
        logits = xb @ head_w.astype(xb.dtype)  # [B, chunk, V]
        if cfg.logit_soft_cap > 0:
            logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, lb[..., None], axis=-1)[..., 0]
        valid = (ci * chunk + jnp.arange(chunk))[None, :] < S
        return acc + jnp.sum(jnp.where(valid, lse - ll, 0.0)), None

    total, _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False),
        jnp.zeros((), jnp.float32), (jnp.arange(n), xc, lc),
    )
    return total / (B * S)


def head_weights(cfg: ArchConfig, params: dict) -> jax.Array:
    return params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]["w"]


def make_loss_fn(
    cfg: ArchConfig,
    *,
    remat: bool = True,
    window_override: Optional[int] = None,
) -> Callable:
    """Next-token LM loss over the token part of the sequence (frontend
    prefix tokens excluded)."""
    from repro.models.layers import apply_norm

    def loss_fn(params, batch):
        x, aux, npre = T.forward_hidden(
            cfg, params, batch, remat=remat, window_override=window_override
        )
        x = apply_norm(cfg, params["final_norm"], x)
        loss = blockwise_lm_xent(cfg, head_weights(cfg, params), x,
                                 batch["tokens"], npre)
        return loss + MOE_AUX_COEF * aux, {"xent": loss, "moe_aux": aux}

    return loss_fn


def init_train_state(cfg: ArchConfig, params, opt: Optimizer, mask=None) -> dict:
    return {"params": params, "opt": opt.init(params, mask), "step": jnp.zeros((), jnp.int32)}


def make_train_step(
    cfg: ArchConfig,
    opt: Optimizer,
    *,
    remat: bool = True,
    window_override: Optional[int] = None,
) -> Callable[[dict, dict], tuple]:
    loss_fn = make_loss_fn(cfg, remat=remat, window_override=window_override)

    def train_step(state: dict, batch: dict):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch
        )
        new_params, new_opt = opt.update(
            grads, state["opt"], state["params"], state["step"]
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step
