"""Numpy .npz checkpointing of arbitrary pytrees (no orbax in container).

Leaves are flattened with their tree paths as keys, so a checkpoint can be
restored into any structurally-identical tree and partially loaded (e.g. the
ProFL shrinking stage saves per-block init params that the growing stage
loads block-by-block).  Flat-dict states (the engine's int8 error-feedback
tree, the async server's buffer from
``fl/async_server.py::async_state_to_tree``) round-trip as-is — their keys
are already path strings; :func:`subtree` slices one component back out of
a combined checkpoint.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez_compressed(path, **_flatten(tree))


def subtree(flat: dict, prefix: str) -> dict:
    """Slice one namespaced component out of a flat ``{path: array}``
    checkpoint dict: keys under ``"<prefix>/"`` come back with the prefix
    stripped (e.g. ``subtree(load(p), "async")`` recovers exactly what
    ``save(p, {"async": state, ...})`` stored for it)."""
    pre = prefix + "/"
    return {k[len(pre):]: v for k, v in flat.items() if k.startswith(pre)}


def load(path: str, like: Optional[PyTree] = None) -> PyTree:
    """Restore; if ``like`` is given, reshape into its structure (and cast to
    its dtypes). Otherwise returns the flat {path: array} dict."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    if like is None:
        return flat
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves_p:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        out.append(jnp.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)
