"""Optimizers with frozen-leaf masking (no flax/optax dependency).

The ProFL memory claim hinges on frozen blocks carrying NO optimizer state:
``init(params, mask)`` allocates moments only for trainable leaves (frozen
leaves get a zero-size placeholder so the pytree structure stays static),
and ``update`` returns zero updates for them.  This is what turns "freeze
the prefix" into actual HBM savings in the compiled step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree, Optional[PyTree]], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array], tuple]
    # update(grads, opt_state, params, step) -> (new_params, new_opt_state)


def _mask_tree(params: PyTree, mask: Optional[PyTree]) -> PyTree:
    if mask is None:
        return jax.tree.map(lambda _: True, params)
    return mask


_EMPTY = None  # placeholder for frozen-leaf state


def _zeros_if(flag: bool, leaf):
    return jnp.zeros_like(leaf, dtype=jnp.float32) if flag else jnp.zeros((0,), jnp.float32)


def sgd(lr: float, momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params, mask=None):
        m = _mask_tree(params, mask)
        if momentum == 0.0:
            return jax.tree.map(lambda p: jnp.zeros((0,), jnp.float32), params)
        return jax.tree.map(_zeros_if, m, params)

    def update(grads, state, params, step):
        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            if weight_decay:
                gf = gf + weight_decay * p.astype(jnp.float32)
            if momentum and s.size:
                s = momentum * s + gf
                d = s
            else:
                d = gf
            trainable = (s.size > 0) or momentum == 0.0
            newp = p - (lr * d).astype(p.dtype) if trainable else p
            return newp, s

        out = jax.tree.map(upd, grads, state, params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, new_state

    return Optimizer(init, update)


def masked_sgd(lr: float) -> Optimizer:
    """Plain SGD that respects a trainable mask captured in the state tree.
    State per leaf: f32 scalar 1.0 (trainable) / 0.0 (frozen)."""

    def init(params, mask=None):
        m = _mask_tree(params, mask)
        return jax.tree.map(lambda flag: jnp.float32(1.0 if flag else 0.0), m)

    def update(grads, state, params, step):
        new_params = jax.tree.map(
            lambda g, s, p: p - (lr * s * g.astype(jnp.float32)).astype(p.dtype),
            grads, state, params,
        )
        return new_params, state

    return Optimizer(init, update)


@dataclass(frozen=True)
class AdamWCfg:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup: int = 100
    grad_clip: float = 1.0


def adamw(cfg: AdamWCfg) -> Optimizer:
    """AdamW with linear warmup + masked state: frozen leaves hold zero-size
    moments and receive no update (and no HBM)."""

    def init(params, mask=None):
        m = _mask_tree(params, mask)
        return {
            "mu": jax.tree.map(_zeros_if, m, params),
            "nu": jax.tree.map(_zeros_if, m, params),
        }

    def update(grads, state, params, step):
        lr = cfg.lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup, 1))
        # global grad clip over trainable leaves
        sq = sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g, mu in zip(jax.tree.leaves(grads), jax.tree.leaves(state["mu"]))
            if mu.size
        )
        gnorm = jnp.sqrt(jnp.maximum(sq, 1e-12))
        scale = jnp.minimum(1.0, cfg.grad_clip / gnorm) if cfg.grad_clip else 1.0

        bc1 = 1.0 - cfg.b1 ** (step + 1)
        bc2 = 1.0 - cfg.b2 ** (step + 1)

        def upd(g, mu, nu, p):
            if mu.size == 0:  # frozen
                return p, mu, nu
            gf = g.astype(jnp.float32) * scale
            mu = cfg.b1 * mu + (1 - cfg.b1) * gf
            nu = cfg.b2 * nu + (1 - cfg.b2) * gf * gf
            d = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
            d = d + cfg.weight_decay * p.astype(jnp.float32)
            return (p - (lr * d).astype(p.dtype)), mu, nu

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        is3 = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
        return new_params, {"mu": new_mu, "nu": new_nu}

    return Optimizer(init, update)
