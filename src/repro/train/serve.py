"""Serving path: KV/SSM cache construction, prefill, single-token decode.

Semantics
---------
* ``init_cache(cfg, B, cache_len)`` builds the per-slot decode state with
  capacity ``C``: attention slots get rotating-window or linear KV buffers
  ``[G, B, Kh, C, hd]``; mamba/rwkv slots get O(1) recurrent states.
* ``prefill`` runs the full sequence, returns ``(logits, cache, pos)``.
* ``decode_step`` consumes ONE token at global position ``pos`` (scalar),
  writes its k/v into the cache (slot ``pos % W`` for windowed attention)
  and returns next-token logits — this is the ``serve_step`` lowered by the
  decode_32k / long_500k dry-run shapes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.kernels import ops
from repro.launch import sharding
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import transformer as T


def _attn_window(cfg: ArchConfig, cache_len: int, window: Optional[int]) -> int:
    """Effective attention window for a given cache capacity. 0 = linear
    (non-rotating) cache."""
    if window is not None:
        return window
    return cfg.sliding_window


# ===========================================================================
# cache init
# ===========================================================================


def init_cache(
    cfg: ArchConfig,
    batch: int,
    cache_len: int,
    *,
    window: Optional[int] = None,
    dtype=None,
) -> list:
    """Per-slot stacked decode state ([G, ...] leaves)."""
    dt = dtype or jnp.dtype(cfg.param_dtype)
    G = cfg.n_groups
    w = _attn_window(cfg, cache_len, window)
    C = min(cache_len, w) if w > 0 else cache_len
    Kh, hd = cfg.n_kv_heads, cfg.head_dim
    out = []
    for spec in cfg.pattern:
        c: dict = {}
        if spec.mixer == "attn":
            c["k"] = jnp.zeros((G, batch, Kh, C, hd), dt)
            c["v"] = jnp.zeros((G, batch, Kh, C, hd), dt)
            if cfg.encoder is not None:
                F = cfg.encoder.n_frames
                c["cross_k"] = jnp.zeros((G, batch, Kh, F, hd), dt)
                c["cross_v"] = jnp.zeros((G, batch, Kh, F, hd), dt)
        elif spec.mixer == "mamba":
            st = S.mamba_state_init(cfg, cfg.ssm, batch, dt)
            c["mamba"] = jax.tree.map(lambda x: jnp.broadcast_to(x, (G, *x.shape)), st)
        elif spec.mixer == "rwkv":
            st = S.rwkv_state_init(cfg, cfg.rwkv, batch, dt)
            c["rwkv"] = jax.tree.map(lambda x: jnp.broadcast_to(x, (G, *x.shape)), st)
        if spec.ffn == "rwkv_cm":
            c["cm_x_prev"] = jnp.zeros((G, batch, 1, cfg.d_model), dt)
        out.append(c)
    return out


def cache_shardings(cfg: ArchConfig, env, cache) -> list:
    """KV heads over 'model' (GQA kv=8 == mesh model dim fits), batch over
    dp; recurrent states: inner channel dim over 'model'."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def spec(path, leaf):
        name = sharding._path_str(path)
        nd = leaf.ndim
        if name.endswith("/k") or name.endswith("/v") or "cross_" in name:
            return P(None, env.dp_axes, "model", None, None)
        if "mamba/h" in name:
            return P(None, env.dp_axes, "model", None)
        if "mamba/conv" in name:
            return P(None, env.dp_axes, None, "model")
        if "rwkv/S" in name:
            return P(None, env.dp_axes, "model", None, None)
        return P(None, env.dp_axes, *([None] * (nd - 2)))

    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(env.mesh, spec(p, x)), cache
    )


# ===========================================================================
# prefill
# ===========================================================================


def _store_kv(k, v, C: int, w: int):
    """k/v [B,Kh,S,hd] -> cache [B,Kh,C,hd] (rotated when windowed)."""
    B, Kh, Sq, hd = k.shape
    if w > 0 and Sq > C:
        k, v = k[:, :, -C:], v[:, :, -C:]
        pos0 = Sq - C
        slots = (pos0 + jnp.arange(C)) % C
        ck = jnp.zeros((B, Kh, C, hd), k.dtype).at[:, :, slots].set(k)
        cv = jnp.zeros((B, Kh, C, hd), v.dtype).at[:, :, slots].set(v)
        return ck, cv
    if Sq < C:
        pad = C - Sq
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return k, v


def _prefill_layer(cfg, spec: LayerSpec, p, x, positions, enc, C: int, w: int):
    """Mirror of transformer.apply_layer that also emits the decode state."""
    cache: dict = {}
    if cfg.parallel_block and spec.mixer == "attn":
        h = L.apply_norm(cfg, p["norm1"], x)
        q, k, v = L.qkv_project(cfg, p["attn"], h, positions)
        o = ops.attention(q, k, v, causal=True, window=w)
        a = L.attn_out(cfg, p["attn"], o)
        f = L.apply_mlp(cfg, p["ffn"], h)
        cache["k"], cache["v"] = _store_kv(k, v, C, w)
        return sharding.constrain_hidden(x + a + f), cache

    if spec.mixer == "attn":
        h = L.apply_norm(cfg, p["norm1"], x)
        q, k, v = L.qkv_project(cfg, p["attn"], h, positions)
        o = ops.attention(q, k, v, causal=True, window=w)
        x = x + L.attn_out(cfg, p["attn"], o)
        cache["k"], cache["v"] = _store_kv(k, v, C, w)
        if enc is not None and "cross" in p:
            hc = L.apply_norm(cfg, p["norm_cross"], x)
            x = x + L.cross_attention(cfg, p["cross"], hc, enc)
            B, F = enc.shape[0], enc.shape[1]
            Kh, hd = cfg.n_kv_heads, cfg.head_dim
            ck = enc @ p["cross"]["wk"]
            cv = enc @ p["cross"]["wv"]
            if cfg.qkv_bias:
                ck, cv = ck + p["cross"]["bk"], cv + p["cross"]["bv"]
            cache["cross_k"] = ck.reshape(B, F, Kh, hd).transpose(0, 2, 1, 3)
            cache["cross_v"] = cv.reshape(B, F, Kh, hd).transpose(0, 2, 1, 3)
    elif spec.mixer == "mamba":
        h = L.apply_norm(cfg, p["norm1"], x)
        y, st = S.mamba_forward(cfg, cfg.ssm, p["mamba"], h, return_state=True)
        x = x + y
        cache["mamba"] = st
    elif spec.mixer == "rwkv":
        h = L.apply_norm(cfg, p["norm1"], x)
        y, st = S.rwkv_forward(cfg, cfg.rwkv, p["rwkv"], h, return_state=True)
        x = x + y
        cache["rwkv"] = st

    if spec.ffn == "dense":
        x = x + L.apply_mlp(cfg, p["ffn"], L.apply_norm(cfg, p["norm2"], x))
    elif spec.ffn == "moe":
        y, _ = M.apply_moe(cfg, cfg.moe, p["moe"], L.apply_norm(cfg, p["norm2"], x))
        x = x + y
    elif spec.ffn == "rwkv_cm":
        h = L.apply_norm(cfg, p["norm2"], x)
        x = x + S.rwkv_cm_forward(cfg, p["rwkv_cm"], h)
        cache["cm_x_prev"] = h[:, -1:]
    return sharding.constrain_hidden(x), cache


def prefill(
    cfg: ArchConfig,
    params: dict,
    batch: dict,
    *,
    cache_len: Optional[int] = None,
    window: Optional[int] = None,
):
    """Full-sequence forward emitting the decode cache.
    Returns (last-token logits [B, V], cache, next position scalar)."""
    x, positions, n_prefix = T.embed_inputs(cfg, params, batch)
    Sq = x.shape[1]
    C_total = cache_len or Sq
    w = _attn_window(cfg, C_total, window)
    C = min(C_total, w) if w > 0 else C_total
    enc = None
    if cfg.encoder is not None:
        enc = T.encode(cfg, params, batch["frames"])

    def body(x, slot_params):
        caches = []
        for spec, p in zip(cfg.pattern, slot_params):
            x, c = _prefill_layer(cfg, spec, p, x, positions, enc, C, w)
            caches.append(c)
        return x, tuple(caches)

    x, stacked = jax.lax.scan(body, x, tuple(params["layers"]))
    cache = list(stacked)
    logits = T.logits_from_hidden(cfg, params, x[:, -1:])
    return logits[:, 0], cache, jnp.int32(Sq)


# ===========================================================================
# decode
# ===========================================================================


def decode_step(
    cfg: ArchConfig,
    params: dict,
    cache: list,
    tokens: jax.Array,  # [B] int32 — the token being decoded
    pos: jax.Array,  # scalar int32 global position of this token
    *,
    window: Optional[int] = None,
):
    """One-token serve step. Returns (logits [B, V], new_cache)."""
    C = 0
    for c in cache:
        if "k" in c:
            C = c["k"].shape[3]
            break
    w = _attn_window(cfg, C, window)
    x = params["embed"]["tok"][tokens][:, None]  # [B,1,D]
    if cfg.learned_pos:
        x = x + params["embed"]["pos"][pos][None, None].astype(x.dtype)
    x = sharding.constrain_hidden(x)

    def body(x, xs):
        slot_params, slot_cache = xs
        new_caches = []
        for spec, p, c in zip(cfg.pattern, slot_params, slot_cache):
            x, nc = T.decode_layer_step(cfg, spec, p, x, c, pos, w)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_stacked = jax.lax.scan(
        body, x, (tuple(params["layers"]), tuple(cache))
    )
    logits = T.logits_from_hidden(cfg, params, x)
    return logits[:, 0], list(new_stacked)
