"""Effective movement (paper §3.3) + block-freezing determination.

Per evaluation step k, for the active block's flattened scalars:

    U_k       = p_k - p_{k-1}
    net_H     = Σ_{h<H} U_{k-h}          (windowed net movement per scalar)
    EM_k      = Σ_s |net_H,s|  /  Σ_s Σ_{h<H} |U_{k-h,s}|   ∈ [0, 1]

EM ≈ 1 while scalars move consistently toward the optimum; EM → 0 when they
oscillate around it.  The server fits a least-squares line to the EM series
and freezes the block once the |slope| stays below φ for W consecutive
evaluations (with EM itself below an absolute level, so the high flat EM of
early training does not trigger).

Implementation: tumbling windows of H updates with an O(1)-memory net-
movement accumulator, maintained by the fused Pallas pass
(kernels/effective_movement.py) — one HBM sweep per round per block.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def flatten_params(tree) -> jax.Array:
    leaves = [jnp.ravel(x) for x in jax.tree.leaves(tree)]
    return jnp.concatenate(leaves) if leaves else jnp.zeros((0,))


@dataclass
class EMConfig:
    window_h: int = 5  # H: updates per EM window
    slope_phi: float = 0.004  # φ: |slope| threshold
    patience_w: int = 3  # W: consecutive below-threshold evals to freeze
    fit_points: int = 6  # EM points used in the least-squares fit
    em_level: float = 0.5  # EM must also be below this absolute level
    min_rounds: int = 10  # don't freeze before this many rounds


@dataclass
class EMState:
    prev: jax.Array  # p_{k-1} flattened
    net: jax.Array  # running Σ U within the current window (f32)
    path: float = 0.0  # running Σ|U| within the current window
    k: int = 0  # updates seen in the current window
    history: List[float] = field(default_factory=list)  # EM per window
    rounds: int = 0
    below: int = 0  # consecutive below-threshold evaluations


def em_init(params) -> EMState:
    p = flatten_params(params)
    return EMState(prev=p, net=jnp.zeros_like(p, jnp.float32))


def em_update(cfg: EMConfig, st: EMState, params) -> Optional[float]:
    """Feed one aggregated update (as a tree); returns the EM value when a
    window completes, else None."""
    return em_update_flat(cfg, st, flatten_params(params))


def em_update_flat(cfg: EMConfig, st: EMState, p_new: jax.Array) -> Optional[float]:
    """Same as :func:`em_update`, but takes the round's aggregated params as
    an already-packed flat vector — the sharded engine (fl/engine.py) hands
    this straight from its Pallas fedavg output, so the EM bookkeeping is one
    fused ``effective_movement_update`` pass with no per-leaf re-flattening."""
    net, path_inc, net_abs = ops.effective_movement_update(p_new, st.prev, st.net)
    st.prev = p_new
    st.net = net
    st.path += float(path_inc)
    st.k += 1
    st.rounds += 1
    if st.k < cfg.window_h:
        return None
    em = float(net_abs) / max(st.path, 1e-12)
    st.history.append(em)
    st.net = jnp.zeros_like(st.net)
    st.path = 0.0
    st.k = 0
    return em


def slope(history: List[float], n: int) -> float:
    """Least-squares slope over the last n EM points (paper: linear
    least-squares regression [36])."""
    ys = np.asarray(history[-n:], dtype=np.float64)
    if len(ys) < 2:
        return float("inf")
    xs = np.arange(len(ys), dtype=np.float64)
    xm, ym = xs.mean(), ys.mean()
    denom = ((xs - xm) ** 2).sum()
    return float(((xs - xm) * (ys - ym)).sum() / max(denom, 1e-12))


def should_freeze(cfg: EMConfig, st: EMState) -> bool:
    """Called after each em_update that produced a window value."""
    if st.rounds < cfg.min_rounds or len(st.history) < 2:
        return False
    s = slope(st.history, cfg.fit_points)
    if abs(s) < cfg.slope_phi and st.history[-1] < cfg.em_level:
        st.below += 1
    else:
        st.below = 0
    return st.below >= cfg.patience_w
