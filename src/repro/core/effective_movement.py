"""Effective movement (paper §3.3) + block-freezing determination.

Per evaluation step k, for the active block's flattened scalars:

    U_k       = p_k - p_{k-1}
    net_H     = Σ_{h<H} U_{k-h}          (windowed net movement per scalar)
    EM_k      = Σ_s |net_H,s|  /  Σ_s Σ_{h<H} |U_{k-h,s}|   ∈ [0, 1]

EM ≈ 1 while scalars move consistently toward the optimum; EM → 0 when they
oscillate around it.  The server fits a least-squares line to the EM series
and freezes the block once the |slope| stays below φ for W consecutive
evaluations (with EM itself below an absolute level, so the high flat EM of
early training does not trigger).

Implementation: tumbling windows of H updates with an O(1)-memory net-
movement accumulator, maintained by the fused Pallas pass
(kernels/effective_movement.py) — one HBM sweep per round per block.

Host-sync discipline: ``em_update_flat`` keeps ``path``/``net`` as DEVICE
scalars across the window and reads them back with one explicit
``jax.device_get`` only when the window closes (``k == window_h``) — a
mid-window round issues no device→host transfer at all, so EM bookkeeping
composes with the engine's one-``block_until_ready``-per-round contract
(asserted under ``jax.transfer_guard('disallow')`` in tests/test_core.py).

:class:`FreezeTracker` runs the same machinery per BLOCK over stable column
ids of the packed trainable vector (fl/engine.py::columns_for_paths) and
reports newly frozen blocks — the decision the engine's freezing-aware
layouts (``grouped_round(frozen=...)``) consume to shrink the panel.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def flatten_params(tree) -> jax.Array:
    leaves = [jnp.ravel(x) for x in jax.tree.leaves(tree)]
    return jnp.concatenate(leaves) if leaves else jnp.zeros((0,))


@dataclass
class EMConfig:
    window_h: int = 5  # H: updates per EM window
    slope_phi: float = 0.004  # φ: |slope| threshold
    patience_w: int = 3  # W: consecutive below-threshold evals to freeze
    fit_points: int = 6  # EM points used in the least-squares fit
    em_level: float = 0.5  # EM must also be below this absolute level
    min_rounds: int = 10  # don't freeze before this many rounds


@dataclass
class EMState:
    prev: jax.Array  # p_{k-1} flattened
    net: jax.Array  # running Σ U within the current window (f32)
    path: float = 0.0  # running Σ|U| (a DEVICE scalar mid-window)
    k: int = 0  # updates seen in the current window
    history: List[float] = field(default_factory=list)  # EM per window,
    # trimmed by em_update_flat to the max(fit_points, 2) entries slope and
    # should_freeze actually read, so a long run can't grow it unboundedly
    rounds: int = 0
    below: int = 0  # consecutive below-threshold evaluations


def em_init(params) -> EMState:
    p = flatten_params(params)
    return EMState(prev=p, net=jnp.zeros_like(p, jnp.float32))


def em_update(cfg: EMConfig, st: EMState, params) -> Optional[float]:
    """Feed one aggregated update (as a tree); returns the EM value when a
    window completes, else None."""
    return em_update_flat(cfg, st, flatten_params(params))


def em_update_flat(cfg: EMConfig, st: EMState, p_new: jax.Array) -> Optional[float]:
    """Same as :func:`em_update`, but takes the round's aggregated params as
    an already-packed flat vector — the sharded engine (fl/engine.py) hands
    this straight from its Pallas fedavg output, so the EM bookkeeping is one
    fused ``effective_movement_update`` pass with no per-leaf re-flattening.

    Mid-window rounds accumulate ``path`` as a DEVICE scalar (``0.0 + array``
    promotes on the first update) and return without any device→host
    transfer; the one explicit ``jax.device_get`` happens at window close,
    batched over ``(path, net_abs)``."""
    net, path_inc, net_abs = ops.effective_movement_update(p_new, st.prev, st.net)
    st.prev = p_new
    st.net = net
    # device-scalar accumulation, no transfer in either direction: the
    # window's first update ADOPTS the device increment (st.path is the
    # python-float 0.0 placeholder then), later updates add device-to-device
    st.path = path_inc if st.k == 0 else st.path + path_inc
    st.k += 1
    st.rounds += 1
    if st.k < cfg.window_h:
        return None
    path_v, net_v = jax.device_get((st.path, net_abs))
    em = float(net_v) / max(float(path_v), 1e-12)
    st.history.append(em)
    maxlen = max(cfg.fit_points, 2)
    if len(st.history) > maxlen:
        del st.history[: len(st.history) - maxlen]
    st.net = jnp.zeros_like(st.net)
    st.path = 0.0
    st.k = 0
    return em


def slope(history: List[float], n: int) -> float:
    """Least-squares slope over the last n EM points (paper: linear
    least-squares regression [36])."""
    ys = np.asarray(history[-n:], dtype=np.float64)
    if len(ys) < 2:
        return float("inf")
    xs = np.arange(len(ys), dtype=np.float64)
    xm, ym = xs.mean(), ys.mean()
    denom = ((xs - xm) ** 2).sum()
    return float(((xs - xm) * (ys - ym)).sum() / max(denom, 1e-12))


def should_freeze(cfg: EMConfig, st: EMState) -> bool:
    """Called after each em_update that produced a window value."""
    if st.rounds < cfg.min_rounds or len(st.history) < 2:
        return False
    s = slope(st.history, cfg.fit_points)
    if abs(s) < cfg.slope_phi and st.history[-1] < cfg.em_level:
        st.below += 1
    else:
        st.below = 0
    return st.below >= cfg.patience_w


def em_state_to_tree(st: EMState) -> dict:
    """Checkpointable pytree view of an EMState (train/checkpoint.py::save
    takes it directly).  ``below`` and ``history`` ride along so a freeze
    decision — patience already accumulated, slope-fit window — survives a
    checkpoint round-trip instead of resetting to zero on restore."""
    return {
        "prev": st.prev,
        "net": st.net,
        "path": jnp.asarray(st.path, jnp.float32),
        "k": np.int64(st.k),
        "rounds": np.int64(st.rounds),
        "below": np.int64(st.below),
        "history": np.asarray(st.history, np.float64),
    }


def em_state_from_tree(tree: Mapping) -> EMState:
    """Inverse of :func:`em_state_to_tree`; accepts the flat dict
    ``train/checkpoint.py::load`` returns for a saved EM state."""
    return EMState(
        prev=jnp.asarray(tree["prev"]),
        net=jnp.asarray(tree["net"], jnp.float32),
        path=float(np.asarray(tree["path"])),
        k=int(np.asarray(tree["k"])),
        rounds=int(np.asarray(tree["rounds"])),
        below=int(np.asarray(tree["below"])),
        history=[float(v) for v in np.asarray(tree["history"]).reshape(-1)],
    )


class FreezeTracker:
    """Per-BLOCK freeze determination over a packed flat trainable vector.

    ``blocks`` maps a block name (conventionally the leaf-path prefix the
    engine's :func:`repro.fl.engine.columns_for_paths` resolved) to the
    block's STABLE column ids in the packed vector.  Each round,
    :meth:`update` slices every still-live block out of the aggregated flat
    vector DEVICE-side, feeds its own :class:`EMState`, and returns the
    names that crossed :func:`should_freeze` this round — the caller turns
    those into a frozen-column epoch
    (``repro.fl.engine.frozen_columns_for_paths``) for the next
    ``grouped_round(frozen=...)``.

    The first ``update`` call only records the baseline (``em_init``
    semantics); sub-vector slicing is async like the EM update itself, so a
    mid-window round still performs no host sync."""

    def __init__(self, cfg: EMConfig, blocks: Mapping[str, np.ndarray]):
        self.cfg = cfg
        self.blocks: Dict[str, np.ndarray] = {
            name: np.asarray(cols, np.int64).reshape(-1)
            for name, cols in blocks.items()
        }
        self._cols_dev = {
            name: jnp.asarray(cols) for name, cols in self.blocks.items()
        }
        self.states: Dict[str, EMState] = {}
        self.frozen: Dict[str, bool] = {name: False for name in self.blocks}

    @property
    def frozen_names(self) -> List[str]:
        return [name for name, f in self.frozen.items() if f]

    def update(self, flat: jax.Array) -> List[str]:
        """Feed one round's aggregated flat trainable vector; returns the
        block names newly frozen by this round's window (usually [])."""
        newly = []
        for name, cols in self._cols_dev.items():
            if self.frozen[name]:
                continue
            sub = jnp.take(flat, cols)
            st = self.states.get(name)
            if st is None:
                self.states[name] = EMState(
                    prev=sub, net=jnp.zeros_like(sub, jnp.float32)
                )
                continue
            em = em_update_flat(self.cfg, st, sub)
            if em is not None and should_freeze(self.cfg, st):
                self.frozen[name] = True
                newly.append(name)
        return newly
