"""Progressive training paradigm (paper §3.1–3.2): sub-model assembly for
both stages, and train-step factories.

A step-``t`` sub-model is  [frozen prefix 0..t-1 | active block t | θ_op]:

* **shrinking** (t = T-1 → 1): the prefix is frozen at its *initial* values;
  after block t converges its params become θ_t^ini and the block is
  distilled into proxy_t (core/distill.py), which then serves inside θ_op of
  step t-1 — and later inside θ_op of growing step t-1.
* **growing** (t = 0 → T-1): the prefix is frozen at its *converged* values;
  block t is initialized from θ_t^ini; θ_op reuses the shrinking proxies.

The frozen prefix runs under ``stop_gradient`` with remat disabled — XLA
DCEs its saved residuals, so no backward pass and no stored activations:
this is exactly the paper's memory saving, visible in the compiled
``memory_analysis()`` (EXPERIMENTS.md §Dry-run).

Both the transformer path (at-scale, pjit) and the CNN path (the paper's
faithful FL simulation) are built here from the same blocks/output-module
machinery.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax

from repro.configs.base import ArchConfig
from repro.core import blocks as B
from repro.core import output_module as OM
from repro.models import cnn as C
from repro.models import transformer as T
from repro.train.optimizer import Optimizer
from repro.train.train_step import MOE_AUX_COEF, softmax_xent

sg = jax.lax.stop_gradient


# ===========================================================================
# Transformer sub-model
# ===========================================================================


def submodel_init(cfg: ArchConfig, params: dict, rng, t: int) -> Tuple[dict, dict]:
    """(frozen, trainable) trees for step t. trainable = {'active', 'op'}."""
    frozen, active = B.split_model(cfg, params, t)
    op = OM.init_tf_output_module(cfg, rng, t, params)
    return frozen, {"active": active, "op": op}


def submodel_forward(
    cfg: ArchConfig,
    frozen: dict,
    trainable: dict,
    batch: dict,
    t: int,
    *,
    remat_active: bool = True,
    window_override: Optional[int] = None,
    return_hidden: bool = False,
):
    """Forward of the step-t sub-model. Returns (logits_or_hidden, moe_aux,
    n_prefix); with ``return_hidden`` the output-module proxies + final norm
    are applied but the LM-head matmul is left to the (blockwise) loss."""
    fro = jax.tree.map(sg, frozen)
    active, op = trainable["active"], trainable["op"]
    stem = active if t == 0 else fro  # embed/projector/encoder owner

    x, positions, n_prefix = T.embed_inputs(cfg, stem, batch)
    enc = None
    if cfg.encoder is not None:
        enc = T.encode(cfg, stem, batch["frames"])

    if fro["layers"] and fro["layers"][0]:
        n_frozen_groups = jax.tree.leaves(fro["layers"][0])[0].shape[0]
    else:
        n_frozen_groups = 0
    if n_frozen_groups:
        # frozen prefix: no remat — stop_gradient means XLA keeps nothing
        x, _ = T.run_layers(
            cfg, fro["layers"], x, positions, enc,
            remat=False, window_override=window_override,
        )
        x = sg(x)
    x, aux = T.run_layers(
        cfg, active["layers"], x, positions, enc,
        remat=remat_active, window_override=window_override,
    )
    if return_hidden:
        return OM.apply_tf_output_module_hidden(cfg, op, x), aux, n_prefix
    embed_tok = stem["embed"]["tok"] if cfg.tie_embeddings else None
    logits = OM.apply_tf_output_module(cfg, op, x, embed_tok)
    return logits, aux, n_prefix


def make_progressive_loss(
    cfg: ArchConfig, t: int, *, window_override: Optional[int] = None
) -> Callable:
    from repro.train.train_step import blockwise_lm_xent

    def loss_fn(trainable, frozen, batch):
        hidden, aux, npre = submodel_forward(
            cfg, frozen, trainable, batch, t,
            window_override=window_override, return_hidden=True,
        )
        stem = trainable["active"] if t == 0 else frozen
        w = OM.tf_output_head_w(
            cfg, trainable["op"],
            sg(stem["embed"]["tok"]) if cfg.tie_embeddings and t != 0
            else (stem["embed"]["tok"] if cfg.tie_embeddings else None),
        )
        xent = blockwise_lm_xent(cfg, w, hidden, batch["tokens"], npre)
        return xent + MOE_AUX_COEF * aux, {"xent": xent, "moe_aux": aux}

    return loss_fn


def make_progressive_train_step(
    cfg: ArchConfig,
    opt: Optimizer,
    t: int,
    *,
    window_override: Optional[int] = None,
) -> Callable:
    """Step-t train step: state = {'params': trainable, 'opt', 'step'};
    the frozen prefix rides along in the batch-side args (it is NOT part of
    the optimizer state — no moments, no updates: the memory claim)."""
    loss_fn = make_progressive_loss(cfg, t, window_override=window_override)

    def train_step(state: dict, frozen: dict, batch: dict):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], frozen, batch
        )
        new_params, new_opt = opt.update(
            grads, state["opt"], state["params"], state["step"]
        )
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            dict(metrics, loss=loss),
        )

    return train_step


# ===========================================================================
# CNN sub-model (the paper's faithful path)
# ===========================================================================


def apply_cnn_block(cfg: C.CNNConfig, t: int, block_params, block_state, x, train,
                    ratio: float = 1.0):
    plan = C.build_plan(cfg, ratio)[t]
    new_bs = []
    for u, p, s in zip(plan, block_params, block_state):
        x, ns = C._apply_unit(u, p, s, x, train)
        new_bs.append(ns)
    return x, new_bs


def cnn_submodel_forward(
    cfg: C.CNNConfig,
    frozen: dict,  # {'blocks': [...t blocks...]}
    trainable: dict,  # {'active': {'blocks': [block_t]}, 'op': output module}
    bn_state: dict,  # full bn state tree {'blocks': [...]}
    x: jax.Array,
    t: int,
    *,
    train: bool = True,
    ratio: float = 1.0,
):
    """Returns (logits, new_bn_state)."""
    fro = jax.tree.map(sg, frozen)
    new_state = {"blocks": list(bn_state["blocks"])}
    for bi in range(t):
        x, nbs = apply_cnn_block(
            cfg, bi, fro["blocks"][bi], bn_state["blocks"][bi], x, train, ratio
        )
        new_state["blocks"][bi] = nbs
    x = sg(x)
    x, nbs = apply_cnn_block(
        cfg, t, trainable["active"]["blocks"][0], bn_state["blocks"][t], x, train,
        ratio,
    )
    new_state["blocks"][t] = nbs
    logits = OM.apply_cnn_output_module(cfg, t, trainable["op"], x)
    return logits, new_state


def cnn_submodel_loss(cfg: C.CNNConfig, t: int, ratio: float = 1.0) -> Callable:
    def loss_fn(trainable, frozen, bn_state, xb, yb):
        logits, new_state = cnn_submodel_forward(
            cfg, frozen, trainable, bn_state, xb, t, train=True, ratio=ratio
        )
        return softmax_xent(logits, yb), new_state

    return loss_fn


# ===========================================================================
# Schedule
# ===========================================================================


def schedule(n_blocks: int, use_shrinking: bool = True):
    """Yields (stage, t) over the whole ProFL run.

    Shrinking trains blocks T-1 .. 1 (block 0 needs no proxy/init — growing
    starts there), then growing trains 0 .. T-1.  With ``use_shrinking=False``
    (the paper's low-communication variant, §4.6) only the growing stage
    runs, with randomly initialized output modules."""
    if use_shrinking:
        for t in range(n_blocks - 1, 0, -1):
            yield ("shrink", t)
    for t in range(n_blocks):
        yield ("grow", t)
