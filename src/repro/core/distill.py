"""The "Map" operation (paper §3.2 / Fig. 3): after a block converges during
progressive model shrinking, integrate its learned function into its proxy
layer via knowledge distillation — the proxy is trained to match the block's
output features on (client-local) data, so no public dataset is needed.

The distillation itself runs federated (clients compute the MSE on their own
data against the frozen teacher block); the server aggregates proxy params
with the same FedAvg as ordinary rounds.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import output_module as OM
from repro.core import progressive as P
from repro.models import cnn as C
from repro.models import transformer as T

sg = jax.lax.stop_gradient


def cnn_map_loss(cfg: C.CNNConfig, t: int, ratio: float = 1.0) -> Callable:
    """MSE between proxy_t(features_in) and block_t(features_in).

    features_in = output of blocks [0, t) (frozen prefix); the teacher block
    runs with batch-stat BN and stop_gradient."""

    def loss_fn(proxy, frozen_prefix, teacher_block, bn_state, xb):
        x = xb
        for bi in range(t):
            x, _ = P.apply_cnn_block(
                cfg, bi, sg(frozen_prefix["blocks"][bi]),
                bn_state["blocks"][bi], x, True, ratio,
            )
        x = sg(x)
        y_teacher, _ = P.apply_cnn_block(
            cfg, t, sg(teacher_block["blocks"][0]), bn_state["blocks"][t], x, True,
            ratio,
        )
        y_student = OM.apply_cnn_proxy(cfg, t, proxy, x)
        return jnp.mean(jnp.square(y_student - sg(y_teacher)))

    return loss_fn


def tf_map_loss(cfg: ArchConfig, t: int) -> Callable:
    """Transformer analogue: proxy_t mimics block_t's residual update."""

    def loss_fn(proxy, frozen, teacher_active, batch):
        stem = teacher_active if t == 0 else frozen
        x, positions, _ = T.embed_inputs(cfg, sg(stem), batch)
        enc = None
        if cfg.encoder is not None:
            enc = T.encode(cfg, sg(stem), batch["frames"])
        if frozen["layers"] and frozen["layers"][0]:
            x, _ = T.run_layers(cfg, sg(frozen["layers"]), x, positions, enc, remat=False)
        x = sg(x)
        y_teacher, _ = T.run_layers(
            cfg, sg(teacher_active["layers"]), x, positions, enc, remat=False
        )
        y_student = OM.apply_tf_proxy(cfg, proxy, x)
        return jnp.mean(jnp.square(
            y_student.astype(jnp.float32) - sg(y_teacher).astype(jnp.float32)
        ))

    return loss_fn
