"""Output modules (paper §3.2): per-step heads that let a depth-truncated
sub-model train end-to-end while PRESERVING each block's position in the
feature hierarchy.

Paper (CNNs): the blocks behind the active one are each replaced by ONE conv
layer that mimics that block's spatial downsampling and channel growth; the
proxies + a single fc form θ_op.  After a block converges during shrinking,
its knowledge is distilled into its proxy ("Map").

Transformer adaptation (DESIGN.md §2): a block's proxy is one residual
norm+MLP layer at d_ff = d_model (a cheap stand-in keeping depth position);
θ_L is the final norm + LM head.  Same shrinking/growing mechanics.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import blocks as B
from repro.models import cnn as C
from repro.models import layers as L


# ===========================================================================
# CNN proxies
# ===========================================================================


def init_cnn_proxy(cfg: C.CNNConfig, rng, t: int, ratio: float = 1.0) -> dict:
    """Proxy conv for prog-block ``t``: 3x3 conv with the block's total
    stride and channel growth + BN (+relu in apply)."""
    chans = [3] + C.block_out_channels(cfg, ratio)
    cin, cout = chans[t], chans[t + 1]
    return {
        "conv": jax.random.normal(rng, (3, 3, cin, cout), jnp.float32)
        * math.sqrt(2.0 / (9 * cin)),
        "bn": {"scale": jnp.ones((cout,)), "bias": jnp.zeros((cout,))},
    }


def cnn_proxy_stride(cfg: C.CNNConfig, t: int) -> int:
    sizes = [cfg.in_size] + C.block_spatial_sizes(cfg)
    return max(1, sizes[t] // sizes[t + 1])


def apply_cnn_proxy(cfg: C.CNNConfig, t: int, p: dict, x: jax.Array) -> jax.Array:
    s = cnn_proxy_stride(cfg, t)
    x = jax.lax.conv_general_dilated(
        x, p["conv"], (s, s), "SAME", dimension_numbers=C.DN
    )
    # proxy BN uses batch stats only (it is a transient training scaffold)
    mu = jnp.mean(x, axis=(0, 1, 2))
    var = jnp.var(x, axis=(0, 1, 2))
    x = (x - mu) * jax.lax.rsqrt(var + 1e-5) * p["bn"]["scale"] + p["bn"]["bias"]
    return jax.nn.relu(x)


def init_cnn_output_module(
    cfg: C.CNNConfig, rng, t: int, head_params: dict, ratio: float = 1.0
) -> dict:
    """θ_op for step t: proxies for blocks t+1..T-1 + θ_L (the classifier).
    For the last step it is exactly the real classifier."""
    T = cfg.n_prog_blocks
    proxies = [
        init_cnn_proxy(cfg, jax.random.fold_in(rng, b), b, ratio)
        for b in range(t + 1, T)
    ]
    return {"proxies": proxies, "head": head_params}


def apply_cnn_output_module(
    cfg: C.CNNConfig, t: int, op: dict, feats: jax.Array
) -> jax.Array:
    T = cfg.n_prog_blocks
    x = feats
    for i, b in enumerate(range(t + 1, T)):
        x = apply_cnn_proxy(cfg, b, op["proxies"][i], x)
    return C.head_logits({"head": op["head"]}, x)


# ===========================================================================
# Transformer proxies
# ===========================================================================


def init_tf_proxy(cfg: ArchConfig, rng) -> dict:
    """One residual norm+MLP proxy layer (d_ff = d_model)."""
    pcfg = cfg.with_(act="swiglu", d_ff=cfg.d_model)
    return {
        "norm": L.init_norm(cfg, cfg.d_model, jnp.dtype(cfg.param_dtype)),
        "mlp": L.init_mlp(pcfg, rng, d_ff=cfg.d_model),
    }


def apply_tf_proxy(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    pcfg = cfg.with_(act="swiglu")
    return x + L.apply_mlp(pcfg, p["mlp"], L.apply_norm(cfg, p["norm"], x))


def init_tf_output_module(cfg: ArchConfig, rng, t: int, params: dict) -> dict:
    """θ_op for transformer step t: proxies for blocks t+1..T-1 + final norm
    + head (tied-embedding archs share the embed matrix — the head entry is
    then absent and logits use the frozen/active embed)."""
    T = B.n_blocks(cfg)
    op = {
        "proxies": [
            init_tf_proxy(cfg, jax.random.fold_in(rng, 555_000 + b))
            for b in range(t + 1, T)
        ],
        "final_norm": params["final_norm"],
    }
    if not cfg.tie_embeddings:
        op["head"] = params["head"]
    return op


def apply_tf_output_module_hidden(
    cfg: ArchConfig, op: dict, x: jax.Array
) -> jax.Array:
    """Proxies + final norm (everything before the LM head matmul)."""
    for p in op["proxies"]:
        x = apply_tf_proxy(cfg, p, x)
    return L.apply_norm(cfg, op["final_norm"], x)


def tf_output_head_w(cfg: ArchConfig, op: dict, embed_tok=None) -> jax.Array:
    return embed_tok.T if cfg.tie_embeddings else op["head"]["w"]


def apply_tf_output_module(
    cfg: ArchConfig, op: dict, x: jax.Array, embed_tok: Optional[jax.Array] = None
) -> jax.Array:
    x = apply_tf_output_module_hidden(cfg, op, x)
    w = tf_output_head_w(cfg, op, embed_tok)
    logits = x @ w.astype(x.dtype)
    if cfg.logit_soft_cap > 0:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    return logits
