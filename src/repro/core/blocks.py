"""Block partitioning (paper §3.1): divide the model into T blocks along
depth, at group granularity for transformer stacks (a block is a contiguous
range of scan groups) and at the paper's stage boundaries for the CNNs.

Ownership:
* transformer block 1 owns the embedding (+ projector / encoder tower),
  matching the paper where the stem belongs to the first block;
* the final norm + LM head are the θ_L component of the *output module* and
  are trained at every step (paper §3.2: θ_op = [conv proxies..., θ_L]).
"""
from __future__ import annotations

from typing import List, Tuple

import jax

from repro.configs.base import ArchConfig


def group_boundaries(n_groups: int, n_blocks: int) -> List[int]:
    """Split ``n_groups`` into ``n_blocks`` contiguous ranges; earlier blocks
    get the remainder (paper splits by architecture stages; for uniform
    transformer stacks an even split is the natural analogue)."""
    n_blocks = min(n_blocks, n_groups)
    base, rem = divmod(n_groups, n_blocks)
    out = [0]
    for b in range(n_blocks):
        out.append(out[-1] + base + (1 if b < rem else 0))
    return out


def boundaries(cfg: ArchConfig) -> List[int]:
    return group_boundaries(cfg.n_groups, cfg.n_prog_blocks)


def n_blocks(cfg: ArchConfig) -> int:
    return len(boundaries(cfg)) - 1


def slice_groups(layer_params: list, g0: int, g1: int) -> list:
    """Slice every slot's stacked leaves to groups [g0, g1)."""
    return [jax.tree.map(lambda a: a[g0:g1], slot) for slot in layer_params]


def merge_groups(full_layers: list, block_layers: list, g0: int) -> list:
    """Write a block's (updated) groups back into the full stack."""

    def put(full, part):
        return full.at[g0 : g0 + part.shape[0]].set(part.astype(full.dtype))

    return [
        jax.tree.map(put, full_slot, part_slot)
        for full_slot, part_slot in zip(full_layers, block_layers)
    ]


def split_model(cfg: ArchConfig, params: dict, t: int) -> Tuple[dict, dict]:
    """Partition full-model params into (frozen_prefix, trainable_block) for
    growing/shrinking step ``t`` (0-indexed block id).

    frozen:  embed/projector/encoder (if t>0) + layer groups [0, b[t])
    active:  layer groups [b[t], b[t+1])  (+ embed etc. when t == 0)
    The head/final_norm are NOT here — they live in the output module.
    """
    bs = boundaries(cfg)
    g0, g1 = bs[t], bs[t + 1]
    stem = {k: params[k] for k in ("embed", "projector", "encoder") if k in params}
    frozen = {"layers": slice_groups(params["layers"], 0, g0)}
    active = {"layers": slice_groups(params["layers"], g0, g1)}
    if t == 0:
        active.update(stem)
    else:
        frozen.update(stem)
    return frozen, active


def block_param_count(cfg: ArchConfig, params: dict, t: int) -> int:
    _, active = split_model(cfg, params, t)
    return sum(x.size for x in jax.tree.leaves(active))


def merge_block_into(cfg: ArchConfig, params: dict, active: dict, t: int) -> dict:
    """Write trained block-t params back into the full model tree."""
    bs = boundaries(cfg)
    out = dict(params)
    out["layers"] = merge_groups(params["layers"], active["layers"], bs[t])
    for k in ("embed", "projector", "encoder"):
        if k in active:
            out[k] = active[k]
    return out


# ---------------------------------------------------------------------------
# CNN (paper models): blocks are explicit lists already
# ---------------------------------------------------------------------------


def cnn_split(params: dict, t: int) -> Tuple[dict, dict]:
    """(frozen blocks [0,t), active block t). Head lives in the output
    module (paper: θ_L)."""
    return (
        {"blocks": params["blocks"][:t]},
        {"blocks": [params["blocks"][t]]},
    )


def cnn_merge(params: dict, active: dict, t: int) -> dict:
    out = dict(params)
    blocks = list(params["blocks"])
    blocks[t] = active["blocks"][0]
    out["blocks"] = blocks
    return out
