"""Shared world setup + timing helpers for the benchmark suite.

Default profile is CPU-sized (reduced-width CNNs, small round budgets) so
``python -m benchmarks.run`` completes in tens of minutes; pass --full for
longer runs.  Client *eligibility* always uses the paper-scale memory model
(fl/memory_model.py), so participation-rate structure matches the paper
regardless of the simulated width.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax
import numpy as np

from repro.core.effective_movement import EMConfig
from repro.fl import data as D
from repro.fl import memory_model as MM
from repro.fl.server import FLConfig
from repro.models.cnn import CNNConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def results_path(name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, name)


_WORLD_CACHE = {}


def world(non_iid: bool = False, n_clients: int = 100, seed: int = 0):
    """(xtr, ytr, xte, yte, parts, budgets) — cached."""
    key = (non_iid, n_clients, seed)
    if key not in _WORLD_CACHE:
        rng = jax.random.PRNGKey(seed)
        xtr, ytr, xte, yte = D.make_synthetic(
            rng, n_train=2000, n_test=500, size=16
        )
        if non_iid:
            parts = D.partition_dirichlet(
                jax.random.PRNGKey(seed + 1), ytr, n_clients, alpha=1.0
            )
        else:
            parts = D.partition_iid(jax.random.PRNGKey(seed + 1), len(xtr),
                                    n_clients)
        budgets = MM.assign_budgets_mb(np.random.default_rng(seed), n_clients)
        _WORLD_CACHE[key] = (xtr, ytr, xte, yte, parts, budgets)
    return _WORLD_CACHE[key]


def small_cnn(kind: str) -> CNNConfig:
    return CNNConfig(kind, width_mult=0.25, in_size=16)


def default_fl(**kw) -> FLConfig:
    base = dict(
        n_clients=100,
        clients_per_round=10,
        local_steps=4,
        batch_size=16,
        n_local_fixed=32,
        max_rounds_per_step=8,
        distill_rounds=2,
        eval_every=4,
        em=EMConfig(window_h=2, slope_phi=0.03, patience_w=2, fit_points=4,
                    em_level=0.92, min_rounds=4),
    )
    base.update(kw)
    return FLConfig(**base)


BASELINE_ROUNDS = 12  # per baseline in the accuracy tables (CPU profile)


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 10, **kw):
    """Median microseconds per call (after jit warmup)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us: float, derived: str):
    """The run.py CSV contract: name,us_per_call,derived."""
    print(f"{name},{us:.1f},{derived}")


def save_json(name: str, obj):
    with open(results_path(name), "w") as f:
        json.dump(obj, f, indent=1, default=float)
