"""Paper Table 3: ablation of progressive model shrinking — final global
accuracy with and without the shrinking stage (init params + proxy bank)."""
from __future__ import annotations

from repro.fl.server import ProFLServer

from benchmarks import common as C


def bench(ctx: dict, full: bool = False):
    xtr, ytr, xte, yte, parts, budgets = C.world()
    cfg = C.small_cnn("resnet18")
    out = {}
    for use_shrink in (True, False):
        fl = C.default_fl(use_shrinking=use_shrink, seed=1)
        srv = ProFLServer(cfg, fl, xtr, ytr, xte, yte, parts, budgets)
        res = srv.run()
        # per-step sub-model accuracies (the paper's Step1..4 columns)
        sub = [h.get("sub_acc") for h in res["history"] if "sub_acc" in h
               and h["stage"] == "grow"]
        out["with" if use_shrink else "without"] = {
            "global_acc": res["final_acc"],
            "grow_sub_accs": sub,
        }
    delta = out["with"]["global_acc"] - out["without"]["global_acc"]
    C.emit("table3/shrinking_ablation", 0.0,
           f"with={out['with']['global_acc']:.3f};"
           f"without={out['without']['global_acc']:.3f};delta={delta:+.3f}")
    ctx["table3"] = out
    C.save_json("bench_table3.json", out)
