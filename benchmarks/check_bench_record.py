"""Declarative bench-artifact gate (ISSUE 10, CI headline).

CI's slow job regenerates the benchmark trajectory and must fail loud if
any GATED section silently vanishes from the uploaded artifact.  That
check used to live as inline Python in ``.github/workflows/ci.yml`` and
only covered ``transport`` + ``async`` — the ``faults`` (PR 8) and
``freeze_decay`` (PR 6) sections could disappear without a peep.  This
module replaces it with ONE declarative spec: ``REQUIRED_SECTIONS`` maps
each gated section to the dotted key paths that must be present, so
adding a gated bench section without registering it here fails the
tier-1 unit test (tests/test_population.py::test_check_bench_record_*)
and a section dropping out of the artifact fails the CI step.

Usage: ``python benchmarks/check_bench_record.py BENCH_kernels.regen.json``
— exits 0 when every required section and key is present, else prints
every violation and exits 1.  Stdlib only (runs before/without the jax
environment).
"""
from __future__ import annotations

import json
import sys

# section -> dotted key paths that must exist (and be non-None) in the
# record.  One entry per GATED bench section — benchmarks/bench_kernels.py
# sections whose disappearance would silently disable a regression gate.
REQUIRED_SECTIONS: dict = {
    "transport": (
        "dtypes.f32.wire_bytes",
        "dtypes.bf16.wire_bytes",
        "dtypes.int8.wire_bytes",
        "int8_over_f32_wire",
    ),
    "async": (
        "overhead_async_vs_sync",
        "buffer_peak_bytes",
    ),
    "faults": (
        "overhead_faulted_vs_clean",
        "straggler.staging_bytes",
        "counters.fault_ok",
    ),
    "freeze_decay": (
        "points",
    ),
    "hierarchy": (
        "population",
        "cohort",
        "admission.rejected_budget",
        "flat.round_us",
        "flat.server_peak_bytes",
        "edges.4.hier_server_peak_bytes",
        "edges.8.hier_server_peak_bytes",
    ),
}


def _lookup(d, path: str):
    """Walk a dotted path through nested dicts; returns (found, value)."""
    cur = d
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return False, None
        cur = cur[part]
    return True, cur


def check_record(rec: dict) -> list:
    """All violations of ``REQUIRED_SECTIONS`` in ``rec`` (empty = ok)."""
    problems = []
    for section, keys in REQUIRED_SECTIONS.items():
        sec = rec.get(section)
        if not isinstance(sec, dict):
            problems.append(
                f"section {section!r} missing from the bench record — its "
                f"regression gate silently vanished"
            )
            continue
        for path in keys:
            found, val = _lookup(sec, path)
            if not found or val is None:
                problems.append(
                    f"section {section!r} lacks required key {path!r}"
                )
    return problems


def main(argv) -> int:
    if len(argv) != 2:
        print(f"usage: {argv[0]} <bench_record.json>", file=sys.stderr)
        return 2
    try:
        with open(argv[1]) as f:
            rec = json.load(f)
    except OSError as e:
        print(f"{argv[1]} unreadable ({e}) — the bench smoke died before "
              f"emitting the record", file=sys.stderr)
        return 1
    except json.JSONDecodeError as e:
        print(f"{argv[1]} is not valid JSON ({e})", file=sys.stderr)
        return 1
    problems = check_record(rec)
    if problems:
        for p in problems:
            print(p, file=sys.stderr)
        return 1
    print(f"bench record ok: all {len(REQUIRED_SECTIONS)} gated sections "
          f"present ({', '.join(sorted(REQUIRED_SECTIONS))})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
