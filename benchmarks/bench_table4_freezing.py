"""Paper Table 4: block-freezing determination (effective movement) vs the
ParamAware baseline that allocates a fixed round budget per block
proportional to its parameter count."""
from __future__ import annotations

import numpy as np

from repro.core.effective_movement import EMConfig
from repro.fl.server import ProFLServer
from repro.models import cnn as CN

from benchmarks import common as C


class ParamAwareServer(ProFLServer):
    """Replaces EM freezing with parameter-proportional round allocation
    (same total round budget)."""

    def __init__(self, *args, total_rounds: int, **kw):
        super().__init__(*args, **kw)
        counts = np.asarray(CN.block_param_counts(self.params), float)
        shares = counts / counts.sum()
        # shrink steps (T-1..1) + grow steps (0..T-1) share the budget
        self._alloc = {}
        for t in range(self.cfg.n_prog_blocks):
            self._alloc[t] = max(2, int(round(shares[t] * total_rounds)))

    def _train_step_t(self, stage, t):
        fl = self.fl
        orig = fl.max_rounds_per_step
        fl.max_rounds_per_step = self._alloc[t]
        # disable EM freezing by making it unreachable
        old_em = fl.em
        fl.em = EMConfig(window_h=10_000, min_rounds=10**9)
        try:
            return super()._train_step_t(stage, t)
        finally:
            fl.max_rounds_per_step = orig
            fl.em = old_em


def bench(ctx: dict, full: bool = False):
    xtr, ytr, xte, yte, parts, budgets = C.world()
    cfg = C.small_cnn("resnet18")

    fl = C.default_fl(seed=2)
    ours = ProFLServer(cfg, fl, xtr, ytr, xte, yte, parts, budgets)
    res_ours = ours.run()
    rounds_used = sum(s["rounds"] for s in res_ours["steps"])

    fl2 = C.default_fl(seed=2)
    # same per-stage round budget as ours used, allocated by param count
    pa = ParamAwareServer(cfg, fl2, xtr, ytr, xte, yte, parts, budgets,
                          total_rounds=max(rounds_used // 2, 8))  # /2: ours
    # spends its budget across both stages; ParamAware allocates per block
    # and runs each block twice (shrink+grow), matching total rounds
    res_pa = pa.run()

    out = {
        "ours": {"acc": res_ours["final_acc"], "rounds": rounds_used},
        "param_aware": {"acc": res_pa["final_acc"],
                        "rounds": sum(s["rounds"] for s in res_pa["steps"])},
    }
    C.emit("table4/freezing", 0.0,
           f"ours={out['ours']['acc']:.3f};"
           f"param_aware={out['param_aware']['acc']:.3f};"
           f"delta={out['ours']['acc'] - out['param_aware']['acc']:+.3f}")
    ctx["table4"] = out
    C.save_json("bench_table4.json", out)
