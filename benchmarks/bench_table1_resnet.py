"""Paper Table 1: ProFL vs AllSmall / ExclusiveFL / HeteroFL / DepthFL on
the ResNet family (reduced CPU scale, synthetic data — the reproduced signal
is the accuracy ORDERING and the participation rates; see DESIGN.md §6)."""
from __future__ import annotations

import time

from repro.fl import baselines as BL
from repro.fl.server import ProFLServer

from benchmarks import common as C


def run(kind: str, non_iid: bool, rounds: int):
    xtr, ytr, xte, yte, parts, budgets = C.world(non_iid=non_iid)
    cfg = C.small_cnn(kind)
    fl = C.default_fl()
    out = {}
    t0 = time.time()
    srv = ProFLServer(cfg, fl, xtr, ytr, xte, yte, parts, budgets)
    res = srv.run()
    out["ProFL"] = {"acc": res["final_acc"], "pr": 1.0}
    out["_profl_history"] = res["history"]
    out["_profl_steps"] = res["steps"]
    out["_profl_uplink"] = res["uplink_params"]
    for name, fn in [
        ("AllSmall", BL.run_allsmall),
        ("ExclusiveFL", BL.run_exclusivefl),
        ("HeteroFL", BL.run_heterofl),
        ("DepthFL", BL.run_depthfl),
    ]:
        r = fn(cfg, fl, xtr, ytr, xte, yte, parts, budgets, rounds)
        out[name] = {"acc": r["acc"], "pr": r["pr"]}
    out["_elapsed_s"] = time.time() - t0
    return out


def bench(ctx: dict, full: bool = False):
    rounds = C.BASELINE_ROUNDS
    cases = [("resnet18", False)] + ([("resnet18", True), ("resnet34", False)]
                                     if full else [])
    table = {}
    for kind, non_iid in cases:
        tag = f"{kind}-{'noniid' if non_iid else 'iid'}"
        table[tag] = run(kind, non_iid, rounds)
        r = table[tag]
        best_base = max(
            (v["acc"] or 0.0) for k, v in r.items()
            if not k.startswith("_") and k != "ProFL"
        )
        C.emit(
            f"table1/{tag}/ProFL",
            r["_elapsed_s"] * 1e6,
            f"acc={r['ProFL']['acc']:.3f};best_baseline={best_base:.3f};"
            f"margin={r['ProFL']['acc'] - best_base:+.3f}",
        )
        for k, v in r.items():
            if k.startswith("_") or k == "ProFL":
                continue
            acc = "NA" if v["acc"] is None else f"{v['acc']:.3f}"
            C.emit(f"table1/{tag}/{k}", 0.0, f"acc={acc};pr={v['pr']:.2f}")
    ctx["table1"] = table
    C.save_json("bench_table1.json", {
        k: {kk: vv for kk, vv in v.items() if not kk.startswith("_")}
        for k, v in table.items()
    })
    # keep histories for fig4/5 benches
    ctx["profl_history"] = {k: v["_profl_history"] for k, v in table.items()}
