"""Paper Fig. 4/5: effective movement as a convergence indicator — per-step
EM series from the ProFL run (reused from the Table 1 bench when available),
checked for the paper's qualitative shape: high at step start, declining
toward the freeze point."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C


def bench(ctx: dict, full: bool = False):
    hist = ctx.get("profl_history")
    if not hist:  # standalone invocation: run a short ProFL
        from repro.fl.server import ProFLServer
        xtr, ytr, xte, yte, parts, budgets = C.world()
        srv = ProFLServer(C.small_cnn("resnet18"), C.default_fl(),
                          xtr, ytr, xte, yte, parts, budgets)
        hist = {"resnet18-iid": srv.run()["history"]}

    out = {}
    for tag, h in hist.items():
        series = {}
        for rec in h:
            if rec.get("em") is None:
                continue
            series.setdefault((rec["stage"], rec["t"]), []).append(rec["em"])
        for (stage, t), ems in series.items():
            if len(ems) < 2:
                continue
            declines = ems[-1] <= ems[0] + 1e-6
            out[f"{tag}/{stage}{t}"] = {
                "em_first": ems[0], "em_last": ems[-1], "n": len(ems),
                "declines_or_flat": bool(declines),
            }
            C.emit(
                f"fig45/{tag}/{stage}{t}", 0.0,
                f"em_first={ems[0]:.3f};em_last={ems[-1]:.3f};n={len(ems)}",
            )
    frac_decl = np.mean([v["declines_or_flat"] for v in out.values()]) if out else 0
    C.emit("fig45/summary", 0.0, f"fraction_declining={frac_decl:.2f}")
    ctx["fig45"] = out
    C.save_json("bench_fig45.json", out)
