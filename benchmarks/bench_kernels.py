"""Kernel microbenchmarks (CPU wall-clock for the jnp paths; the Pallas
kernels run in interpret mode here and are timed for regression tracking,
not TPU-performance claims).

Standalone smoke entry point for CI (catches kernel/engine regressions
before merge without the full benchmark suite):

    PYTHONPATH=src python -m benchmarks.bench_kernels --smoke
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops

from benchmarks import common as C


def bench(ctx: dict, full: bool = False):
    rng = jax.random.PRNGKey(0)
    B, H, K, S, hd = 2, 8, 2, 1024, 64
    q = jax.random.normal(rng, (B, H, S, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, K, S, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, K, S, hd))

    att = jax.jit(functools.partial(ops.attention, impl="chunked", bq=256,
                                    bk=256))
    us = C.time_call(att, q, k, v)
    flops = 4 * B * H * S * S * hd / 2  # causal
    C.emit("kernels/attention_chunked_1k", us,
           f"gflops_s={flops/us/1e3:.1f}")

    n = 2_000_000
    pn = jax.random.normal(rng, (n,))
    po = pn + 0.01 * jax.random.normal(jax.random.fold_in(rng, 3), (n,))
    net = jnp.zeros((n,))
    em = jax.jit(functools.partial(ops.effective_movement_update, impl="naive"))
    us = C.time_call(em, pn, po, net)
    C.emit("kernels/effective_movement_2M", us,
           f"gbytes_s={4*4*n/us/1e3:.2f}")
    em_pl = jax.jit(functools.partial(ops.effective_movement_update,
                                      impl="pallas"))
    us_pl = C.time_call(em_pl, pn, po, net, iters=3)
    C.emit("kernels/effective_movement_2M_pallas_interp", us_pl,
           "interpret_mode=1")

    Kc, n2 = 20, 1_000_000
    p = jax.random.normal(rng, (Kc, n2))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(rng, 4), (Kc,)))
    fa = jax.jit(functools.partial(ops.fedavg, impl="naive"))
    us = C.time_call(fa, p, w)
    C.emit("kernels/fedavg_20x1M", us, f"gbytes_s={4*Kc*n2/us/1e3:.2f}")

    _bench_cohort_aggregation(rng, full)
    _bench_grouped_round(full=full)


def _bench_cohort_aggregation(rng, full: bool):
    """Packed-panel fedavg (fl/engine.py) vs the per-leaf einsum tree-map of
    client.cohort_round, on a realistic many-leaf trainable tree."""
    from repro.fl import engine as ENG

    Kc = 20
    leaf_shapes = [(64, 64)] * 24 + [(256, 64)] * 8 + [(64,)] * 32
    if full:
        leaf_shapes = [(256, 256)] * 24 + [(1024, 256)] * 8 + [(256,)] * 32
    tree = {
        f"l{i}": jax.random.normal(jax.random.fold_in(rng, 10 + i), (Kc,) + s)
        for i, s in enumerate(leaf_shapes)
    }
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(rng, 5), (Kc,)))
    n = sum(int(jnp.prod(jnp.asarray(s))) for s in leaf_shapes)

    @jax.jit
    def treemap_agg(trs, w):
        wn = w / jnp.sum(w)
        agg = lambda leaf: jnp.einsum(
            "k,k...->...", wn, leaf.astype(jnp.float32)
        ).astype(leaf.dtype)
        return jax.tree.map(agg, trs)

    us = C.time_call(treemap_agg, tree, w)
    C.emit("kernels/cohort_agg_treemap", us,
           f"n_params={n} gbytes_s={4*Kc*n/us/1e3:.2f}")

    template = jax.tree.map(lambda l: l[0], tree)

    def packed_agg(trs, w, impl):
        spec = ENG.make_pack_spec(template)
        panel = spec.pack_stacked(trs, Kc)
        return spec.unpack(ops.fedavg(panel, w / jnp.sum(w), impl=impl))

    pk = jax.jit(functools.partial(packed_agg, impl="naive"))
    us = C.time_call(pk, tree, w)
    C.emit("kernels/cohort_agg_packed", us,
           f"n_params={n} gbytes_s={4*Kc*n/us/1e3:.2f}")

    pk_pl = jax.jit(functools.partial(packed_agg, impl="pallas"))
    us_pl = C.time_call(pk_pl, tree, w, iters=3)
    C.emit("kernels/cohort_agg_packed_pallas_interp", us_pl, "interpret_mode=1")


def _width_loss_factory(f: int):
    def loss_fn(tr, fro, bn, xb, yb):
        pred = xb[:, :f] @ tr["w"] + tr["b"]
        return jnp.mean((pred - yb[:, None]) ** 2), bn

    return loss_fn


def _bench_grouped_round(full: bool = False, smoke: bool = False,
                         iters: int = 5):
    """Grouped heterogeneous round (fl/engine.py::grouped_round): the fused
    single-dispatch masked aggregation vs the serial per-group oracle, on a
    HeteroFL-shaped cohort of three width groups.  Also asserts the fused
    path's one-dispatch-per-round contract via the ops.DISPATCHES counter."""
    from repro.fl import engine as ENG

    d = 256 if smoke else (4096 if full else 1024)
    out = 16
    ks = (4, 6, 10)  # clients per width group
    fracs = (0.25, 0.5, 1.0)
    rng = jax.random.PRNGKey(0)
    gtr = {"w": jax.random.normal(rng, (d, out)), "b": jnp.zeros((out,))}
    losses = {f: _width_loss_factory(f) for f in
              [max(1, int(d * r)) for r in fracs]}
    plans = []
    for gi, (r, kg) in enumerate(zip(fracs, ks)):
        f = max(1, int(d * r))
        sub = {"w": gtr["w"][:f], "b": gtr["b"]}
        xs = jax.random.normal(jax.random.fold_in(rng, gi), (kg, 16, d))
        ys = jax.random.normal(jax.random.fold_in(rng, 50 + gi), (kg, 16))
        rngs = jax.random.split(jax.random.fold_in(rng, 100 + gi), kg)
        plans.append(ENG.GroupPlan(
            losses[f], sub, {}, {}, xs, ys, rngs,
            jnp.arange(1.0, kg + 1.0), 0.1, 2, 8,
        ))
    n = sum(x.size for x in jax.tree.leaves(gtr))

    serial = ENG.make_engine("vmap")
    fused = ENG.make_engine("packed")

    us_s = C.time_call(
        lambda: serial.grouped_round(plans, gtr, {}).loss, iters=iters
    )
    C.emit("kernels/grouped_round_serial", us_s,
           f"groups={len(plans)} k_total={sum(ks)} n={n}")

    us_f = C.time_call(
        lambda: fused.grouped_round(plans, gtr, {}).loss, iters=iters
    )
    ops.reset_dispatches()
    fused.grouped_round(plans, gtr, {})
    n_disp = ops.DISPATCHES["fedavg_masked"]
    assert n_disp == 1, (
        f"grouped round must issue exactly ONE aggregation dispatch "
        f"regardless of group count, saw {n_disp}"
    )
    ops.reset_dispatches()
    C.emit("kernels/grouped_round_fused", us_f,
           f"groups={len(plans)} k_total={sum(ks)} n={n} agg_dispatches=1 "
           f"speedup_vs_serial={us_s/us_f:.2f}x")


def main() -> None:
    """CI smoke entry: run the grouped-round benchmark (with its dispatch
    assertion) plus a small fedavg pass, fast enough for the slow job."""
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, few iters (CI regression gate)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        _bench_grouped_round(smoke=True, iters=2)
    else:
        bench({}, full=args.full)


if __name__ == "__main__":
    main()
