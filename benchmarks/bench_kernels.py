"""Kernel microbenchmarks (CPU wall-clock for the jnp paths; the Pallas
kernels run in interpret mode here and are timed for regression tracking,
not TPU-performance claims)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops

from benchmarks import common as C


def bench(ctx: dict, full: bool = False):
    rng = jax.random.PRNGKey(0)
    B, H, K, S, hd = 2, 8, 2, 1024, 64
    q = jax.random.normal(rng, (B, H, S, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, K, S, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, K, S, hd))

    att = jax.jit(functools.partial(ops.attention, impl="chunked", bq=256,
                                    bk=256))
    us = C.time_call(att, q, k, v)
    flops = 4 * B * H * S * S * hd / 2  # causal
    C.emit("kernels/attention_chunked_1k", us,
           f"gflops_s={flops/us/1e3:.1f}")

    n = 2_000_000
    pn = jax.random.normal(rng, (n,))
    po = pn + 0.01 * jax.random.normal(jax.random.fold_in(rng, 3), (n,))
    net = jnp.zeros((n,))
    em = jax.jit(functools.partial(ops.effective_movement_update, impl="naive"))
    us = C.time_call(em, pn, po, net)
    C.emit("kernels/effective_movement_2M", us,
           f"gbytes_s={4*4*n/us/1e3:.2f}")
    em_pl = jax.jit(functools.partial(ops.effective_movement_update,
                                      impl="pallas"))
    us_pl = C.time_call(em_pl, pn, po, net, iters=3)
    C.emit("kernels/effective_movement_2M_pallas_interp", us_pl,
           "interpret_mode=1")

    Kc, n2 = 20, 1_000_000
    p = jax.random.normal(rng, (Kc, n2))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(rng, 4), (Kc,)))
    fa = jax.jit(functools.partial(ops.fedavg, impl="naive"))
    us = C.time_call(fa, p, w)
    C.emit("kernels/fedavg_20x1M", us, f"gbytes_s={4*Kc*n2/us/1e3:.2f}")
