"""Kernel microbenchmarks (CPU wall-clock for the jnp paths; the Pallas
kernels run in interpret mode here and are timed for regression tracking,
not TPU-performance claims).

Standalone smoke entry point for CI (catches kernel/engine regressions
before merge without the full benchmark suite):

    PYTHONPATH=src python -m benchmarks.bench_kernels --smoke

``--json PATH`` additionally persists the benchmark trajectory (the
masked-vs-grouped kernel comparison, membership bytes staged, per-round
wall clock over the G x K/G grouped-round matrix, the replicated-vs-
column-sharded aggregation comparison, and dispatch counts) so subsequent
PRs regress against recorded numbers instead of vibes — CI uploads the file
as a workflow artifact and the repo commits a seed copy
(BENCH_kernels.json).  Extend the JSON record — don't fork new files — when
adding kernel benches.

Smoke gates (``--smoke``), all on the fused grouped round:
  * exactly ONE logical ``fedavg_grouped`` dispatch per round;
  * membership staging within ``G·n + K`` elements (vs the dense ``K·n``
    mask);
  * grouped-vs-masked round wall clock at G=4, K=16 within an
    interpret-mode tolerance (x1.35, one noise-absorbing retry);
  * the ``agg_compare`` record (PR 4): the column-sharded aggregation
    (``agg="sharded"``) keeps its per-device panel bytes within
    ``K·(n/D + AGG_TILE)`` — i.e. the replicated panel divided by the
    ``model``-axis device count D plus tile padding (read from the actual
    panel sharding via ``engine.AGG_STATS``, so a silent re-replication
    fails the gate) — and its round wall clock within ``AGG_GATE_TOL``
    (x2: PR 8's jitted reference aggregation sped the replicated baseline
    up ~25%, so the shard_map orchestration's fixed cost is a larger
    fraction) of the replicated round.  On the 1-device CI runner D=1, so
    the byte gate
    pins the padding overhead and the wall gate pins the shard_map
    orchestration overhead; on multi-device hardware the same gates verify
    the ÷D memory claim.
  * NEW (PR 5): the TRANSIENT group-panel stream is gated too — the
    shard-local stream's per-device bytes (``AGG_STATS
    ["per_device_stream_elems"]``, read from the real transfer sharding)
    must equal ``memory_model.agg_stream_elems_per_device`` and stay within
    ``max_g K_g·(n_g/D + AGG_TILE)``; re-replicating the group panels
    across the agg mesh fails this gate.
  * NEW (PR 7): the ``transport`` record runs the gate cell's sharded
    round once per wire dtype (``stream_dtype`` ∈ f32/bf16/int8) and
    records the measured interconnect bytes (``AGG_STATS["wire_bytes"]``,
    asserted equal to ``memory_model.agg_wire_bytes`` — plan metadata, no
    sync) plus round wall clock.  Gated (deterministic, always): the int8
    wire must stay ≤ 0.30× the f32 wire at the gate cell (4-bit packed
    scale exponents + per-group bf16 base keep the scale side-channel
    under 5% of payload).  The record also carries the analytic
    ragged-vs-uniform wire ratio for a DepthFL-style concentrated cohort
    at 4 column shards — the saving the ragged per-shard transfer buys
    over the old uniform axis-0 split.
  * NEW (PR 6): the ``freeze_decay`` record replays the grouped round at
    the gate cell under growing frozen-column prefixes
    (``FREEZE_FRACS`` — the Table-4 schedule order: leading blocks
    converge and freeze first) for BOTH aggregation placements, asserts
    measured ``AGG_STATS`` equals ``memory_model`` at each point (with the
    per-group frozen counts), and asserts all four per-device byte metrics
    (panel and stream, replicated and sharded) STRICTLY DECREASE at every
    freeze transition — frozen columns must leave the panel, the stream,
    and the kernel, not just be masked out of the update.
  * NEW (PR 8): the ``faults`` record runs the gate cell's fused round
    with an armed fault plan (one dropout + one norm-blowup corruption
    quarantined by the in-kernel gate) and gates the faulted round's wall
    clock within x1.15 of the clean round — the per-column quarantine
    check must stay fused, not grow a second dispatch or host sync.  The
    record also parks a straggler and asserts the engine staging-buffer
    bytes, quarantine/dropout counters, and merged-row counts all equal
    their ``memory_model`` twins (plan metadata, no extra sync).
  * NEW (PR 9): the ``async`` record drives the gate cell through the
    buffered-aggregation server (``fl/async_server.py``): publishes/sec vs
    sync rounds/sec (a staleness-0 publish makes the verbatim
    ``grouped_round`` call, so it gates within x1.15 of the sync round —
    the buffer/version bookkeeping must stay host metadata, not device
    work), the buffer's peak byte occupancy asserted equal to the
    ``memory_model.async_buffer_bytes`` twin (deterministic, always), a
    one-dispatch check per publish, and an ungated stale-publish data
    point (one group a version behind, β=0.9) recording the staleness
    histogram and wall clock of the side-merge path.
  * NEW (PR 10): the ``hierarchy`` record builds the pop=1M client
    registry (``fl/population.py``), admits a memory-budgeted cohort of
    512 through the device-budget and server-peak gates (recording the
    admission-rejection counts; gated: the admission must replay
    bit-identically from ``(seed, round)``), then runs that cohort flat
    vs two-tier hierarchical at E ∈ {4, 8} edge aggregators.  Gated
    (deterministic, always): the measured hier per-tier bytes
    (``AGG_STATS["hier_server_peak_bytes"]`` /
    ``["hier_edge_partial_bytes"]``) equal their ``memory_model`` twins,
    the round keeps ONE logical carrier dispatch plus E per-edge folds,
    and the hier server peak stays STRICTLY below the flat-round server
    peak at every edge count — the memory-wall claim the two-tier fold
    exists for, re-enforced on the fresh record by ``--compare``.

The per-shard kernel launches a sharded round fans out to are recorded in
the JSON under ``dispatches`` (``fedavg_grouped_shards`` = D per logical
round; the streaming scatters under ``stream_scatter*``) — see
kernels/ops.py for the counter semantics.

``--compare SEED.json`` (PR 5, run by the slow CI job against the committed
seed copy) turns the recorded trajectory into an enforced regression gate:
after the run, every gated metric must stay within x1.5 (deterministic:
membership staging elements, per-device panel/stream bytes) or x3 (wall
clocks: grouped-round per matrix cell, the sharded/replicated overhead
ratio — noise-padded for cross-machine comparison) of the seed record,
else the process exits non-zero; a gated metric that DISAPPEARS from the fresh
record fails rather than silently skipping.  When EVERY failure is a
wall-clock gate, the compare re-measures the whole suite once and
re-compares before failing (shared-runner noise); deterministic failures
— bytes, elements, missing sections — never get a retry.  Regenerate the
seed copy (``--smoke --json BENCH_kernels.json``) when a PR legitimately
moves a gated metric.

The freeze-decay section gates on SHAPE as well as magnitude: the fresh
record's byte metrics must decrease at every freeze transition regardless
of the seed's absolute numbers (so the gate holds even on the first run
against an older seed), and each point's deterministic bytes additionally
compare x1.5 against the seed point with the same ``n_frozen``.
"""
from __future__ import annotations

import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from benchmarks import common as C

# grouped-round trajectory matrix: (groups, clients-per-group)
GROUPED_MATRIX = [(1, 4), (1, 16), (4, 4), (4, 16), (8, 4), (8, 16)]
# the perf-gate cell: G=4 groups, K_total=16 clients
GATE_CELL = (4, 4)
# interpret-mode tolerance for the grouped<=masked wall-clock gate: both
# rounds run identical local SGD, so the gate only needs to catch the
# aggregation path regressing, not win every noisy CPU timing
GATE_TOL = 1.35
# sharded-vs-replicated wall gate: looser than GATE_TOL since PR 8 jitted
# the replicated round's reference aggregation into one fused dispatch —
# the round got ~25% faster, so the shard_map orchestration's FIXED cost
# (stream slicing, per-shard scatters, pacing tokens) is now a larger
# fraction of a smaller round on the 1-device CI runner.  A genuine
# sharded-path regression (an extra sync, a re-replication) still lands
# well beyond x2.
AGG_GATE_TOL = 2.0


def bench(ctx: dict, full: bool = False, record: dict = None):
    rng = jax.random.PRNGKey(0)
    B, H, K, S, hd = 2, 8, 2, 1024, 64
    q = jax.random.normal(rng, (B, H, S, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, K, S, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, K, S, hd))

    att = jax.jit(functools.partial(ops.attention, impl="chunked", bq=256,
                                    bk=256))
    us = C.time_call(att, q, k, v)
    flops = 4 * B * H * S * S * hd / 2  # causal
    C.emit("kernels/attention_chunked_1k", us,
           f"gflops_s={flops/us/1e3:.1f}")

    n = 2_000_000
    pn = jax.random.normal(rng, (n,))
    po = pn + 0.01 * jax.random.normal(jax.random.fold_in(rng, 3), (n,))
    net = jnp.zeros((n,))
    em = jax.jit(functools.partial(ops.effective_movement_update, impl="naive"))
    us = C.time_call(em, pn, po, net)
    C.emit("kernels/effective_movement_2M", us,
           f"gbytes_s={4*4*n/us/1e3:.2f}")
    em_pl = jax.jit(functools.partial(ops.effective_movement_update,
                                      impl="pallas"))
    us_pl = C.time_call(em_pl, pn, po, net, iters=3)
    C.emit("kernels/effective_movement_2M_pallas_interp", us_pl,
           "interpret_mode=1")

    Kc, n2 = 20, 1_000_000
    p = jax.random.normal(rng, (Kc, n2))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(rng, 4), (Kc,)))
    fa = jax.jit(functools.partial(ops.fedavg, impl="naive"))
    us = C.time_call(fa, p, w)
    C.emit("kernels/fedavg_20x1M", us, f"gbytes_s={4*Kc*n2/us/1e3:.2f}")

    _bench_cohort_aggregation(rng, full)
    return {
        "kernel_compare": _bench_kernel_compare(smoke=False, sink=record),
        "grouped_rounds": _bench_grouped_round(full=full, matrix=True,
                                               sink=record),
        "agg_compare": _bench_agg_compare(smoke=False, sink=record),
        "freeze_decay": _bench_freeze_decay(smoke=False, sink=record),
        "transport": _bench_transport(smoke=False, sink=record),
        "faults": _bench_faults(smoke=False, sink=record),
        "async": _bench_async(smoke=False, sink=record),
        "hierarchy": _bench_hierarchy(smoke=False, sink=record),
    }


def _bench_cohort_aggregation(rng, full: bool):
    """Packed-panel fedavg (fl/engine.py) vs the per-leaf einsum tree-map of
    client.cohort_round, on a realistic many-leaf trainable tree."""
    from repro.fl import engine as ENG

    Kc = 20
    leaf_shapes = [(64, 64)] * 24 + [(256, 64)] * 8 + [(64,)] * 32
    if full:
        leaf_shapes = [(256, 256)] * 24 + [(1024, 256)] * 8 + [(256,)] * 32
    tree = {
        f"l{i}": jax.random.normal(jax.random.fold_in(rng, 10 + i), (Kc,) + s)
        for i, s in enumerate(leaf_shapes)
    }
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(rng, 5), (Kc,)))
    n = sum(int(jnp.prod(jnp.asarray(s))) for s in leaf_shapes)

    @jax.jit
    def treemap_agg(trs, w):
        wn = w / jnp.sum(w)
        agg = lambda leaf: jnp.einsum(
            "k,k...->...", wn, leaf.astype(jnp.float32)
        ).astype(leaf.dtype)
        return jax.tree.map(agg, trs)

    us = C.time_call(treemap_agg, tree, w)
    C.emit("kernels/cohort_agg_treemap", us,
           f"n_params={n} gbytes_s={4*Kc*n/us/1e3:.2f}")

    template = jax.tree.map(lambda l: l[0], tree)

    def packed_agg(trs, w, impl):
        spec = ENG.make_pack_spec(template)
        panel = spec.pack_stacked(trs, Kc)
        return spec.unpack(ops.fedavg(panel, w / jnp.sum(w), impl=impl))

    pk = jax.jit(functools.partial(packed_agg, impl="naive"))
    us = C.time_call(pk, tree, w)
    C.emit("kernels/cohort_agg_packed", us,
           f"n_params={n} gbytes_s={4*Kc*n/us/1e3:.2f}")

    pk_pl = jax.jit(functools.partial(packed_agg, impl="pallas"))
    us_pl = C.time_call(pk_pl, tree, w, iters=3)
    C.emit("kernels/cohort_agg_packed_pallas_interp", us_pl, "interpret_mode=1")


_WIDTH_LOSSES = {}


def _width_loss_factory(f: int):
    # cached: loss closures are jit static keys, and the matrix revisits fracs
    if f not in _WIDTH_LOSSES:

        def loss_fn(tr, fro, bn, xb, yb):
            pred = xb[:, :f] @ tr["w"] + tr["b"]
            return jnp.mean((pred - yb[:, None]) ** 2), bn

        _WIDTH_LOSSES[f] = loss_fn
    return _WIDTH_LOSSES[f]


def _make_width_plans(d: int, G: int, k_per_group: int, out: int = 16):
    """HeteroFL-shaped cohort: G width groups slicing the leading rows of the
    global ``w``.  Fractions stay < 1 so even G=1 is a strict sub-structure
    (the identity fast path would bypass the grouped kernel)."""
    from repro.fl import engine as ENG

    rng = jax.random.PRNGKey(0)
    gtr = {"w": jax.random.normal(rng, (d, out)), "b": jnp.zeros((out,))}
    fracs = [(i + 1) / (G + 1) for i in range(G)]
    plans = []
    for gi, r in enumerate(fracs):
        f = max(1, int(d * r))
        sub = {"w": gtr["w"][:f], "b": gtr["b"]}
        xs = jax.random.normal(jax.random.fold_in(rng, gi),
                               (k_per_group, 16, d))
        ys = jax.random.normal(jax.random.fold_in(rng, 50 + gi),
                               (k_per_group, 16))
        rngs = jax.random.split(jax.random.fold_in(rng, 100 + gi),
                                k_per_group)
        plans.append(ENG.GroupPlan(
            _width_loss_factory(f), sub, {}, {}, xs, ys, rngs,
            jnp.arange(1.0, k_per_group + 1.0), 0.1, 2, 8,
        ))
    return plans, gtr


def _bench_grouped_cell(d: int, G: int, k_per_group: int, iters: int) -> dict:
    """One cell of the grouped-round matrix: fused group-compressed round vs
    the legacy dense-mask fused round, with dispatch/staging accounting."""
    from repro.fl import engine as ENG

    plans, gtr = _make_width_plans(d, G, k_per_group)
    eng = ENG.make_engine("packed")
    layout = ENG.make_group_layout(plans, gtr, {})
    k_total = G * k_per_group

    # warm compiles, then account one round of each aggregation path
    eng.grouped_round(plans, gtr, {})
    eng.grouped_round(plans, gtr, {}, impl="fused_masked")
    ops.reset_dispatches()
    eng.grouped_round(plans, gtr, {})
    disp = dict(ops.DISPATCHES)
    staged_grouped = ops.STAGED["fedavg_grouped"]
    assert disp.get("fedavg_grouped") == 1 and not disp.get("fedavg_masked"), (
        f"grouped round must issue exactly ONE fedavg_grouped dispatch "
        f"regardless of group count, saw {disp}"
    )
    staged_bound = G * layout.n + k_total
    assert staged_grouped <= staged_bound, (
        f"grouped aggregation staged {staged_grouped} membership elements, "
        f"over the G*n+K bound {staged_bound} (dense mask would be "
        f"{k_total * layout.n})"
    )
    ops.reset_dispatches()
    eng.grouped_round(plans, gtr, {}, impl="fused_masked")
    staged_masked = ops.STAGED["fedavg_masked"]
    assert staged_masked == k_total * layout.n
    ops.reset_dispatches()

    us_g = C.time_call(
        lambda: eng.grouped_round(plans, gtr, {}).loss, iters=iters
    )
    us_m = C.time_call(
        lambda: eng.grouped_round(plans, gtr, {}, impl="fused_masked").loss,
        iters=iters,
    )
    return {
        "G": G, "k_per_group": k_per_group, "k_total": k_total,
        "n": layout.n, "grouped_us": us_g, "masked_us": us_m,
        "speedup_grouped_vs_masked": us_m / us_g,
        "staged_grouped_elems": int(staged_grouped),
        "staged_masked_elems": int(staged_masked),
        "staged_bound_elems": int(staged_bound),
        "mask_bytes_grouped": int(staged_grouped) * 4,
        "mask_bytes_masked": int(staged_masked) * 4,
        "dispatches": disp,
    }


def _bench_grouped_round(full: bool = False, smoke: bool = False,
                         iters: int = 5, matrix: bool = False,
                         sink: dict = None) -> dict:
    """Grouped heterogeneous rounds (fl/engine.py::grouped_round): the fused
    group-compressed aggregation (``fedavg_grouped``) vs the legacy dense-
    mask fused round and the serial per-group oracle.  Returns the recorded
    cells; asserts the one-dispatch, staging-bound, and (at the gate cell)
    wall-clock contracts.  ``sink`` (the --json record) receives the result
    dict BEFORE any gate can fire, so a failing CI run still persists every
    number measured up to the failure."""
    from repro.fl import engine as ENG

    d = 128 if smoke else (4096 if full else 1024)
    cells = []
    out = {"d": d, "cells": cells}
    if sink is not None:
        sink["grouped_rounds"] = out
    todo = GROUPED_MATRIX if (matrix or smoke) else [GATE_CELL]
    for G, kpg in todo:
        cell = _bench_grouped_cell(d, G, kpg, iters)
        cells.append(cell)
        C.emit(
            f"kernels/grouped_round_G{G}_k{cell['k_total']}",
            cell["grouped_us"],
            f"masked_us={cell['masked_us']:.1f} n={cell['n']} "
            f"staged={cell['staged_grouped_elems']}/"
            f"{cell['staged_masked_elems']} agg_dispatches=1",
        )
    gate = next(
        c for c in cells
        if (c["G"], c["k_per_group"]) == GATE_CELL
    )
    if gate["grouped_us"] > gate["masked_us"] * GATE_TOL:
        # one re-measure before failing: the smoke shapes are small enough
        # that a co-tenant CPU spike on a shared CI runner can skew a single
        # median; a genuine aggregation regression fails both attempts
        retry = _bench_grouped_cell(d, *GATE_CELL, iters)
        gate["grouped_us_retry"] = retry["grouped_us"]
        gate["masked_us_retry"] = retry["masked_us"]
        assert retry["grouped_us"] <= retry["masked_us"] * GATE_TOL, (
            f"perf regression: grouped fused round "
            f"({gate['grouped_us']:.1f}/{retry['grouped_us']:.1f}us) slower "
            f"than the masked fused round "
            f"({gate['masked_us']:.1f}/{retry['masked_us']:.1f}us) at "
            f"G={gate['G']}, K={gate['k_total']} beyond the interpret-mode "
            f"tolerance x{GATE_TOL} on both attempts"
        )

    # serial oracle reference point at the gate cell
    plans, gtr = _make_width_plans(d, *GATE_CELL)
    serial = ENG.make_engine("vmap")
    us_s = C.time_call(
        lambda: serial.grouped_round(plans, gtr, {}).loss,
        iters=max(2, iters // 2),
    )
    C.emit("kernels/grouped_round_serial", us_s,
           f"groups={GATE_CELL[0]} k_total={GATE_CELL[0] * GATE_CELL[1]} "
           f"speedup_fused={us_s / gate['grouped_us']:.2f}x")
    out["serial_us_gate"] = us_s
    return out


def _bench_agg_compare(smoke: bool, sink: dict = None, iters: int = 5) -> dict:
    """Replicated vs column-sharded fused grouped aggregation at the gate
    cell: wall clock per round plus per-device panel bytes read from the
    ACTUAL panel sharding (engine.AGG_STATS metadata, not the analytic
    model), so the record catches a path that silently re-replicates the
    panel.  The per-device byte bound (replicated/D + tile padding) is
    asserted unconditionally — it is a correctness contract, not a timing;
    the wall-clock gate (sharded ≤ x1.35 replicated, one retry) fires only
    in smoke mode.  ``sink`` receives the result dict before any gate."""
    from repro.fl import engine as ENG
    from repro.fl import memory_model as MM
    from repro.kernels.fedavg import AGG_TILE

    d = 128 if smoke else 1024
    G, kpg = GATE_CELL
    plans, gtr = _make_width_plans(d, G, kpg)
    eng_r = ENG.make_engine("packed", agg="replicated")
    eng_s = ENG.make_engine("packed", agg="sharded")
    res = {"n_local_devices": len(jax.devices())}
    if sink is not None:
        sink["agg_compare"] = res
    eng_r.grouped_round(plans, gtr, {})
    stats_r = dict(ENG.AGG_STATS)
    ops.reset_dispatches()
    eng_s.grouped_round(plans, gtr, {})
    stats_s = dict(ENG.AGG_STATS)
    res["dispatches"] = dict(ops.DISPATCHES)
    ops.reset_dispatches()
    D = stats_s["n_shards"]
    k_total, n = stats_s["k_total"], stats_s["n"]
    bytes_r = 4 * stats_r["per_device_panel_elems"]
    bytes_s = 4 * stats_s["per_device_panel_elems"]
    layout = ENG.make_group_layout(plans, gtr, {})
    kns = [(k, int(ix.size)) for k, ix in zip(layout.ks, layout.idx)]
    stream_r = 4 * stats_r["per_device_stream_elems"]
    stream_s = 4 * stats_s["per_device_stream_elems"]
    stream_model = 4 * max(
        MM.agg_stream_elems_per_device(k, n_g, n_devices=D, agg="sharded")
        for k, n_g in kns
    )
    res.update(
        G=G, k_total=k_total, n=n, n_shards=D,
        n_padded_sharded=stats_s["n_padded"],
        per_device_panel_bytes_replicated=bytes_r,
        per_device_panel_bytes_sharded=bytes_s,
        per_device_panel_bytes_model=MM.server_aggregation_peak_bytes(
            k_total, n, G, n_devices=D, agg="sharded"
        ),
        per_device_stream_bytes_replicated=stream_r,
        per_device_stream_bytes_sharded=stream_s,
        per_device_stream_bytes_model=stream_model,
        stream_chunks_sharded=stats_s["stream_chunks"],
    )
    byte_bound = 4 * k_total * (-(-n // D) + AGG_TILE)
    assert bytes_s <= byte_bound, (
        f"column-sharded aggregation staged {bytes_s} panel bytes per "
        f"device, over the replicated/D + tile-padding bound {byte_bound} "
        f"(replicated panel is {bytes_r})"
    )
    # transient-stream gate: the shard-local stream's per-device bytes (read
    # from the real transfer sharding) must match the analytic model and
    # stay within max_g K_g*(n_g/D + AGG_TILE) — a silent re-replication of
    # the group panels across the agg mesh fails here
    stream_bound = 4 * max(k * (-(-n_g // D) + AGG_TILE) for k, n_g in kns)
    assert stream_s == stream_model, (
        f"measured per-device stream bytes {stream_s} != analytic model "
        f"{stream_model} (memory_model.agg_stream_elems_per_device drifted "
        f"from the engine's stream_plan)"
    )
    assert stream_s <= stream_bound, (
        f"shard-local stream staged {stream_s} bytes per device, over the "
        f"max_g K_g*(n_g/D + tile) bound {stream_bound} (a full group-panel "
        f"replica would be {stream_r})"
    )
    assert res["dispatches"].get("fedavg_grouped") == 1
    assert res["dispatches"].get("fedavg_grouped_shards") == D
    for attempt in range(2):
        us_r = C.time_call(
            lambda: eng_r.grouped_round(plans, gtr, {}).loss, iters=iters
        )
        us_s = C.time_call(
            lambda: eng_s.grouped_round(plans, gtr, {}).loss, iters=iters
        )
        res.update(replicated_us=us_r, sharded_us=us_s,
                   overhead_sharded_vs_replicated=us_s / us_r)
        if not smoke or us_s <= us_r * AGG_GATE_TOL:
            break  # retry once: shared-runner noise, not a regression
    C.emit("kernels/grouped_round_agg_replicated", us_r,
           f"per_dev_panel_bytes={bytes_r}")
    C.emit("kernels/grouped_round_agg_sharded", us_s,
           f"n_shards={D} per_dev_panel_bytes={bytes_s} "
           f"overhead={us_s / us_r:.2f}x")
    if smoke:
        assert us_s <= us_r * AGG_GATE_TOL, (
            f"perf regression: column-sharded fused round ({us_s:.1f}us) "
            f"slower than the replicated fused round ({us_r:.1f}us) beyond "
            f"x{AGG_GATE_TOL} at G={G}, K={k_total} on both attempts"
        )
    return res


# int8-wire gate at the gate cell: quantized payload (1 B/elem) + packed
# 4-bit scale exponents (0.5 B/col) + per-group bf16 base must land at or
# under 0.30x the f32 wire
WIRE_INT8_RATIO = 0.30


def _wire_model_groups(layout, n_shards: int):
    """Per-group ``(K_g, live-per-shard)`` entries for the sharded wire
    model: the live column histogram over the layout's column-shard
    ranges — the same accounting the engine's measured ``wire_bytes`` uses
    (tests/test_contract.py pins engine == model)."""
    cs = layout.column_shards(n_shards)
    gs = []
    for gi, k in enumerate(layout.ks):
        live = layout.group_active_cols(gi)
        gs.append((int(k), [
            int(np.sum((live >= o) & (live < o + cs.n_shard)))
            for o in cs.offsets
        ]))
    return gs


def _bench_transport(smoke: bool, sink: dict = None, iters: int = 5) -> dict:
    """Quantized/ragged/paced panel-stream transport record (ISSUE 7) at
    the gate cell: one sharded round per wire dtype, interconnect bytes
    from ``AGG_STATS`` (asserted equal to ``memory_model.agg_wire_bytes``
    — both are plan metadata, no device sync) and round wall clock.  The
    int8 wire gates at ≤ ``WIRE_INT8_RATIO``× the f32 wire, always — it is
    a deterministic byte figure, not a timing.  Also records the analytic
    ragged-vs-uniform ratio for a DepthFL-style concentrated cohort at 4
    column shards (pure plan metadata, so the 1-device CI runner measures
    the same number multi-device hardware would).  ``sink`` receives the
    result dict before any gate can fire."""
    from repro.fl import engine as ENG
    from repro.fl import memory_model as MM

    d = 128 if smoke else 1024
    G, kpg = GATE_CELL
    plans, gtr = _make_width_plans(d, G, kpg)
    layout = ENG.make_group_layout(plans, gtr, {})
    res = {"G": G, "k_total": G * kpg, "n": layout.n,
           "n_local_devices": len(jax.devices()), "dtypes": {}}
    if sink is not None:
        sink["transport"] = res
    for sd in ENG.STREAM_DTYPES:
        eng = ENG.make_engine("packed", agg="sharded", stream_dtype=sd)
        eng.grouped_round(plans, gtr, {})  # warm compiles (+ seeds int8 EF)
        st = dict(ENG.AGG_STATS)
        groups = _wire_model_groups(layout, st["n_shards"])
        model_w = MM.agg_wire_bytes(groups, agg="sharded", stream_dtype=sd)
        assert st["wire_bytes"] == model_w, (
            f"transport: measured {sd} wire bytes {st['wire_bytes']} != "
            f"analytic model {model_w} (memory_model.agg_wire_bytes drifted "
            f"from the engine's ragged stream)"
        )
        assert st["wire_bytes_uniform"] == MM.agg_wire_bytes_uniform(
            groups, agg="sharded", stream_dtype=sd
        )
        us = C.time_call(
            lambda: eng.grouped_round(plans, gtr, {}).loss, iters=iters
        )
        res["dtypes"][sd] = {
            "wire_bytes": st["wire_bytes"],
            "wire_bytes_uniform": st["wire_bytes_uniform"],
            "per_device_panel_bytes": st["per_device_panel_bytes"],
            "per_device_scales_bytes": st["per_device_scales_bytes"],
            "round_us": us,
        }
        C.emit(f"kernels/transport_round_{sd}", us,
               f"wire_bytes={st['wire_bytes']} "
               f"uniform={st['wire_bytes_uniform']} "
               f"panel_bytes={st['per_device_panel_bytes']}")
    wire_f32 = res["dtypes"]["f32"]["wire_bytes"]
    wire_int8 = res["dtypes"]["int8"]["wire_bytes"]
    res["int8_over_f32_wire"] = wire_int8 / wire_f32
    assert wire_int8 <= WIRE_INT8_RATIO * wire_f32, (
        f"wire regression: int8 stream put {wire_int8} bytes on the wire, "
        f"over {WIRE_INT8_RATIO}x the f32 wire ({wire_f32}) at "
        f"G={G}, K={G * kpg} — the scale side-channel must stay packed"
    )
    # DepthFL-style concentrated cohort at 4 column shards: the narrow
    # prefix groups leave the trailing shards with zero live columns, so
    # the ragged transfer ships them nothing while the uniform axis-0
    # split pays a full m_chunk pad row per shard per pass
    conc_plans, conc_gtr = _make_width_plans(d, 2, kpg)
    conc_layout = ENG.make_group_layout(conc_plans, conc_gtr, {})
    groups4 = _wire_model_groups(conc_layout, 4)
    ragged = MM.agg_wire_bytes(groups4, agg="sharded")
    uniform = MM.agg_wire_bytes_uniform(groups4, agg="sharded")
    res["concentrated"] = {
        "n_shards": 4, "wire_bytes_ragged": ragged,
        "wire_bytes_uniform": uniform,
        "ragged_over_uniform": ragged / uniform,
    }
    assert ragged < uniform, (
        f"ragged transfer saved nothing on the concentrated cohort "
        f"({ragged} vs {uniform})"
    )
    return res


# quarantine-overhead gate at the gate cell (ISSUE 8): a faulted round —
# armed in-kernel quarantine gate, a dropped client, a poisoned client —
# must stay within x1.15 of the clean round's wall clock (the fault layer
# rides the SAME single dispatch; only the gate's compare/where and the
# weight masking are extra work)
FAULTS_GATE_TOL = 1.15


def _bench_faults(smoke: bool, sink: dict = None, iters: int = 5) -> dict:
    """Fault-tolerance record (ISSUE 8) at the gate cell: wall clock of a
    clean round vs a faulted round (one dropped + one norm-blowup-poisoned
    client with the quarantine gate armed), gated at
    ``FAULTS_GATE_TOL`` in smoke mode with one noise-absorbing retry, plus
    the quarantine/staleness counters (verdict counts, staged/merged/
    evicted rows, staging bytes — all ``AGG_STATS`` plan metadata, pinned
    against the ``memory_model`` twins) so the CI artifact carries the
    fault telemetry.  ``sink`` receives the result dict before any gate
    can fire."""
    from repro.fl import engine as ENG
    from repro.fl import faults as FLT
    from repro.fl import memory_model as MM

    d = 128 if smoke else 1024
    G, kpg = GATE_CELL
    plans, gtr = _make_width_plans(d, G, kpg)
    k_total = G * kpg
    eng = ENG.make_engine("packed")
    res = {"G": G, "k_total": k_total,
           "n_local_devices": len(jax.devices())}
    if sink is not None:
        sink["faults"] = res

    # straggler park + merge across two rounds: record the staleness
    # counters and pin the staging bytes against the memory-model twin
    verdicts = [FLT.OK] * k_total
    verdicts[2] = FLT.ClientFault("straggler", delay=1)
    eng.grouped_round(plans, gtr, {},
                      faults=FLT.FaultPlan(verdicts=tuple(verdicts)))
    st_park = dict(ENG.AGG_STATS)
    widths = [int(e.vals.shape[0]) for e in eng._staging]
    assert st_park["fault_staging_bytes"] == MM.fault_staging_bytes(widths), (
        f"faults: measured staging bytes {st_park['fault_staging_bytes']} "
        f"!= memory-model twin {MM.fault_staging_bytes(widths)}"
    )
    eng.grouped_round(plans, gtr, {}, faults=FLT.all_ok(k_total))
    st_merge = dict(ENG.AGG_STATS)
    res["straggler"] = {
        "staged_rows": st_park["fault_staged_rows"],
        "staging_bytes": st_park["fault_staging_bytes"],
        "merged_rows": st_merge["fault_merged_rows"],
        "evicted_rows": st_merge["fault_evicted_rows"],
    }
    assert res["straggler"]["merged_rows"] == 1
    eng.reset_faults()

    # the gated comparison: clean round vs dropped+poisoned round with the
    # quarantine gate armed (finite norm bound) — same dispatch count
    verdicts = [FLT.OK] * k_total
    verdicts[1] = FLT.ClientFault("dropped")
    verdicts[5] = FLT.ClientFault("corrupt", mode="norm_blowup")
    fp = FLT.FaultPlan(verdicts=tuple(verdicts), norm_bound=1e6)
    eng.grouped_round(plans, gtr, {})                 # warm clean compiles
    eng.grouped_round(plans, gtr, {}, faults=fp)      # warm quarantined
    st_f = dict(ENG.AGG_STATS)
    fc = MM.fault_counts([v.kind for v in fp.verdicts])
    assert st_f["fault_dropped"] == fc["dropped"] == 1
    assert st_f["fault_corrupt"] == fc["corrupt"] == 1
    assert st_f["quarantine_bound"] == 1e6
    res["counters"] = {
        "fault_ok": st_f["fault_ok"], "fault_dropped": st_f["fault_dropped"],
        "fault_stragglers": st_f["fault_stragglers"],
        "fault_corrupt": st_f["fault_corrupt"],
        "quarantine_bound": st_f["quarantine_bound"],
    }
    ops.reset_dispatches()
    eng.grouped_round(plans, gtr, {}, faults=fp)
    assert ops.DISPATCHES["fedavg_grouped"] == 1, dict(ops.DISPATCHES)
    ops.reset_dispatches()
    for attempt in range(2):
        us_c = C.time_call(
            lambda: eng.grouped_round(plans, gtr, {}).loss, iters=iters
        )
        us_f = C.time_call(
            lambda: eng.grouped_round(plans, gtr, {}, faults=fp).loss,
            iters=iters,
        )
        res.update(clean_us=us_c, faulted_us=us_f,
                   overhead_faulted_vs_clean=us_f / us_c)
        if not smoke or us_f <= us_c * FAULTS_GATE_TOL:
            break  # retry once: shared-runner noise, not a regression
    C.emit("kernels/faulted_round", us_f,
           f"clean_us={us_c:.1f} overhead={us_f / us_c:.2f}x "
           f"staging_bytes={res['straggler']['staging_bytes']}")
    if smoke:
        assert us_f <= us_c * FAULTS_GATE_TOL, (
            f"perf regression: the quarantined round ({us_f:.1f}us) costs "
            f"more than x{FAULTS_GATE_TOL} the clean round ({us_c:.1f}us) "
            f"at G={G}, K={k_total} on both attempts — the fault gate must "
            f"stay fused in the single dispatch"
        )
    return res


# async-publish gate at the gate cell (ISSUE 9): a staleness-0 publish
# makes the VERBATIM grouped_round call, so it may only cost the host-side
# buffer/version bookkeeping on top of the sync round — x1.15, same budget
# as the quarantine gate
ASYNC_GATE_TOL = 1.15


def _bench_async(smoke: bool, sink: dict = None, iters: int = 5) -> dict:
    """Async buffered-aggregation record (ISSUE 9) at the gate cell:
    publishes/sec through ``fl/async_server.py::AsyncAggServer`` vs sync
    rounds/sec through ``grouped_round`` on the identical cohort, the sync
    side state-churned like a real training loop (each round's output
    feeds the next — the server pays the identical churn through
    ``self.trainable``, so a constant-input baseline would overstate the
    async overhead).  Gated in smoke mode (one noise-absorbing retry): the
    staleness-0 publish within ``ASYNC_GATE_TOL`` of the sync round.  Gated always (deterministic):
    the buffer's peak byte occupancy — both the server's own accounting and
    the measured ``AGG_STATS`` figure — equal to the
    ``memory_model.async_buffer_bytes`` twin, and exactly ONE
    ``fedavg_grouped`` dispatch per publish.  Also records an ungated
    stale-publish point (one group a version behind at β=0.9: the parked
    rows ride the publish's side inputs) with its staleness histogram.
    ``sink`` receives the result dict before any gate can fire."""
    from repro.fl import async_server as AS
    from repro.fl import engine as ENG
    from repro.fl import memory_model as MM

    d = 128 if smoke else 1024
    G, kpg = GATE_CELL
    plans, gtr = _make_width_plans(d, G, kpg)
    k_total = G * kpg
    layout = ENG.make_group_layout(plans, gtr, {})
    res = {"G": G, "k_total": k_total, "n": layout.n,
           "publish_at": k_total}
    if sink is not None:
        sink["async"] = res

    # the sync baseline carries its state round to round (a real training
    # loop feeds each round's output into the next) — a constant-input
    # round would understate the sync side and overstate the async
    # overhead, since the server pays the same churn via self.trainable
    eng_sync = ENG.make_engine("packed")
    sync_state = {"tr": gtr}

    def one_sync_round():
        res = eng_sync.grouped_round(plans, sync_state["tr"], {})
        sync_state["tr"] = res.trainable
        return res.loss

    one_sync_round()  # warm the sync compiles

    srv = AS.AsyncAggServer(ENG.make_engine("packed"), gtr, {},
                            publish_at=k_total)

    def one_publish():
        for p in plans:
            srv.submit(p, srv.version)
        return srv.publish().loss

    one_publish()  # warm (the same compiles — the call is verbatim)
    # deterministic gates on a fully-buffered cohort: the server's peak
    # buffer accounting, the measured AGG_STATS figure, and the analytic
    # twin must agree (per-plan row panels cover the plan's own columns)
    for p in plans:
        srv.submit(p, srv.version)
    peak = srv.buffer_bytes()
    model = MM.async_buffer_bytes(
        [(e.k, e.n_cols) for e in srv.buffer]
    )
    assert peak == model, (
        f"async: server buffer accounting {peak} != memory-model twin "
        f"{model}"
    )
    ops.reset_dispatches()
    srv.publish()
    assert ops.DISPATCHES.get("fedavg_grouped") == 1, dict(ops.DISPATCHES)
    ops.reset_dispatches()
    st = dict(ENG.AGG_STATS)
    assert st["async_buffer_bytes"] == model, (
        f"async: measured AGG_STATS buffer bytes {st['async_buffer_bytes']} "
        f"!= memory-model twin {model}"
    )
    res.update(buffer_peak_bytes=peak, buffer_peak_bytes_model=model)

    for attempt in range(2):
        us_sync = C.time_call(one_sync_round, iters=iters)
        us_pub = C.time_call(one_publish, iters=iters)
        res.update(
            sync_round_us=us_sync, async_publish_us=us_pub,
            overhead_async_vs_sync=us_pub / us_sync,
            sync_rounds_per_sec=1e6 / us_sync,
            async_publishes_per_sec=1e6 / us_pub,
        )
        if not smoke or us_pub <= us_sync * ASYNC_GATE_TOL:
            break  # retry once: shared-runner noise, not a regression
    C.emit("kernels/async_publish", us_pub,
           f"sync_us={us_sync:.1f} overhead={us_pub / us_sync:.2f}x "
           f"publishes_s={1e6 / us_pub:.1f} buffer_bytes={peak}")
    if smoke:
        assert us_pub <= us_sync * ASYNC_GATE_TOL, (
            f"perf regression: the async publish ({us_pub:.1f}us) costs "
            f"more than x{ASYNC_GATE_TOL} the sync round ({us_sync:.1f}us) "
            f"at G={G}, K={k_total} on both attempts — the buffer/version "
            f"bookkeeping must stay host-side metadata"
        )

    # ungated stale-publish data point: one group reports a version late,
    # its rows park in the engine staging buffer and merge as w*beta^s side
    # inputs riding the publish's single dispatch
    srv_st = AS.AsyncAggServer(ENG.make_engine("packed"), gtr, {},
                               publish_at=k_total, beta=0.9)
    for p in plans:
        srv_st.submit(p, srv_st.version)
    srv_st.publish()

    def stale_publish():
        srv_st.submit(plans[0], srv_st.version - 1)  # one group at s=1
        for p in plans[1:]:
            srv_st.submit(p, srv_st.version)
        srv_st.submit(plans[0], srv_st.version)  # keep k_fresh == k_total
        return srv_st.publish().loss

    stale_publish()  # warm the armed side-merge compiles
    us_st = C.time_call(stale_publish, iters=max(2, iters // 2))
    st_s = dict(ENG.AGG_STATS)
    res["stale"] = {
        "publish_us": us_st,
        "stale_rows": st_s["async_stale_rows"],
        "staleness_hist": {str(k): v for k, v in
                           st_s["async_staleness_hist"].items()},
    }
    assert st_s["async_stale_rows"] == kpg
    C.emit("kernels/async_publish_stale", us_st,
           f"stale_rows={st_s['async_stale_rows']} "
           f"hist={st_s['async_staleness_hist']}")
    return res


# freeze-decay schedule: fraction of PANEL columns frozen at each freeze
# point.  Leading columns freeze first (leading blocks converge first —
# the order the Table 4 freezing benchmark's EM determination produces on
# the progressive schedule); each step freezes another quarter of the
# packed space, so every transition must shrink the panel by whole tiles.
FREEZE_FRACS = (0.0, 0.25, 0.5, 0.75)


def _bench_freeze_decay(smoke: bool, sink: dict = None, iters: int = 3) -> dict:
    """Freezing-aware layout decay record (ISSUE 6): per-device panel and
    transient-stream bytes vs round across a schedule of freeze events, per
    aggregation placement, all read from the real sharding metadata
    (``engine.AGG_STATS``) and pinned against ``memory_model``'s
    frozen-fraction term.  Gated here (always — these are deterministic
    byte figures, not timings): measured == model at every point, and both
    placements' bytes strictly DECREASE at every freeze transition — the
    paper's peak-memory-decay claim, measured.  ``--compare`` re-enforces
    the decay shape on the fresh record (compare_trajectories), so a layout
    change that stops shrinking the panel fails the slow CI job even if
    every wall clock looks fine.  ``sink`` receives the result dict before
    any gate can fire."""
    from repro.fl import engine as ENG
    from repro.fl import memory_model as MM

    d = 128 if smoke else 1024
    G, kpg = GATE_CELL
    plans, gtr = _make_width_plans(d, G, kpg)
    eng_r = ENG.make_engine("packed", agg="replicated")
    eng_s = ENG.make_engine("packed", agg="sharded")
    n = ENG.make_group_layout(plans, gtr, {}).n
    points: list = []
    res = {"d": d, "G": G, "k_total": G * kpg, "n": n,
           "n_local_devices": len(jax.devices()), "points": points}
    if sink is not None:
        sink["freeze_decay"] = res
    for rnd, frac in enumerate(FREEZE_FRACS):
        n_frozen = int(n * frac)
        mask = np.zeros(n, bool)
        mask[:n_frozen] = True
        fro = ENG.make_frozen_columns(mask)
        us_r = C.time_call(
            lambda: eng_r.grouped_round(plans, gtr, {}, frozen=fro).loss,
            iters=iters,
        )
        st_r = dict(ENG.AGG_STATS)
        us_s = C.time_call(
            lambda: eng_s.grouped_round(plans, gtr, {}, frozen=fro).loss,
            iters=iters,
        )
        st_s = dict(ENG.AGG_STATS)
        D = st_s["n_shards"]
        layout = ENG.make_group_layout(plans, gtr, {}, frozen=fro)
        g_kn = [(k, int(ix.size), int(np.sum(dd >= layout.n_active)))
                for k, ix, dd in zip(layout.ks, layout.idx, layout.dst)]
        point = {
            "round": rnd, "n_frozen": n_frozen,
            "n_active": n - n_frozen,
            "per_device_panel_bytes_replicated":
                4 * st_r["per_device_panel_elems"],
            "per_device_panel_bytes_sharded":
                4 * st_s["per_device_panel_elems"],
            "per_device_stream_bytes_replicated":
                4 * st_r["per_device_stream_elems"],
            "per_device_stream_bytes_sharded":
                4 * st_s["per_device_stream_elems"],
            "replicated_us": us_r, "sharded_us": us_s,
        }
        points.append(point)
        # model == measured, per placement, at every freeze point
        for agg, st in (("replicated", st_r), ("sharded", st_s)):
            panel_model = st["k_total"] * MM.agg_columns_per_device(
                n, n_devices=st["n_shards"], agg=agg, n_frozen=n_frozen
            )
            stream_model = max(
                MM.agg_stream_elems_per_device(
                    k, n_g, n_devices=st["n_shards"], agg=agg, n_frozen=f
                )
                for k, n_g, f in g_kn
            )
            assert st["per_device_panel_elems"] == panel_model, (
                f"freeze decay: measured {agg} panel elems "
                f"{st['per_device_panel_elems']} != model {panel_model} at "
                f"n_frozen={n_frozen} (memory_model drifted from the layout)"
            )
            assert st["per_device_stream_elems"] == stream_model, (
                f"freeze decay: measured {agg} stream elems "
                f"{st['per_device_stream_elems']} != model {stream_model} "
                f"at n_frozen={n_frozen}"
            )
            assert st["n_frozen"] == n_frozen and st["n_active"] == n - n_frozen
        C.emit(
            f"kernels/freeze_decay_f{int(frac * 100)}", us_s,
            f"n_frozen={n_frozen} "
            f"panel_bytes_repl={point['per_device_panel_bytes_replicated']} "
            f"panel_bytes_shard={point['per_device_panel_bytes_sharded']} "
            f"stream_bytes_shard={point['per_device_stream_bytes_sharded']}",
        )
    # the decay gate: every freeze transition must strictly shrink BOTH
    # placements' panel and stream bytes (the schedule steps whole tiles,
    # so tile padding cannot mask a step on any realistic device count)
    for prev, cur in zip(points, points[1:]):
        for key in ("per_device_panel_bytes_replicated",
                    "per_device_panel_bytes_sharded",
                    "per_device_stream_bytes_replicated",
                    "per_device_stream_bytes_sharded"):
            assert cur[key] < prev[key], (
                f"freeze decay: {key} did not decrease at the "
                f"n_frozen={cur['n_frozen']} transition "
                f"({prev[key]} -> {cur[key]}) — frozen columns are not "
                f"leaving the panel/stream"
            )
    return res


# two-tier hierarchy (ISSUE 10): the population the registry materializes,
# the cohort admission draws from it, and the edge counts the gate cell's
# hierarchical round runs at.  The pop=1M registry is columnar numpy and
# builds in well under a second, so even smoke mode keeps the full million.
HIER_POPULATION = 1_000_000
HIER_COHORT = 512
HIER_EDGES = (4, 8)
HIER_ROUND = 3  # arbitrary non-zero round index: admission replays from it


def _make_cohort_plans(d: int, ks, weights, out: int = 16):
    """``_make_width_plans`` with RAGGED per-group client counts and real
    aggregation weights — the shape a memory-budgeted admitted cohort has
    (``fl/population.py``): group g holds ``ks[g]`` clients carrying
    ``weights[g]``."""
    from repro.fl import engine as ENG

    G = len(ks)
    rng = jax.random.PRNGKey(0)
    gtr = {"w": jax.random.normal(rng, (d, out)), "b": jnp.zeros((out,))}
    fracs = [(i + 1) / (G + 1) for i in range(G)]
    plans = []
    for gi, r in enumerate(fracs):
        f = max(1, int(d * r))
        k = int(ks[gi])
        sub = {"w": gtr["w"][:f], "b": gtr["b"]}
        xs = jax.random.normal(jax.random.fold_in(rng, gi), (k, 16, d))
        ys = jax.random.normal(jax.random.fold_in(rng, 50 + gi), (k, 16))
        rngs = jax.random.split(jax.random.fold_in(rng, 100 + gi), k)
        plans.append(ENG.GroupPlan(
            _width_loss_factory(f), sub, {}, {}, xs, ys, rngs,
            jnp.asarray(weights[gi], jnp.float32), 0.1, 2, 8,
        ))
    return plans, gtr


def _bench_hierarchy(smoke: bool, sink: dict = None, iters: int = 3) -> dict:
    """Million-client round record (ISSUE 10): build the pop=1M registry
    (``fl/population.py``), admit a memory-budgeted cohort of
    ``HIER_COHORT`` through the two admission gates, then run that cohort
    as ONE round — flat (single-tier fused) and two-tier hierarchical at
    ``HIER_EDGES`` edge aggregators — recording admission counts, wall
    clocks, and per-tier peak bytes.  Gated here (deterministic figures,
    no retry): admission must replay bit-identically from ``(seed,
    round)``; the measured ``AGG_STATS`` hier peaks must equal their
    ``memory_model`` twins; and every edge count's server peak must stay
    STRICTLY below the flat-round server peak — the memory-wall win the
    two-tier fold exists for.  ``--compare`` re-enforces the
    below-flat shape on the fresh record (compare_trajectories).
    ``sink`` receives the result dict before any gate can fire."""
    from repro.fl import engine as ENG
    from repro.fl import memory_model as MM
    from repro.fl import population as POP
    from repro.models import cnn as CNN

    d = 128 if smoke else 1024
    G = 4
    cfg = POP.PopulationConfig(n_clients=HIER_POPULATION, n_groups=G, seed=0)
    t0 = time.perf_counter()
    pop = POP.build_population(cfg)
    build_us = (time.perf_counter() - t0) * 1e6
    # resnet34's top-tier footprint (≈735 MB) sits ABOVE group 3's 700 MB
    # budget floor, so the device-budget gate genuinely rejects — the
    # recorded rejection counts are a live figure, not a vacuous zero
    need = POP.group_train_need_mb(CNN.CNNConfig("resnet34"), G)
    t0 = time.perf_counter()
    cohort = POP.sample_cohort(pop, HIER_ROUND, cohort_size=HIER_COHORT,
                               need_mb=need)
    sample_us = (time.perf_counter() - t0) * 1e6
    replay = POP.sample_cohort(pop, HIER_ROUND, cohort_size=HIER_COHORT,
                               need_mb=need)
    assert np.array_equal(cohort.ids, replay.ids), (
        "hierarchy: cohort admission is not reproducible from "
        "(seed, round) — sample_cohort must be a pure function"
    )
    ks = [int(np.sum(cohort.groups == g)) for g in range(G)]
    assert all(k > 0 for k in ks), f"empty structure group in cohort: {ks}"
    gw = [cohort.weights[cohort.groups == g] for g in range(G)]
    plans, gtr = _make_cohort_plans(d, ks, gw)
    eng = ENG.make_engine("packed")
    layout = ENG.make_group_layout(plans, gtr, {})
    k_total = int(sum(ks))
    res = {
        "d": d, "G": G, "n": layout.n, "k_total": k_total,
        "n_local_devices": len(jax.devices()),
        "population": {
            "n_clients": pop.n_clients, "n_groups": G, "seed": cfg.seed,
            "build_us": build_us,
            "strata": [int(len(s)) for s in pop.strata],
        },
        "cohort": {
            "round": cohort.round_idx, "k": cohort.k,
            "cohort_size": HIER_COHORT, "sample_us": sample_us,
            "group_counts": ks,
        },
        "admission": {
            "considered": cohort.considered,
            "rejected_budget": cohort.rejected_budget,
            "rejected_server": cohort.rejected_server,
        },
    }
    if sink is not None:
        sink["hierarchy"] = res

    # the flat (single-tier) round the hierarchy competes with: its server
    # peak is the memory_model flat-round twin; cross-check the measured
    # panel against the twin's dominant term so the figures stay honest
    eng.grouped_round(plans, gtr, {})  # warm compiles
    st_flat = dict(ENG.AGG_STATS)
    flat_peak = int(MM.server_aggregation_peak_bytes(k_total, layout.n, G))
    assert st_flat["per_device_panel_elems"] == (
        k_total * MM.agg_columns_per_device(layout.n)
    ), "hierarchy: flat panel elems drifted from the memory-model twin"
    us_flat = C.time_call(
        lambda: eng.grouped_round(plans, gtr, {}).loss, iters=iters
    )
    res["flat"] = {"round_us": us_flat, "server_peak_bytes": flat_peak,
                   "per_device_panel_bytes":
                       int(st_flat["per_device_panel_bytes"])}
    C.emit("kernels/hier_flat_round", us_flat,
           f"k={k_total} n={layout.n} flat_peak_bytes={flat_peak}")

    res["edges"] = {}
    for E in HIER_EDGES:
        eng.grouped_round(plans, gtr, {}, edges=E)  # warm compiles
        ops.reset_dispatches()
        eng.grouped_round(plans, gtr, {}, edges=E)
        disp = dict(ops.DISPATCHES)
        assert disp.get("fedavg_grouped") == 1, (
            f"hierarchical round must keep the ONE logical carrier "
            f"dispatch, saw {disp}"
        )
        assert disp.get("fedavg_grouped_edges") == E, (
            f"expected {E} per-edge folds, saw {disp}"
        )
        st = dict(ENG.AGG_STATS)
        assert st["hier_edges_used"] == E
        assert st["hier_server_peak_bytes"] == MM.hier_server_peak_bytes(
            layout.n, E
        ), (
            f"hierarchy: measured server peak "
            f"{st['hier_server_peak_bytes']} != memory-model twin "
            f"{MM.hier_server_peak_bytes(layout.n, E)} at E={E}"
        )
        assert st["hier_edge_partial_bytes"] == MM.edge_partial_bytes(
            layout.n
        ), (
            f"hierarchy: measured edge partial "
            f"{st['hier_edge_partial_bytes']} != memory-model twin "
            f"{MM.edge_partial_bytes(layout.n)}"
        )
        ops.reset_dispatches()
        us_h = C.time_call(
            lambda: eng.grouped_round(plans, gtr, {}, edges=E).loss,
            iters=iters,
        )
        res["edges"][str(E)] = {
            "round_us": us_h,
            "hier_server_peak_bytes": int(st["hier_server_peak_bytes"]),
            "edge_partial_bytes": int(st["hier_edge_partial_bytes"]),
            "edges_used": int(st["hier_edges_used"]),
            "wire_bytes": int(st["wire_bytes"]),
        }
        C.emit(f"kernels/hier_round_e{E}", us_h,
               f"flat_us={us_flat:.1f} "
               f"hier_peak_bytes={st['hier_server_peak_bytes']} "
               f"flat_peak_bytes={flat_peak}")
    # the memory-wall gate (deterministic, always): the two-tier server
    # only ever holds E (num, den) partial pairs plus the carrier — it
    # must beat the flat K-row panel at every recorded edge count
    for E in HIER_EDGES:
        hp = res["edges"][str(E)]["hier_server_peak_bytes"]
        assert hp < flat_peak, (
            f"hierarchy: server peak {hp} at E={E} is not strictly below "
            f"the flat-round peak {flat_peak} — the two-tier fold lost "
            f"its memory-wall win"
        )
    return res


def _bench_kernel_compare(smoke: bool, sink: dict = None) -> dict:
    """Aggregation-kernel wall clock in isolation: dense-mask fedavg_masked
    vs group-compressed fedavg_grouped on the same panel (jnp paths, jitted;
    the Pallas kernels are interpret-mode on CPU and tracked separately).
    In smoke mode this is ALSO gated (with one noise-absorbing re-measure) —
    unlike the round-level gate (whose wall clock is dominated by identical
    local SGD), an aggregation-only regression shows up here undiluted.
    ``sink`` (the --json record) receives the result dict before the gate
    can fire."""
    from repro.kernels import ref

    K, n, G = (8, 100_000, 4) if smoke else (32, 1_000_000, 4)
    rng = jax.random.PRNGKey(3)
    gid = jnp.asarray([i * G // K for i in range(K)])
    gmask = (jax.random.uniform(jax.random.fold_in(rng, 1), (G, n)) > 0.3
             ).astype(jnp.float32)
    mask = gmask[gid]
    p = jax.random.normal(rng, (K, n)) * mask
    w = jnp.arange(1.0, K + 1.0)
    wsum = jnp.zeros((G,)).at[gid].add(w)
    prev = jnp.zeros((n,))
    masked = jax.jit(ref.fedavg_masked)
    grouped = jax.jit(ref.fedavg_grouped)
    res = {
        "K": K, "n": n, "G": G,
        "mask_bytes_masked": 4 * K * n, "mask_bytes_grouped": 4 * (G * n + G),
    }
    if sink is not None:
        sink["kernel_compare"] = res
    for attempt in range(2):
        us_m = C.time_call(masked, p, w, mask, prev, iters=5)
        us_g = C.time_call(grouped, p, w, gmask, wsum, prev, iters=5)
        res.update(masked_us=us_m, grouped_us=us_g,
                   speedup_grouped_vs_masked=us_m / us_g)
        if not smoke or us_g <= us_m * GATE_TOL:
            break  # retry once: shared-runner noise, not a regression
    C.emit(f"kernels/fedavg_masked_{K}x{n//1000}k", us_m,
           f"mask_bytes={4*K*n}")
    C.emit(f"kernels/fedavg_grouped_{K}x{n//1000}k", us_g,
           f"mask_bytes={4*(G*n+G)} speedup_vs_masked={us_m/us_g:.2f}x")
    if smoke:
        assert us_g <= us_m * GATE_TOL, (
            f"perf regression: group-compressed aggregation kernel "
            f"({us_g:.1f}us) slower than the dense-mask kernel "
            f"({us_m:.1f}us) beyond x{GATE_TOL} on the same {K}x{n} panel "
            f"on both attempts"
        )
    return res


# --compare regression factors.  DETERMINISTIC metrics (staged elements,
# per-device panel/stream bytes) regress only when the code regresses, so
# they gate tight at x1.5.  WALL-CLOCK metrics compare a fresh CI-runner
# measurement against a seed recorded on a different machine, with
# co-tenant noise on top — the recorded trajectory itself shows >2x
# same-machine swings (grouped_us vs grouped_us_retry in one run) — so they
# gate at x3: loose enough to survive a shared-runner spike, tight enough
# to catch a step-function regression (losing donation/pipelining costs
# more than 3x).  The fresh side additionally uses the smoke gate's retry
# re-measure when one was taken (min of the two), never the seed side.
COMPARE_FACTOR = 1.5
COMPARE_WALL_FACTOR = 3.0

# gated metrics for --compare: (key, is_wall_clock).  The agg comparison is
# gated on the sharded/replicated overhead RATIO, not the absolute wall
# clocks: both sides are timed seconds apart in the same run, so machine-
# load noise is common-mode and cancels in the ratio (observed: a 4x
# absolute swing with the ratio stable), while the absolute round time at
# the same cell is already gated via grouped_rounds[G=4,kpg=4].grouped_us.
COMPARE_AGG_KEYS = (("overhead_sharded_vs_replicated", True),
                    ("per_device_panel_bytes_sharded", False),
                    ("per_device_stream_bytes_sharded", False))
COMPARE_CELL_KEYS = (("grouped_us", True), ("staged_grouped_elems", False))
COMPARE_KERNEL_KEYS = (("grouped_us", True),)
COMPARE_DECAY_KEYS = ("per_device_panel_bytes_replicated",
                      "per_device_panel_bytes_sharded",
                      "per_device_stream_bytes_replicated",
                      "per_device_stream_bytes_sharded")
# transport gate (ISSUE 7): wire bytes are deterministic plan metadata, so
# they gate tight at x1.5 per wire dtype; the per-dtype round wall clock
# gates at the wall factor like every other timing
COMPARE_TRANSPORT_KEYS = (("wire_bytes", False), ("round_us", True))
# faults gate (ISSUE 8): the quarantine overhead ratio is common-mode like
# the agg ratio (both sides timed seconds apart in one run), so it gates at
# the wall factor; the staging bytes are deterministic plan metadata
COMPARE_FAULTS_KEYS = (("overhead_faulted_vs_clean", True),
                       ("faulted_us", True))
# async gate (ISSUE 9): the publish-vs-sync overhead ratio is common-mode
# (both sides timed seconds apart in one run) and gates at the wall factor
# with the absolute publish wall clock; the buffer peak bytes are
# deterministic plan metadata and gate tight at x1.5
COMPARE_ASYNC_KEYS = (("overhead_async_vs_sync", True),
                      ("async_publish_us", True),
                      ("buffer_peak_bytes", False))
# hierarchy gate (ISSUE 10): per-tier peak bytes are deterministic plan
# metadata (x1.5 vs seed, per edge count), round wall clocks gate at x3;
# the section ALSO gates on shape like freeze_decay — the fresh record's
# hier server peak must stay strictly below the fresh flat-round peak at
# every edge count, independent of the seed's absolute numbers
COMPARE_HIER_KEYS = (("round_us", True),
                     ("hier_server_peak_bytes", False),
                     ("edge_partial_bytes", False))


def compare_trajectories(new: dict, seed: dict,
                         factor: float = COMPARE_FACTOR,
                         wall_factor: float = COMPARE_WALL_FACTOR):
    """Regression gate for ``--compare``: check every gated metric of the
    fresh record against the committed seed trajectory and return
    ``(failures, n_checked)``.  A metric regresses when it exceeds
    ``factor ×`` (deterministic) / ``wall_factor ×`` (wall clock) its seed
    value.  The skip rules are ASYMMETRIC: metrics missing from the SEED
    copy (an older schema) are skipped so extending the record never breaks
    the gate, but a gated metric present in the seed and missing from the
    fresh record FAILS — a refactor that renames a key or drops a record
    section must not silently disable the gate.  Only same-backend records
    are comparable — wall clocks from a TPU seed mean nothing on a CPU
    runner.

    Each failure is a ``(message, is_wall_clock)`` pair: ``main`` grants
    timing-only failures ONE automatic re-measure (shared-runner noise),
    while any deterministic (byte/element) failure fails immediately."""
    fails: list = []
    checked = [0]

    def check(name, new_v, seed_v, wall):
        if seed_v is None or seed_v <= 0:
            return  # not in the seed (older schema): legitimately skippable
        if new_v is None:
            fails.append((
                f"{name}: missing from the fresh record (seed has "
                f"{seed_v:.1f}) — gated metrics must not silently disappear",
                False,  # a schema break, not noise: no re-measure
            ))
            return
        checked[0] += 1
        f = wall_factor if wall else factor
        if new_v > seed_v * f:
            fails.append(
                (f"{name}: {new_v:.1f} > x{f} seed {seed_v:.1f}", wall)
            )

    if new.get("backend") != seed.get("backend"):
        return ([(f"backend mismatch: new={new.get('backend')!r} "
                  f"seed={seed.get('backend')!r} — regenerate the seed copy "
                  f"on the comparison backend", False)], 0)
    # iterate the SEED's cells so a shrunken fresh matrix fails instead of
    # silently skipping the dropped cells
    new_cells = {(c["G"], c["k_per_group"]): c
                 for c in new.get("grouped_rounds", {}).get("cells", [])}
    for key, s in (
        ((c["G"], c["k_per_group"]), c)
        for c in seed.get("grouped_rounds", {}).get("cells", [])
    ):
        c = new_cells.get(key)
        tag = f"grouped_rounds[G={key[0]},kpg={key[1]}]"
        if c is None:
            fails.append(
                (f"{tag}: cell missing from the fresh record", False)
            )
            continue
        for mkey, wall in COMPARE_CELL_KEYS:
            new_v = c.get(mkey)
            if wall:
                # the smoke gate re-measures a noisy cell once; gate on the
                # better of the two fresh measurements
                retry = c.get(mkey + "_retry")
                if new_v is not None and retry is not None:
                    new_v = min(new_v, retry)
            check(f"{tag}.{mkey}", new_v, s.get(mkey), wall)
    na, sa = new.get("agg_compare", {}), seed.get("agg_compare", {})
    for mkey, wall in COMPARE_AGG_KEYS:
        check(f"agg_compare.{mkey}", na.get(mkey), sa.get(mkey), wall)
    nk, sk = new.get("kernel_compare", {}), seed.get("kernel_compare", {})
    for mkey, wall in COMPARE_KERNEL_KEYS:
        check(f"kernel_compare.{mkey}", nk.get(mkey), sk.get(mkey), wall)
    # freeze-decay gate (ISSUE 6): the FRESH record must show per-device
    # panel and stream bytes strictly decreasing at every freeze transition
    # — the decay SHAPE is the contract, independent of the seed's absolute
    # numbers — and the per-point deterministic bytes also gate at x1.5
    # against the seed points (matched by n_frozen).  A freeze_decay
    # section present in the seed and missing from the fresh record fails
    # like any other gated metric.
    nf, sf = new.get("freeze_decay", {}), seed.get("freeze_decay", {})
    if sf and not nf:
        fails.append(
            ("freeze_decay: section missing from the fresh record", False)
        )
    pts = nf.get("points", [])
    for prev_p, p in zip(pts, pts[1:]):
        if p.get("n_frozen", 0) <= prev_p.get("n_frozen", 0):
            continue  # not a freeze transition
        for mkey in COMPARE_DECAY_KEYS:
            checked[0] += 1
            if not p.get(mkey, 0) < prev_p.get(mkey, float("inf")):
                fails.append((
                    f"freeze_decay.{mkey}: did not decrease at "
                    f"n_frozen={p.get('n_frozen')} "
                    f"({prev_p.get(mkey)} -> {p.get(mkey)})",
                    False,
                ))
    seed_pts = {p.get("n_frozen"): p for p in sf.get("points", [])}
    for p in pts:
        s = seed_pts.get(p.get("n_frozen"))
        if s is None:
            continue
        for mkey in COMPARE_DECAY_KEYS:
            check(f"freeze_decay[n_frozen={p.get('n_frozen')}].{mkey}",
                  p.get(mkey), s.get(mkey), False)
    # transport gate (ISSUE 7): wire bytes per dtype gate deterministic at
    # x1.5, wall clocks at x3; a transport section present in the seed and
    # missing from the fresh record fails like any other gated metric, and
    # so does a wire-dtype entry that disappears
    ntr, str_ = new.get("transport", {}), seed.get("transport", {})
    if str_ and not ntr:
        fails.append(
            ("transport: section missing from the fresh record", False)
        )
    for sd, s_ent in str_.get("dtypes", {}).items():
        n_ent = ntr.get("dtypes", {}).get(sd, {})
        for mkey, wall in COMPARE_TRANSPORT_KEYS:
            check(f"transport.{sd}.{mkey}", n_ent.get(mkey),
                  s_ent.get(mkey), wall)
    sc, nc = str_.get("concentrated", {}), ntr.get("concentrated", {})
    check("transport.concentrated.wire_bytes_ragged",
          nc.get("wire_bytes_ragged"), sc.get("wire_bytes_ragged"), False)
    # faults gate (ISSUE 8): the quarantine-overhead ratio and faulted-round
    # wall clock gate at x3 (timings), the staging bytes of the parked
    # straggler deterministic at x1.5; a faults section present in the seed
    # and missing from the fresh record fails like any other gated metric —
    # dropping the fault-tolerance bench must not silently disable the gate
    nfa, sfa = new.get("faults", {}), seed.get("faults", {})
    if sfa and not nfa:
        fails.append(
            ("faults: section missing from the fresh record", False)
        )
    for mkey, wall in COMPARE_FAULTS_KEYS:
        check(f"faults.{mkey}", nfa.get(mkey), sfa.get(mkey), wall)
    sst = sfa.get("straggler", {})
    nst = nfa.get("straggler", {})
    check("faults.straggler.staging_bytes", nst.get("staging_bytes"),
          sst.get("staging_bytes"), False)
    # async gate (ISSUE 9): publish overhead ratio and wall clock at x3,
    # buffer peak bytes deterministic at x1.5; an async section present in
    # the seed and missing from the fresh record fails like any other
    # gated metric — the round-barrier-free path must not silently lose
    # its regression gate
    nas, sas = new.get("async", {}), seed.get("async", {})
    if sas and not nas:
        fails.append(
            ("async: section missing from the fresh record", False)
        )
    for mkey, wall in COMPARE_ASYNC_KEYS:
        check(f"async.{mkey}", nas.get(mkey), sas.get(mkey), wall)
    # hierarchy gate (ISSUE 10): wall clocks at x3 and deterministic
    # per-tier bytes at x1.5 vs the seed (iterating the SEED's edge
    # entries so a dropped edge count fails), plus the SHAPE gate on the
    # fresh record: every hier server peak strictly below the fresh
    # flat-round peak — the memory-wall win must survive --compare even
    # when the seed predates the section
    nh, sh = new.get("hierarchy", {}), seed.get("hierarchy", {})
    if sh and not nh:
        fails.append(
            ("hierarchy: section missing from the fresh record", False)
        )
    nfl, sfl = nh.get("flat", {}), sh.get("flat", {})
    check("hierarchy.flat.round_us", nfl.get("round_us"),
          sfl.get("round_us"), True)
    check("hierarchy.flat.server_peak_bytes", nfl.get("server_peak_bytes"),
          sfl.get("server_peak_bytes"), False)
    for e, s_ent in sh.get("edges", {}).items():
        n_ent = nh.get("edges", {}).get(e, {})
        for mkey, wall in COMPARE_HIER_KEYS:
            check(f"hierarchy.edges[{e}].{mkey}", n_ent.get(mkey),
                  s_ent.get(mkey), wall)
    flat_peak = nfl.get("server_peak_bytes")
    for e, n_ent in nh.get("edges", {}).items():
        hp = n_ent.get("hier_server_peak_bytes")
        if flat_peak is None or hp is None:
            continue
        checked[0] += 1
        if not hp < flat_peak:
            fails.append((
                f"hierarchy.edges[{e}].hier_server_peak_bytes: {hp} not "
                f"strictly below the flat-round peak {flat_peak} — the "
                f"two-tier fold lost its memory-wall win",
                False,
            ))
    return fails, checked[0]


def main() -> None:
    """CI smoke entry: run the grouped-round matrix (with its dispatch,
    staging, and wall-clock gates) plus the kernel comparison, fast enough
    for the slow job; ``--json`` persists the trajectory; ``--compare``
    turns the committed trajectory into an enforced regression gate."""
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, few iters (CI regression gate)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the benchmark trajectory (kernel compare, "
                         "grouped-round matrix, staging/dispatch counts) "
                         "to PATH, e.g. BENCH_kernels.json")
    ap.add_argument("--compare", metavar="SEED", default=None,
                    help="after the run, gate the fresh record against this "
                         "recorded trajectory (the committed "
                         "BENCH_kernels.json): exit non-zero when any gated "
                         f"metric regresses beyond x{COMPARE_FACTOR} "
                         f"(deterministic) / x{COMPARE_WALL_FACTOR} (wall "
                         "clock) or disappears from the record")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    record = {
        "schema": 1,
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "suite": "bench_kernels",
    }
    def run_suite(sink):
        if args.smoke:
            _bench_kernel_compare(smoke=True, sink=sink)
            _bench_grouped_round(smoke=True, iters=5, matrix=True,
                                 sink=sink)
            _bench_agg_compare(smoke=True, sink=sink)
            _bench_freeze_decay(smoke=True, sink=sink)
            _bench_transport(smoke=True, sink=sink)
            _bench_faults(smoke=True, sink=sink)
            _bench_async(smoke=True, sink=sink)
            _bench_hierarchy(smoke=True, sink=sink)
        else:
            bench({}, full=args.full, record=sink)

    try:
        run_suite(record)
    finally:
        # write whatever was recorded even when a smoke gate fails — the
        # failing run's numbers are exactly the ones worth inspecting
        if args.json:
            with open(args.json, "w") as f:
                json.dump(record, f, indent=1, default=float)
                f.write("\n")
            print(f"wrote {args.json}")
    if args.compare:
        with open(args.compare) as f:
            seed = json.load(f)
        fails, n_checked = compare_trajectories(record, seed)
        if fails and all(wall for _, wall in fails):
            # every failure is a wall-clock gate: re-measure ONCE before
            # failing — shared CI runners are noisy and a single slow
            # sample should not block a merge.  Deterministic failures
            # (bytes, elements, missing sections) never get a retry.
            print(f"BENCH COMPARE: {len(fails)} wall-clock regression(s) "
                  "vs seed — re-measuring once before failing")
            for line, _ in fails:
                print("  " + line)
            retry_record = {k: record[k] for k in
                           ("schema", "backend", "smoke", "suite")}
            run_suite(retry_record)
            fails, n_checked = compare_trajectories(retry_record, seed)
        if fails:
            print(f"BENCH COMPARE: {len(fails)} regression(s) vs "
                  f"{args.compare}")
            for line, _ in fails:
                print("  " + line)
            raise SystemExit(1)
        print(f"bench compare vs {args.compare}: green "
              f"({n_checked} gated metrics)")


if __name__ == "__main__":
    main()
