"""Paper Fig. 6: training-memory footprint and participation rate per
ProFL block (full paper-scale memory model), plus the headline
peak-memory-reduction numbers (paper: up to 57.4%)."""
from __future__ import annotations

import numpy as np

from repro.configs.paper_cnn import PAPER_CNNS
from repro.fl import memory_model as MM

from benchmarks import common as C


def bench(ctx: dict, full: bool = False):
    budgets = MM.assign_budgets_mb(np.random.default_rng(0), 100)
    out = {}
    for name, cfg in PAPER_CNNS.items():
        fullmb = MM.full_train_memory_mb(cfg)
        rows = []
        for t in range(cfg.n_prog_blocks):
            mb = MM.submodel_train_memory_mb(cfg, t)
            pr = len(MM.eligible(budgets, mb)) / 100.0
            rows.append({"block": t + 1, "mem_mb": mb, "pr": pr})
        headmb = MM.head_only_memory_mb(cfg)
        peak = max(r["mem_mb"] for r in rows)
        reduction = 1.0 - peak / fullmb
        out[name] = {
            "full_mb": fullmb,
            "blocks": rows,
            "head_only_mb": headmb,
            "peak_reduction": reduction,
            "pr_full": len(MM.eligible(budgets, fullmb)) / 100.0,
        }
        C.emit(
            f"fig6/{name}", 0.0,
            f"full={fullmb:.0f}MB;peak_block={peak:.0f}MB;"
            f"reduction={reduction:.1%};pr_full={out[name]['pr_full']:.0%};"
            f"pr_blocks=" + "/".join(f"{r['pr']:.0%}" for r in rows),
        )
    ctx["fig6"] = out
    C.save_json("bench_fig6.json", out)
