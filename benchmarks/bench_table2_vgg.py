"""Paper Table 2: the same comparison on the VGG family."""
from __future__ import annotations

from benchmarks import common as C
from benchmarks.bench_table1_resnet import run


def bench(ctx: dict, full: bool = False):
    cases = [("vgg11", False)] + ([("vgg16", False)] if full else [])
    table = {}
    for kind, non_iid in cases:
        tag = f"{kind}-{'noniid' if non_iid else 'iid'}"
        table[tag] = run(kind, non_iid, C.BASELINE_ROUNDS)
        r = table[tag]
        for k, v in r.items():
            if k.startswith("_"):
                continue
            acc = "NA" if v["acc"] is None else f"{v['acc']:.3f}"
            C.emit(f"table2/{tag}/{k}", 0.0, f"acc={acc};pr={v['pr']:.2f}")
    ctx["table2"] = table
    C.save_json("bench_table2.json", {
        k: {kk: vv for kk, vv in v.items() if not kk.startswith("_")}
        for k, v in table.items()
    })
