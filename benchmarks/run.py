"""Benchmark harness: one module per paper table/figure (+ kernels and the
roofline report).  Prints ``name,us_per_call,derived`` CSV.

Usage:  PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    bench_fig45_effective_movement,
    bench_fig6_memory,
    bench_kernels,
    bench_table1_resnet,
    bench_table2_vgg,
    bench_table3_shrinking,
    bench_table4_freezing,
    bench_table5_blockparams,
    roofline,
)

MODULES = [
    ("table5_blockparams", bench_table5_blockparams),  # fast, exact checks first
    ("fig6_memory", bench_fig6_memory),
    ("kernels", bench_kernels),
    ("roofline", roofline),
    ("table1_resnet", bench_table1_resnet),
    ("fig45_effective_movement", bench_fig45_effective_movement),
    ("table2_vgg", bench_table2_vgg),
    ("table3_shrinking", bench_table3_shrinking),
    ("table4_freezing", bench_table4_freezing),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="bigger FL runs (more model families)")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    ctx: dict = {}
    failures = []
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod.bench(ctx, full=args.full)
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception as e:  # keep the suite going
            failures.append((name, e))
            import traceback
            traceback.print_exc()
            print(f"{name}/ERROR,0.0,{type(e).__name__}")
    if failures:
        raise SystemExit(f"{len(failures)} bench modules failed: "
                         f"{[n for n, _ in failures]}")


if __name__ == "__main__":
    main()
