"""Paper Table 5: parameter count + percentage per block, ResNet18/34 —
reproduced EXACTLY at the paper's full scale (this is a hard numerical check
of the block partition: 0.15/0.53/2.10/8.39 M etc.)."""
from __future__ import annotations

import jax

from repro.configs.paper_cnn import RESNET18, RESNET34
from repro.models import cnn as CN

from benchmarks import common as C

PAPER = {
    "resnet18": ([0.15, 0.53, 2.10, 8.39], 11.2),
    "resnet34": ([0.22, 1.11, 6.82, 13.11], 21.28),
}


def bench(ctx: dict, full: bool = False):
    out = {}
    for cfg in (RESNET18, RESNET34):
        params, _ = CN.init_cnn(cfg, jax.random.PRNGKey(0))
        counts = CN.block_param_counts(params)
        total = sum(counts)
        pcts = [100.0 * c / total for c in counts]
        exp_counts, exp_total = PAPER[cfg.kind]
        ok = all(abs(c / 1e6 - e) < 0.02 for c, e in zip(counts, exp_counts))
        out[cfg.kind] = {
            "counts_M": [c / 1e6 for c in counts],
            "pcts": pcts,
            "total_M": total / 1e6,
            "matches_paper": ok,
        }
        C.emit(
            f"table5/{cfg.kind}", 0.0,
            "blocks_M=" + "/".join(f"{c/1e6:.2f}" for c in counts)
            + f";total_M={total/1e6:.2f};paper_match={ok}",
        )
        assert ok, f"{cfg.kind} block params diverge from paper Table 5"
    ctx["table5"] = out
    C.save_json("bench_table5.json", out)
