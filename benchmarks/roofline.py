"""Roofline analysis (deliverable g): derive the three roofline terms per
(arch × input shape) from the dry-run's compiled artifact (single-pod mesh).

    compute term    = HLO_FLOPs  / (chips × peak_FLOP/s)
    memory term     = HLO_bytes  / (chips × HBM_bw)
    collective term = coll_bytes / (chips × link_bw)

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed) and the
collective byte counts parsed from the optimized HLO (dryrun.py).  NOTE on
normalization: XLA's post-SPMD cost_analysis reports PER-DEVICE flops/bytes
of the partitioned module, so the terms divide by per-chip peaks directly.

Per row we also report MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) and the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs × chips) — remat recompute and
dispatch overhead push it below 1.

Usage: ``python -m benchmarks.roofline [--json results/dryrun_single_pod.json]``
(also callable as a bench module from benchmarks.run).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional

from repro.configs.base import INPUT_SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, ICI_LINKS, PEAK_FLOPS_BF16

from benchmarks import common as C


def model_params(cfg) -> tuple:
    """(total_params, active_params) analytic estimate."""
    D = cfg.d_model
    per_layer_attn = D * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * D
    total = active = cfg.vocab * D * (1 if cfg.tie_embeddings else 2)
    for spec in cfg.pattern:
        n = cfg.n_groups
        if spec.mixer == "attn":
            total += per_layer_attn * n
            active += per_layer_attn * n
        elif spec.mixer == "mamba":
            di = cfg.ssm.expand * D
            m = 2 * D * di + di * D + di * (cfg.ssm.d_state * 2 + D // 16)
            total += m * n
            active += m * n
        elif spec.mixer == "rwkv":
            total += 5 * D * D * n
            active += 5 * D * D * n
        if spec.ffn == "dense":
            mult = 3 if cfg.act == "swiglu" else 2
            total += mult * D * cfg.d_ff * n
            active += mult * D * cfg.d_ff * n
        elif spec.ffn == "moe":
            e = 3 * D * cfg.moe.d_expert
            total += e * cfg.moe.n_experts * n
            active += e * (cfg.moe.top_k + cfg.moe.n_shared) * n
        elif spec.ffn == "rwkv_cm":
            total += (2 * D * cfg.d_ff + D * D) * n
            active += (2 * D * cfg.d_ff + D * D) * n
    if cfg.encoder is not None:
        enc = cfg.encoder.n_layers * (per_layer_attn + 2 * D * cfg.d_ff)
        total += enc
        active += enc
    return total, active


def model_flops(cfg, shape) -> float:
    """6·N_active·tokens for training; 2·N_active·tokens for one fwd token
    batch (prefill); 2·N_active·B for a decode step."""
    _, active = model_params(cfg)
    if shape.kind == "train":
        return 6.0 * active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch  # decode: ONE token


def activation_traffic_bytes(cfg, shape) -> float:
    """Analytic HBM activation traffic for the whole step (all chips).
    Fusion-aware constants: ~24 D-sized tensor passes per token-layer for
    fwd+bwd with remat; ~8 for prefill.  Decode activation traffic is
    negligible next to the cache/params reads already counted in args."""
    tokens = shape.global_batch * shape.seq_len
    L = cfg.n_layers + (cfg.encoder.n_layers if cfg.encoder else 0)
    per = {"train": 24, "prefill": 8, "decode": 0}[shape.kind]
    return per * cfg.d_model * 2 * L * (
        tokens if shape.kind != "decode" else shape.global_batch
    )


def analyze_row(rec: dict) -> Optional[dict]:
    """Roofline terms per (arch, shape) on the single-pod mesh.

    Calibration note (EXPERIMENTS.md §Roofline): XLA:CPU ``cost_analysis``
    counts while-loop (lax.scan) bodies ONCE, so raw HLO flops/bytes
    underestimate the layer-scanned model by ~n_layers.  The compute term
    therefore uses the exact analytic MODEL_FLOPS; the memory term uses
    per-device argument/output bytes (params + opt state + caches, which
    the step provably touches) plus an analytic activation-traffic model;
    the collective term uses the HLO parse with while-body trip-count
    correction (dryrun._collective_bytes).  Raw HLO numbers are kept as
    ``hlo_*`` columns for corroboration.
    """
    if "error" in rec or "skip" in rec:
        return None
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    n = rec["n_devices"]
    mf = model_flops(cfg, shape)
    t_comp = mf / (n * PEAK_FLOPS_BF16)

    k_rw = 2.0 if shape.kind == "train" else 1.0  # opt-state read+write
    args_b = rec["per_device"]["argument_bytes"]
    out_b = rec["per_device"]["output_bytes"]
    act_b = activation_traffic_bytes(cfg, shape) / n
    t_mem = (k_rw * args_b + out_b + act_b) / HBM_BW

    # the HLO parse sums PER-DEVICE shapes (post-SPMD module); global
    # collective bytes = per-device × chips, so the instructed
    # coll_global / (chips × link_bw) reduces to per-device / link_bw —
    # with all ICI_LINKS of the 2D torus usable per chip
    coll = sum(rec["collective_bytes"].values()) * n
    t_coll = coll / (n * ICI_BW * ICI_LINKS)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    useful = mf / max(rec["flops"] * n, 1.0)
    notes = {
        ("train", "compute"): "already compute-bound: gains come from MFU "
        "(kernel fusion / avoiding remat recompute), not layout",
        ("train", "memory"): "shrink optimizer traffic: bf16 moments or "
        "ZeRO-style sharded updates; larger per-chip batch",
        ("train", "collective"): "overlap FSDP all-gathers with compute; "
        "move Megatron-SP gathers off the critical path (async collectives)",
        ("prefill", "compute"): "compute-bound as desired; block-sparse "
        "attention would cut the quadratic term",
        ("prefill", "collective"): "batch is small per chip: widen the dp "
        "shard or overlap the per-layer gathers",
        ("prefill", "memory"): "fuse the cache writes into the attention "
        "kernel",
        ("decode", "memory"): "int8/fp8 KV cache halves the dominant "
        "cache-streaming term",
        ("decode", "collective"): "per-token all-reduces dominate: batch "
        "more requests per step or use weight-gathered (all-gather once) "
        "decode layout",
    }
    return {
        "note": notes.get((shape.kind, dom), ""),
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_per_dev": rec["flops"],
        "hlo_bytes_per_dev": rec["bytes_accessed"],
        "hlo_vs_model_ratio": useful,
        "peak_gib": rec["per_device"]["peak_bytes"] / 2**30,
        "collective_bytes": coll,
        "roofline_bound_s": max(terms.values()),
    }


def print_table(rows):
    hdr = (f"{'arch':26s} {'shape':12s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'dominant':>10s} {'peak GiB':>9s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(
            f"{r['arch']:26s} {r['shape']:12s} {r['t_compute_s']:9.2e} "
            f"{r['t_memory_s']:9.2e} {r['t_collective_s']:9.2e} "
            f"{r['dominant']:>10s} {r['peak_gib']:9.2f}"
        )


def load_and_analyze(path: str):
    with open(path) as f:
        recs = json.load(f)
    rows = [analyze_row(r) for r in recs]
    return [r for r in rows if r is not None], [
        r for r in recs if "skip" in r or "error" in r
    ]


def bench(ctx: dict, full: bool = False):
    path = C.results_path("dryrun_single_pod.json")
    if not os.path.exists(path):
        C.emit("roofline/skipped", 0.0, "no dryrun json; run launch.dryrun --all")
        return
    rows, other = load_and_analyze(path)
    for r in rows:
        C.emit(
            f"roofline/{r['arch']}/{r['shape']}", 0.0,
            f"dom={r['dominant']};comp={r['t_compute_s']:.2e}s;"
            f"mem={r['t_memory_s']:.2e}s;coll={r['t_collective_s']:.2e}s;"
            f"peak={r['peak_gib']:.1f}GiB",
        )
    C.save_json("roofline.json", rows)
    ctx["roofline"] = rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=C.results_path("dryrun_single_pod.json"))
    args = ap.parse_args()
    rows, other = load_and_analyze(args.json)
    print_table(rows)
    for r in other:
        print(f"{r['arch']:26s} {r['shape']:12s} "
              f"{'SKIP' if 'skip' in r else 'ERROR'}: "
              f"{r.get('skip', r.get('error', ''))[:80]}")
    C.save_json("roofline.json", rows)


if __name__ == "__main__":
    main()
