"""Equivalence tests for the sharded cohort execution engine (fl/engine.py):
packed/sharded `round` must match the vmap+tree-map oracle
(fl/client.py::cohort_round) to <= 1e-5 across cohort sizes, uneven weights,
mixed dtypes, and both CNN and transformer loss_fns; pack/unpack must
round-trip arbitrary trees; plus the grouped-round BEHAVIORAL contracts
(zero-weight groups, the single-group degenerate case, dispatch/sync
counting, layout caching/validation); the multi-device paths are exercised
in a subprocess with --xla_force_host_platform_device_count.

Grouped-round RESULT equivalence across the full engine mode × impl × agg
matrix lives in tests/test_contract.py (the engine-contract conformance
suite) — don't add new pairwise equivalence checks here."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import effective_movement as EM
from repro.core import progressive as P
from repro.fl import client as CL
from repro.fl import engine as ENG
from repro.launch.mesh import make_client_mesh
from repro.models import cnn as C
from repro.train.train_step import softmax_xent

ENGINES = ["packed", "sharded"]


def _tree_close(a, b, atol=1e-5):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), atol=atol
        )


# ---------------------------------------------------------------------------
# pack/unpack round trips
# ---------------------------------------------------------------------------

TREES = [
    {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((3,))},
    {"blocks": [[jnp.ones((2, 2))], [jnp.zeros((4,))]], "head": {"w": jnp.ones((2, 5))}},
    {"a": jnp.ones((3,), jnp.bfloat16), "z": jnp.arange(4, dtype=jnp.float32)},
    {"empty": {}, "x": jnp.ones((1, 1, 2))},
]


@pytest.mark.parametrize("tree", TREES, ids=["flat", "nested", "mixed_dtype", "holey"])
def test_pack_roundtrip(tree):
    spec = ENG.make_pack_spec(tree)
    flat = spec.pack(tree)
    assert flat.dtype == jnp.float32 and flat.shape == (spec.n,)
    back = spec.unpack(flat)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_pack_spec_is_cached():
    t1 = {"w": jnp.zeros((2, 3))}
    t2 = {"w": jnp.ones((2, 3))}
    assert ENG.make_pack_spec(t1) is ENG.make_pack_spec(t2)
    assert ENG.make_pack_spec({"w": jnp.zeros((3, 2))}) is not ENG.make_pack_spec(t1)


def test_pack_stacked_matches_per_client_pack():
    tree = TREES[1]
    K = 3
    stacked = jax.tree.map(
        lambda l: jnp.stack([l * (i + 1) for i in range(K)]), tree
    )
    spec = ENG.make_pack_spec(tree)
    panel = spec.pack_stacked(stacked, K)
    assert panel.shape == (K, spec.n)
    for i in range(K):
        row = spec.pack(jax.tree.map(lambda l: l[i], stacked))
        np.testing.assert_array_equal(np.asarray(panel[i]), np.asarray(row))


def test_empty_tree_pack():
    spec = ENG.make_pack_spec({})
    assert spec.n == 0
    assert spec.pack({}).shape == (0,)
    assert spec.pack_stacked({}, 4).shape == (4, 0)


# ---------------------------------------------------------------------------
# engine vs oracle: synthetic mixed-dtype model, K and weight sweeps
# ---------------------------------------------------------------------------


def _mixed_loss(trainable, frozen, bn_state, xb, yb):
    w = trainable["w"].astype(jnp.float32)  # bf16 leaf
    b = trainable["b"]  # f32 leaf
    pred = xb @ w + b
    loss = jnp.mean((pred - yb[:, None]) ** 2)
    return loss, bn_state


def _mixed_world(K, n_local=8, d=5):
    rng = jax.random.PRNGKey(0)
    trainable = {
        "w": jax.random.normal(rng, (d, 3), jnp.float32).astype(jnp.bfloat16),
        "b": jnp.zeros((3,), jnp.float32),
    }
    bn = {"mu": jnp.zeros((3,))}
    xs = jax.random.normal(jax.random.fold_in(rng, 1), (K, n_local, d))
    ys = jax.random.randint(jax.random.fold_in(rng, 2), (K, n_local), 0, 3)
    rngs = jax.random.split(jax.random.PRNGKey(7), K)
    weights = jnp.arange(1.0, K + 1.0) ** 2  # strongly uneven
    return trainable, bn, xs, ys.astype(jnp.float32), rngs, weights


@pytest.mark.parametrize("mode", ENGINES)
@pytest.mark.parametrize("K", [1, 4])
def test_engine_matches_oracle_mixed_dtype(mode, K):
    trainable, bn, xs, ys, rngs, weights = _mixed_world(K)
    kw = dict(lr=0.1, local_steps=3, batch_size=4)
    want = CL.cohort_round(
        _mixed_loss, trainable, {}, bn, xs, ys, rngs, weights, **kw
    )
    res = ENG.make_engine(mode).round(
        _mixed_loss, trainable, {}, bn, xs, ys, rngs, weights, **kw
    )
    _tree_close(want[0], res.trainable)
    _tree_close(want[1], res.bn_state)
    np.testing.assert_allclose(float(want[2]), float(res.loss), atol=1e-5)
    # dtypes survive the packed round
    assert res.trainable["w"].dtype == jnp.bfloat16
    assert res.trainable["b"].dtype == jnp.float32
    # packed vector is the aggregated flat trainable
    spec = ENG.make_pack_spec(trainable)
    assert res.packed is not None and res.packed.shape == (spec.n,)
    np.testing.assert_allclose(
        np.asarray(res.packed),
        np.asarray(spec.pack(want[0])),
        atol=1e-5,
    )


# ---------------------------------------------------------------------------
# engine vs oracle: CNN and transformer loss_fns
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cnn_world():
    cfg = C.CNNConfig("vgg11", width_mult=0.0625, in_size=16)
    params, bn = C.init_cnn(cfg, jax.random.PRNGKey(0))

    def loss_fn(trainable, frozen, bn_state, xb, yb):
        logits, new_bn = C.forward_cnn(cfg, trainable, bn_state, xb, train=True)
        return softmax_xent(logits, yb), new_bn

    K, n_local = 4, 8
    rng = jax.random.PRNGKey(1)
    xs = jax.random.normal(rng, (K, n_local, 16, 16, 3))
    ys = jax.random.randint(jax.random.fold_in(rng, 1), (K, n_local), 0, 10)
    rngs = jax.random.split(jax.random.PRNGKey(2), K)
    weights = jnp.asarray([3.0, 1.0, 2.0, 0.5])
    kw = dict(lr=0.05, local_steps=2, batch_size=4)
    want = CL.cohort_round(loss_fn, params, {}, bn, xs, ys, rngs, weights, **kw)
    return loss_fn, params, bn, xs, ys, rngs, weights, kw, want


@pytest.mark.parametrize("mode", ENGINES)
def test_engine_matches_oracle_cnn(cnn_world, mode):
    loss_fn, params, bn, xs, ys, rngs, weights, kw, want = cnn_world
    res = ENG.make_engine(mode).round(
        loss_fn, params, {}, bn, xs, ys, rngs, weights, **kw
    )
    _tree_close(want[0], res.trainable)
    _tree_close(want[1], res.bn_state)
    np.testing.assert_allclose(float(want[2]), float(res.loss), atol=1e-5)


@pytest.fixture(scope="module")
def tf_world():
    from repro.configs.base import get_config
    from repro.models import transformer as T

    cfg = get_config("qwen1.5-0.5b").reduced(d_model=64, vocab=32).with_(
        n_prog_blocks=2
    )
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    t = 1
    frozen, trainable = P.submodel_init(cfg, params, jax.random.PRNGKey(1), t)
    prog_loss = P.make_progressive_loss(cfg, t)

    def loss_fn(trainable, frozen, bn_state, xb, yb):
        loss, _ = prog_loss(trainable, frozen, {"tokens": xb})
        return loss, bn_state

    K, n_local, S = 4, 6, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (K, n_local, S), 0,
                              cfg.vocab)
    ys = jnp.zeros((K, n_local), jnp.int32)  # unused by the LM loss
    rngs = jax.random.split(jax.random.PRNGKey(3), K)
    weights = jnp.asarray([1.0, 4.0, 2.0, 3.0])
    kw = dict(lr=0.05, local_steps=2, batch_size=2)
    want = CL.cohort_round(
        loss_fn, trainable, frozen, {}, toks, ys, rngs, weights, **kw
    )
    return loss_fn, trainable, frozen, toks, ys, rngs, weights, kw, want


@pytest.mark.parametrize("mode", ENGINES)
def test_engine_matches_oracle_transformer(tf_world, mode):
    loss_fn, trainable, frozen, toks, ys, rngs, weights, kw, want = tf_world
    res = ENG.make_engine(mode).round(
        loss_fn, trainable, frozen, {}, toks, ys, rngs, weights, **kw
    )
    _tree_close(want[0], res.trainable)
    np.testing.assert_allclose(float(want[2]), float(res.loss), atol=1e-5)


# ---------------------------------------------------------------------------
# EM integration: flat path == tree path
# ---------------------------------------------------------------------------


def test_em_flat_matches_tree_path():
    cfg = EM.EMConfig(window_h=2)
    trainable, bn, xs, ys, rngs, weights = _mixed_world(K=4)
    # same shapes/statics as the K=4 equivalence tests -> jit cache hits
    kw = dict(lr=0.1, local_steps=3, batch_size=4)
    eng = ENG.make_engine("packed")

    st_tree = EM.em_init(trainable)
    st_flat = EM.em_init(trainable)
    tr_a = tr_b = trainable
    for r in range(4):
        rr = jax.random.split(jax.random.PRNGKey(10 + r), 4)
        tr_a, _, _ = CL.cohort_round(
            _mixed_loss, tr_a, {}, bn, xs, ys, rr, weights, **kw
        )
        em_a = EM.em_update(cfg, st_tree, tr_a)
        res = eng.round(_mixed_loss, tr_b, {}, bn, xs, ys, rr, weights, **kw)
        tr_b = res.trainable
        em_b = EM.em_update_flat(cfg, st_flat, res.packed)
        assert (em_a is None) == (em_b is None)
        if em_a is not None:
            np.testing.assert_allclose(em_a, em_b, atol=1e-5)


# ---------------------------------------------------------------------------
# engine construction
# ---------------------------------------------------------------------------


def test_make_engine_modes():
    assert ENG.make_engine("vmap").mode == "vmap"
    assert ENG.make_engine("packed").mode == "packed"
    eng = ENG.make_engine("sharded")
    assert eng.mesh is not None and "clients" in eng.mesh.shape
    # 1 local device -> auto prefers packed
    assert ENG.make_engine("auto").mode == (
        "packed" if len(jax.devices()) == 1 else "sharded"
    )
    with pytest.raises(ValueError):
        ENG.make_engine("einsum")


def test_vmap_engine_returns_no_packed():
    trainable, bn, xs, ys, rngs, weights = _mixed_world(K=4)
    res = ENG.make_engine("vmap").round(
        _mixed_loss, trainable, {}, bn, xs, ys, rngs, weights,
        lr=0.1, local_steps=3, batch_size=4,
    )
    assert res.packed is None


def test_client_mesh_axis():
    mesh = make_client_mesh()
    assert mesh.axis_names == ("clients",)
    assert mesh.shape["clients"] == len(jax.devices())


# ---------------------------------------------------------------------------
# grouped heterogeneous rounds: fused masked aggregation vs the serial
# per-group oracle (HeteroFL-style width groups, DepthFL-style depth groups,
# mask edge cases, single-dispatch assertion)
# ---------------------------------------------------------------------------

from repro.kernels import ops as OPS


def _grouped_close(a: ENG.GroupedResult, b: ENG.GroupedResult, atol=1e-5):
    _tree_close(a.trainable, b.trainable, atol=atol)
    _tree_close(a.bn_state, b.bn_state, atol=atol)
    np.testing.assert_allclose(float(a.loss), float(b.loss), atol=atol)


def _width_loss(f):
    def loss_fn(tr, fro, bn, xb, yb):
        pred = xb[:, :f] @ tr["w"] + tr["b"]
        mu = bn["mu"] * 0.9 + 0.1 * jnp.mean(pred)
        return jnp.mean((pred - yb[:, None]) ** 2), {"mu": mu}

    return loss_fn


_WIDTH_LOSSES = {f: _width_loss(f) for f in (4, 6, 8)}


def _width_world(zero_weight_group=None):
    """HeteroFL-shaped groups: three width levels slice the leading rows of
    the global ``w``; strongly uneven weights."""
    d, out = 8, 3
    rng = jax.random.PRNGKey(0)
    gtr = {"w": jax.random.normal(rng, (d, out)), "b": jnp.zeros((out,))}
    gbn = {"mu": jnp.zeros(())}
    plans = []
    for gi, (f, kg) in enumerate([(4, 2), (6, 3), (8, 2)]):
        sub = {"w": gtr["w"][:f], "b": gtr["b"]}
        xs = jax.random.normal(jax.random.fold_in(rng, gi), (kg, 10, d))
        ys = jax.random.normal(jax.random.fold_in(rng, 100 + gi), (kg, 10))
        rngs = jax.random.split(jax.random.fold_in(rng, 200 + gi), kg)
        w = jnp.arange(1.0, kg + 1.0) * (gi + 0.5)
        if gi == zero_weight_group:
            w = jnp.zeros_like(w)
        plans.append(ENG.GroupPlan(
            _WIDTH_LOSSES[f], sub, {}, gbn, xs, ys, rngs, w, 0.1, 3, 4
        ))
    return plans, gtr, gbn


# Result equivalence for width/depth/transformer groups across the mode ×
# impl × agg matrix moved to tests/test_contract.py (the conformance suite).


def test_grouped_zero_weight_group_passes_through():
    # group 0 (the only one training w rows 0:4 columns it uniquely owns? no:
    # every column of rows 0:4 is shared with wider groups; zero its weights
    # and both paths must agree AND stay finite)
    plans, gtr, gbn = _width_world(zero_weight_group=2)  # widest group
    want = ENG.make_engine("vmap").grouped_round(plans, gtr, gbn)
    got = ENG.make_engine("packed").grouped_round(plans, gtr, gbn)
    _grouped_close(want, got)
    # rows 6:8 of w are trained ONLY by the (zero-weight) widest group ->
    # per-column denominator 0 -> the server's previous values pass through
    np.testing.assert_array_equal(
        np.asarray(got.trainable["w"][6:]), np.asarray(gtr["w"][6:])
    )
    assert all(
        bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(got.trainable)
    )


def test_grouped_single_identity_group_degenerates_to_round():
    plans, gtr, gbn = _width_world()
    p = plans[2]._replace(trainable=gtr)  # full-width group == global tree
    want = CL.cohort_round(
        p.loss_fn, p.trainable, p.frozen, p.bn_state, p.xs, p.ys, p.rngs,
        p.weights, lr=p.lr, local_steps=p.local_steps,
        batch_size=p.batch_size,
    )
    serial = ENG.make_engine("vmap").grouped_round([p], gtr, gbn)
    fused = ENG.make_engine("packed").grouped_round([p], gtr, gbn)
    _tree_close(want[0], serial.trainable, atol=0)  # bit-identical oracle
    _tree_close(want[0], fused.trainable)
    np.testing.assert_allclose(float(want[2]), float(fused.loss), atol=1e-5)


def test_grouped_round_single_aggregation_dispatch():
    """The fused path issues exactly ONE group-compressed fedavg_grouped
    dispatch per round regardless of how many structure groups the cohort
    contains — and never touches the dense-mask or plain kernels."""
    plans, gtr, gbn = _width_world()
    eng = ENG.make_engine("packed")
    eng.grouped_round(plans, gtr, gbn)  # warm caches/compiles
    OPS.reset_dispatches()
    eng.grouped_round(plans, gtr, gbn)
    assert OPS.DISPATCHES["fedavg_grouped"] == 1
    assert OPS.DISPATCHES["fedavg_masked"] == 0
    assert OPS.DISPATCHES["fedavg"] == 0
    # the legacy escape hatch still routes through the dense-mask kernel
    eng.grouped_round(plans, gtr, gbn, impl="fused_masked")
    assert OPS.DISPATCHES["fedavg_masked"] == 1
    OPS.reset_dispatches()


def test_grouped_fused_single_host_sync():
    """The pipelined fused path performs ZERO host syncs between group
    launches: exactly one jax.block_until_ready for the whole round, at the
    aggregation barrier (counted by a shim patched over jax)."""
    plans, gtr, gbn = _width_world()
    eng = ENG.make_engine("packed")
    eng.grouped_round(plans, gtr, gbn)  # warm compiles outside the window
    real = jax.block_until_ready
    calls = []

    def counting(x):
        calls.append(1)
        return real(x)

    jax.block_until_ready = counting
    try:
        ENG.reset_syncs()
        eng.grouped_round(plans, gtr, gbn)
    finally:
        jax.block_until_ready = real
    assert len(calls) == 1, f"expected 1 host sync, saw {len(calls)}"
    assert ENG.SYNCS["aggregation_barrier"] == 1
    ENG.reset_syncs()


def test_grouped_layout_cached_and_validates():
    plans, gtr, gbn = _width_world()
    l1 = ENG.make_group_layout(plans, gtr, gbn)
    l2 = ENG.make_group_layout(plans, gtr, gbn)
    assert l1 is l2
    assert l1.k_total == sum(p.xs.shape[0] for p in plans)
    assert l1.n_groups == len(plans)
    # compact [G, n] group mask is what the fused path stages; the dense
    # [K_total, n] per-client mask survives only as the oracle escape hatch
    assert l1.gmask.shape == (l1.n_groups, l1.n)
    assert l1.legacy_mask.shape == (l1.k_total, l1.n)
    # the group mask rows expand to exactly the legacy per-client rows
    expanded = np.repeat(np.asarray(l1.gmask), l1.ks, axis=0)
    np.testing.assert_array_equal(expanded, np.asarray(l1.legacy_mask))
    with pytest.raises(ValueError):
        ENG.make_engine("packed").grouped_round([], gtr, gbn)
    with pytest.raises(ValueError):
        ENG.make_engine("packed").grouped_round(plans, gtr, gbn, impl="magic")
    # a group leaf that is not a leading-corner slice of its global leaf
    bad = plans[0]._replace(trainable={"w": jnp.zeros((9, 3)), "b": gtr["b"]})
    with pytest.raises(ValueError):
        ENG.make_group_layout([bad], gtr, gbn)
    # a group leaf with no counterpart path in the global tree
    orphan = plans[0]._replace(trainable={"nope": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        ENG.make_group_layout([orphan], gtr, gbn)


def test_layout_cache_keys_on_frozen_epoch():
    """The regression ISSUE 6 guards against: two plan lists identical up
    to frozen columns must produce DISTINCT layouts (the seed cache keyed
    on treedef + shapes only, so the first freeze event would silently get
    the stale full-width layout), the same epoch re-derived from an equal
    mask must still HIT the cache, and aggregates stay bit-correct per
    epoch."""
    plans, gtr, gbn = _width_world()
    base = ENG.make_group_layout(plans, gtr, gbn)
    n = base.n
    m1 = np.zeros(n, bool)
    m1[:3] = True
    l1 = ENG.make_group_layout(plans, gtr, gbn,
                               frozen=ENG.make_frozen_columns(m1))
    assert l1 is not base
    assert l1.n_active == n - 3 and l1.gmask.shape == (l1.n_groups, n - 3)
    assert base.n_active == n
    # an equal mask re-derived elsewhere is the SAME epoch: cache hit
    assert ENG.make_group_layout(
        plans, gtr, gbn, frozen=ENG.make_frozen_columns(m1.copy())
    ) is l1
    # raw-mask callers are normalized onto the same epoch
    assert ENG.make_group_layout(plans, gtr, gbn, frozen=m1) is l1
    # a WIDER epoch supersedes: the narrower sibling (and the unfrozen
    # layout) are eagerly evicted and their device buffers dropped —
    # freeze-event cache invalidation, not LRU pressure
    _ = l1.gmask
    m2 = m1.copy()
    m2[3:5] = True
    l2 = ENG.make_group_layout(plans, gtr, gbn,
                               frozen=ENG.make_frozen_columns(m2))
    assert l2.n_active == n - 5
    assert l1._gmask is None
    assert all(v is not l1 and v is not base
               for v in ENG._LAYOUT_CACHE.values())
    # aggregates are bit-correct for whichever epoch a round uses
    eng = ENG.make_engine("packed")
    prev = np.asarray(ENG.make_pack_spec(gtr).pack(gtr))
    p1 = np.asarray(eng.grouped_round(plans, gtr, gbn, frozen=m1).packed)
    p2 = np.asarray(eng.grouped_round(plans, gtr, gbn, frozen=m2).packed)
    np.testing.assert_array_equal(p1[:3], prev[:3])
    np.testing.assert_array_equal(p2[:5], prev[:5])
    assert not np.array_equal(p1[3:5], prev[3:5])  # live under m1, moved
    np.testing.assert_array_equal(p1[5:], p2[5:])  # live both: identical


def test_clear_caches_resets_spec_and_layout():
    plans, gtr, gbn = _width_world()
    ENG.make_group_layout(plans, gtr, gbn)
    assert len(ENG._SPEC_CACHE) > 0 and len(ENG._LAYOUT_CACHE) > 0
    ENG.clear_caches()
    assert len(ENG._SPEC_CACHE) == 0 and len(ENG._LAYOUT_CACHE) == 0


def test_clear_caches_drops_layout_device_buffers():
    """A layout reference held by a caller must not keep the lazily-built
    device mask/index buffers alive after clear_caches(): the buffers are
    dropped on the layout object itself, not just evicted with the cache
    entry."""
    import gc
    import weakref

    plans, gtr, gbn = _width_world()
    layout = ENG.make_group_layout(plans, gtr, gbn)
    refs = [
        weakref.ref(layout.gmask),
        weakref.ref(layout.legacy_mask),
        weakref.ref(layout.idx_dev[0]),
    ]
    assert layout._gmask is not None and layout._idx_dev is not None
    ENG.clear_caches()  # layout still referenced locally — buffers must go
    assert layout._gmask is None
    assert layout._legacy_mask is None
    assert layout._idx_dev is None
    gc.collect()
    assert all(r() is None for r in refs), (
        "device mask/index buffers still live after clear_caches"
    )


def test_bounded_cache_evicts_lru():
    c = ENG.BoundedCache(maxsize=2)
    c["a"], c["b"] = 1, 2
    assert c.get("a") == 1  # touch: "b" is now LRU
    c["c"] = 3
    assert "b" not in c and c.get("a") == 1 and c.get("c") == 3


def test_layout_cache_eviction_drops_device_buffers():
    """LRU eviction (not just clear_caches) must release an evicted
    layout's device buffers — a caller-held reference to the evicted layout
    would otherwise pin them for the session."""
    evicted = []
    c = ENG.BoundedCache(maxsize=1, on_evict=evicted.append)
    c["a"], c["b"] = 1, 2
    assert evicted == [1]
    # the real layout cache wires eviction to drop_device_buffers
    plans, gtr, gbn = _width_world()
    layout = ENG.make_group_layout(plans, gtr, gbn)
    _ = layout.gmask
    key = next(k for k, v in ENG._LAYOUT_CACHE.items() if v is layout)
    ENG._LAYOUT_CACHE.on_evict(layout)
    assert layout._gmask is None
    # lazy rebuild keeps an evicted-but-referenced layout usable
    assert layout.gmask.shape == (layout.n_groups, layout.n)
    del ENG._LAYOUT_CACHE[key]


# ---------------------------------------------------------------------------
# multi-device sharding (subprocess so the host-device-count flag applies
# before jax initializes)
# ---------------------------------------------------------------------------

_MULTIDEV_SCRIPT = r"""
import jax, jax.numpy as jnp
assert len(jax.devices()) == 4, jax.devices()
from repro.fl import client as CL, engine as ENG

def loss_fn(tr, fro, bn, xb, yb):
    pred = xb @ tr["w"] + tr["b"]
    return jnp.mean((pred - yb[:, None]) ** 2), bn

K, n_local, d = 6, 8, 5   # K=6 on 4 shards -> padded to 8 with ghosts
rng = jax.random.PRNGKey(0)
tr = {"w": jax.random.normal(rng, (d, 3)), "b": jnp.zeros((3,))}
xs = jax.random.normal(jax.random.fold_in(rng, 1), (K, n_local, d))
ys = jax.random.normal(jax.random.fold_in(rng, 2), (K, n_local))
rngs = jax.random.split(jax.random.PRNGKey(1), K)
w = jnp.arange(1.0, K + 1.0)
kw = dict(lr=0.1, local_steps=3, batch_size=4)

want = CL.cohort_round(loss_fn, tr, {}, {}, xs, ys, rngs, w, **kw)
eng = ENG.make_engine("sharded")
assert eng.mesh.shape["clients"] == 4
res = eng.round(loss_fn, tr, {}, {}, xs, ys, rngs, w, **kw)
err = max(
    float(jnp.max(jnp.abs(a - b)))
    for a, b in zip(jax.tree.leaves(want[0]), jax.tree.leaves(res.trainable))
)
err = max(err, abs(float(want[2]) - float(res.loss)))
print("MAXERR", err)
assert err <= 1e-5, err

# grouped heterogeneous round: two width groups of K_g=3 each -> neither
# group size nor K_total=6 divides the 4-device clients axis (ghost padding
# on every group)
def width_loss(f):
    def loss_fn(tr, fro, bn, xb, yb):
        pred = xb[:, :f] @ tr["w"] + tr["b"]
        return jnp.mean((pred - yb[:, None]) ** 2), bn
    return loss_fn

losses = {f: width_loss(f) for f in (3, 5)}
plans = []
for gi, f in enumerate((3, 5)):
    sub = {"w": tr["w"][:f], "b": tr["b"]}
    gxs = jax.random.normal(jax.random.fold_in(rng, 10 + gi), (3, n_local, d))
    gys = jax.random.normal(jax.random.fold_in(rng, 20 + gi), (3, n_local))
    grngs = jax.random.split(jax.random.fold_in(rng, 30 + gi), 3)
    plans.append(ENG.GroupPlan(
        losses[f], sub, {}, {}, gxs, gys, grngs,
        jnp.arange(1.0, 4.0) * (gi + 1), 0.1, 3, 4,
    ))
want_g = ENG.make_engine("vmap").grouped_round(plans, tr, {})
from repro.kernels import ops as OPS
OPS.reset_dispatches()
# agg="auto" on a 4-device mesh resolves to the column-sharded aggregation
assert eng.agg_mesh is not None and eng.agg_mesh.shape["model"] == 4
got_g = eng.grouped_round(plans, tr, {})
# group-compressed aggregation: one LOGICAL fedavg_grouped dispatch (fanning
# out to one shard-local kernel launch per model-axis device), no dense mask
assert OPS.DISPATCHES["fedavg_grouped"] == 1, dict(OPS.DISPATCHES)
assert OPS.DISPATCHES["fedavg_grouped_shards"] == 4, dict(OPS.DISPATCHES)
assert OPS.DISPATCHES["fedavg_masked"] == 0, dict(OPS.DISPATCHES)
# the full [K_total, n] panel never materialized on one device
st = ENG.AGG_STATS
assert st["agg"] == "sharded" and st["n_shards"] == 4, st
assert st["per_device_panel_elems"] == st["k_total"] * st["n_padded"] // 4, st
# the two groups ran on DISJOINT clients-axis sub-meshes (2 devices each;
# K_g=3 divides neither -> ghost padding inside each sub-mesh)
subs = ENG._group_submeshes(eng.mesh, (3, 3))
assert subs is not None and len(subs) == 2
ids = [tuple(d.id for d in m.devices.reshape(-1)) for m in subs]
assert ids[0] == (0, 1) and ids[1] == (2, 3), ids
gerr = max(
    float(jnp.max(jnp.abs(a - b)))
    for a, b in zip(jax.tree.leaves(want_g.trainable),
                    jax.tree.leaves(got_g.trainable))
)
gerr = max(gerr, abs(float(want_g.loss) - float(got_g.loss)))
print("GROUPED_MAXERR", gerr)
assert gerr <= 1e-5, gerr
"""


def test_sharded_multidevice_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_compile_cache")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MAXERR" in out.stdout
    assert "GROUPED_MAXERR" in out.stdout
