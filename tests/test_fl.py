"""FL runtime tests: partitioners, memory model, client training, a tiny
end-to-end ProFL run, and the four baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.effective_movement import EMConfig
from repro.fl import baselines as BL
from repro.fl import client as CL
from repro.fl import data as D
from repro.fl import memory_model as MM
from repro.fl.server import FLConfig, ProFLServer
from repro.models.cnn import CNNConfig
from repro.train.train_step import softmax_xent


@pytest.fixture(scope="module")
def tiny_world():
    rng = jax.random.PRNGKey(0)
    xtr, ytr, xte, yte = D.make_synthetic(rng, n_train=600, n_test=200, size=16)
    parts = D.partition_iid(jax.random.PRNGKey(1), len(xtr), 40)
    budgets = MM.assign_budgets_mb(np.random.default_rng(0), 40)
    return xtr, ytr, xte, yte, parts, budgets


def _fl(**kw):
    base = dict(
        n_clients=40, clients_per_round=6, local_steps=3, batch_size=16,
        n_local_fixed=24, max_rounds_per_step=4, distill_rounds=1,
        eval_every=100,
        em=EMConfig(window_h=2, slope_phi=0.05, patience_w=2, fit_points=3,
                    em_level=0.95, min_rounds=2),
    )
    base.update(kw)
    return FLConfig(**base)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_partition_iid_covers_all():
    parts = D.partition_iid(jax.random.PRNGKey(0), 100, 7)
    allidx = np.concatenate(parts)
    assert len(allidx) == 100 and len(np.unique(allidx)) == 100


def test_partition_dirichlet_covers_and_skews():
    labels = np.random.default_rng(0).integers(0, 10, size=2000)
    parts = D.partition_dirichlet(jax.random.PRNGKey(0), labels, 20, alpha=1.0)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == len(allidx) == 2000
    # non-IID: per-client label distributions differ from global
    fracs = []
    for p in parts:
        h = np.bincount(labels[p], minlength=10) / len(p)
        fracs.append(h)
    assert np.std(np.asarray(fracs), axis=0).mean() > 0.01


def test_synthetic_is_learnable_but_not_trivial():
    rng = jax.random.PRNGKey(3)
    xtr, ytr, xte, yte = D.make_synthetic(rng, n_train=300, n_test=120, size=16)
    assert xtr.shape == (300, 16, 16, 3)
    # nearest-class-mean gets above chance but below perfect
    means = np.stack([xtr[ytr == c].mean(0) for c in range(10)])
    d = ((xte[:, None] - means[None]) ** 2).sum((2, 3, 4))
    acc = (d.argmin(1) == yte).mean()
    assert 0.2 < acc <= 1.0


# ---------------------------------------------------------------------------
# memory model (paper Fig. 6 structure)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["resnet18", "resnet34", "vgg11", "vgg16"])
def test_block_memory_below_full_and_decreasing_participation(kind):
    cfg = CNNConfig(kind)
    full = MM.full_train_memory_mb(cfg)
    subs = [MM.submodel_train_memory_mb(cfg, t) for t in range(cfg.n_prog_blocks)]
    assert all(s < full for s in subs), (subs, full)
    # the paper's claim: later blocks need less memory than block 1
    assert subs[-1] < subs[0]
    # peak ProFL memory reduction vs full training (paper: up to 57.4%)
    assert 1 - max(subs) / full > 0.20


def test_exclusive_participation_regime():
    """Paper Tables 1-2 regime: nobody can full-train ResNet34/VGG16."""
    budgets = MM.assign_budgets_mb(np.random.default_rng(0), 100)
    assert len(MM.eligible(budgets, MM.full_train_memory_mb(CNNConfig("resnet34")))) == 0
    assert len(MM.eligible(budgets, MM.full_train_memory_mb(CNNConfig("vgg16")))) == 0
    r18 = len(MM.eligible(budgets, MM.full_train_memory_mb(CNNConfig("resnet18"))))
    assert 0 < r18 < 30


# ---------------------------------------------------------------------------
# client training
# ---------------------------------------------------------------------------


def test_cohort_round_reduces_loss(tiny_world):
    # width 0.125 keeps the XLA conv compile fast enough for tier-1; the
    # 0.25-width variant runs in the slow job via the end-to-end tests
    xtr, ytr, xte, yte, parts, budgets = tiny_world
    cfg = CNNConfig("vgg11", width_mult=0.0625, in_size=16)
    from repro.models import cnn as C

    params, bn = C.init_cnn(cfg, jax.random.PRNGKey(0))

    def loss_fn(trainable, frozen, bn_state, xb, yb):
        logits, new_bn = C.forward_cnn(cfg, trainable, bn_state, xb, train=True)
        return softmax_xent(logits, yb), new_bn

    rng = np.random.default_rng(0)
    losses = []
    for r in range(3):
        xs, ys, w = [], [], []
        for cid in range(6):
            xb, yb = D.client_batch(xtr, ytr, parts[cid], 24, rng)
            xs.append(xb), ys.append(yb), w.append(len(parts[cid]))
        params, bn, loss = CL.cohort_round(
            loss_fn, params, {}, bn,
            jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys)),
            jax.random.split(jax.random.PRNGKey(r), 6),
            jnp.asarray(np.array(w, np.float32)),
            lr=0.05, local_steps=3, batch_size=8,
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# end-to-end ProFL + baselines (tiny)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_profl_end_to_end(tiny_world):
    xtr, ytr, xte, yte, parts, budgets = tiny_world
    cfg = CNNConfig("vgg11", width_mult=0.125, in_size=16)
    srv = ProFLServer(cfg, _fl(), xtr, ytr, xte, yte, parts, budgets)
    res = srv.run()
    assert res["final_acc"] > 0.2  # well above 10% chance
    stages = [(s["stage"], s["t"]) for s in res["steps"]]
    assert stages == [("shrink", 1), ("grow", 0), ("grow", 1)]
    assert all(s["pr"] > 0 for s in res["steps"])


@pytest.mark.slow
def test_profl_engine_knob_equivalent(tiny_world):
    """The full ProFL workflow is engine-invariant: packed Pallas aggregation
    + flat EM bookkeeping reproduces the vmap/tree-map oracle run."""
    xtr, ytr, xte, yte, parts, budgets = tiny_world
    cfg = CNNConfig("vgg11", width_mult=0.125, in_size=16)
    runs = {}
    for eng in ("vmap", "packed"):
        srv = ProFLServer(cfg, _fl(engine=eng), xtr, ytr, xte, yte, parts,
                          budgets)
        runs[eng] = srv.run()
    a, b = runs["vmap"], runs["packed"]
    assert [(s["stage"], s["t"], s["rounds"]) for s in a["steps"]] == \
           [(s["stage"], s["t"], s["rounds"]) for s in b["steps"]]
    la = [h["loss"] for h in a["history"]]
    lb = [h["loss"] for h in b["history"]]
    np.testing.assert_allclose(la, lb, atol=1e-4)
    np.testing.assert_allclose(a["final_acc"], b["final_acc"], atol=0.02)


def test_heterofl_grouped_matches_serial_oracle(tiny_world):
    """Acceptance: HeteroFL through grouped_round (one fused masked dispatch)
    == the serial per-group oracle, real CNN, >=3 distinct width groups,
    uneven data-size weights."""
    xtr, ytr, xte, yte, parts, budgets = tiny_world
    cfg = CNNConfig("vgg11", width_mult=0.0625, in_size=16)
    fl = _fl(clients_per_round=6, local_steps=2, batch_size=8, n_local_fixed=16)
    levels = [MM.width_ratio_for_budget(cfg, b, BL.RATIOS[:-1]) or BL.RATIOS[-1]
              for b in budgets]
    assert len(set(levels)) >= 3  # the budget draw really is heterogeneous
    got = BL.run_heterofl(cfg, fl, xtr, ytr, xte, yte, parts, budgets, 1)
    want = BL.run_heterofl(cfg, fl, xtr, ytr, xte, yte, parts, budgets, 1,
                           oracle=True)
    for a, b in zip(jax.tree.leaves((want["params"], want["bn"])),
                    jax.tree.leaves((got["params"], got["bn"]))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # accuracy is discrete (steps of 1/len(xte)); tolerate argmax flips from
    # the ~1e-7 reduction-order differences between the two aggregation paths
    np.testing.assert_allclose(got["curve"], want["curve"], atol=0.02)
    assert got["levels"] == want["levels"]


def test_depthfl_grouped_matches_serial_oracle(tiny_world):
    """Acceptance: DepthFL through grouped_round == the serial per-group
    oracle (same round-start bn for every depth group, masked bn average)."""
    xtr, ytr, xte, yte, parts, budgets = tiny_world
    cfg = CNNConfig("vgg11", width_mult=0.0625, in_size=16)
    fl = _fl(clients_per_round=6, local_steps=2, batch_size=8, n_local_fixed=16)
    got = BL.run_depthfl(cfg, fl, xtr, ytr, xte, yte, parts, budgets, 1)
    want = BL.run_depthfl(cfg, fl, xtr, ytr, xte, yte, parts, budgets, 1,
                          oracle=True)
    for a, b in zip(
        jax.tree.leaves((want["params"], want["bn"], want["heads"])),
        jax.tree.leaves((got["params"], got["bn"], got["heads"])),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(got["curve"], want["curve"], atol=0.02)
    assert got["depths"] == want["depths"]


@pytest.mark.slow
def test_baselines_run(tiny_world):
    xtr, ytr, xte, yte, parts, budgets = tiny_world
    cfg = CNNConfig("vgg11", width_mult=0.125, in_size=16)
    fl = _fl()
    r_small = BL.run_allsmall(cfg, fl, xtr, ytr, xte, yte, parts, budgets, 3)
    assert r_small["acc"] is not None and r_small["pr"] == 1.0
    # baselines ride the same engine knob
    # accuracy is discrete (steps of 1/len(xte)); allow a few argmax flips
    # from reduction-order differences between the einsum and packed paths
    r_small_pk = BL.run_allsmall(cfg, _fl(engine="packed"), xtr, ytr, xte, yte,
                                 parts, budgets, 3)
    np.testing.assert_allclose(r_small_pk["curve"], r_small["curve"], atol=0.02)
    r_ex = BL.run_exclusivefl(cfg, fl, xtr, ytr, xte, yte, parts, budgets, 3)
    assert r_ex["pr"] >= 0.0  # may be NA
    r_het = BL.run_heterofl(cfg, fl, xtr, ytr, xte, yte, parts, budgets, 2)
    assert r_het["acc"] is not None
    r_dep = BL.run_depthfl(cfg, fl, xtr, ytr, xte, yte, parts, budgets, 2)
    assert r_dep["pr"] > 0
    # multi-round grouped vs serial oracle: single-round equivalence is
    # 1e-5 (tier-1 tests); across rounds the ~1e-7 reduction-order delta is
    # amplified by the next round's local SGD, so compare at 1e-3 and let
    # accuracy tolerate argmax flips
    r_het_o = BL.run_heterofl(cfg, fl, xtr, ytr, xte, yte, parts, budgets, 2,
                              oracle=True)
    for a, b in zip(jax.tree.leaves((r_het_o["params"], r_het_o["bn"])),
                    jax.tree.leaves((r_het["params"], r_het["bn"]))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
    np.testing.assert_allclose(r_het["curve"], r_het_o["curve"], atol=0.02)
    r_dep_o = BL.run_depthfl(cfg, fl, xtr, ytr, xte, yte, parts, budgets, 2,
                             oracle=True)
    for a, b in zip(
        jax.tree.leaves((r_dep_o["params"], r_dep_o["bn"], r_dep_o["heads"])),
        jax.tree.leaves((r_dep["params"], r_dep["bn"], r_dep["heads"])),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
    np.testing.assert_allclose(r_dep["curve"], r_dep_o["curve"], atol=0.02)
