"""Hypothesis property tests on system invariants."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    if os.environ.get("CI"):
        # CI installs hypothesis in EVERY job (see .github/workflows/ci.yml):
        # a missing install there must fail loudly, not silently skip the
        # whole property suite the way importorskip used to.
        raise
    pytest.skip("hypothesis not installed locally; CI always runs these",
                allow_module_level=True)
from hypothesis import given, settings, strategies as st

from repro.core import blocks as B
from repro.core import effective_movement as EM
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.train.train_step import softmax_xent

jax.config.update("jax_platform_name", "cpu")

SET = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# effective movement invariants (paper §3.3)
# ---------------------------------------------------------------------------


@given(
    st.lists(
        st.lists(st.floats(-1, 1, allow_nan=False, width=32), min_size=8,
                 max_size=8),
        min_size=3, max_size=8,
    )
)
@settings(**SET)
def test_em_always_in_unit_interval(updates):
    """EM = |Σu| / Σ|u| ∈ [0, 1] for ANY update sequence."""
    cfg = EM.EMConfig(window_h=len(updates))
    p = jnp.zeros((8,))
    stt = EM.em_init({"w": p})
    em = None
    for u in updates:
        p = p + jnp.asarray(u, jnp.float32)
        em = EM.em_update(cfg, stt, {"w": p})
    if em is not None:
        assert -1e-6 <= em <= 1.0 + 1e-6


@given(st.floats(0.01, 2.0), st.integers(2, 6))
@settings(**SET)
def test_em_constant_direction_is_one(step, h):
    cfg = EM.EMConfig(window_h=h)
    p = jnp.zeros((16,))
    stt = EM.em_init({"w": p})
    em = None
    for _ in range(h):
        p = p + step
        em = EM.em_update(cfg, stt, {"w": p})
    assert em is not None and abs(em - 1.0) < 1e-5


# ---------------------------------------------------------------------------
# fedavg: convex combination bounds + exactness vs weights
# ---------------------------------------------------------------------------


@given(
    st.integers(2, 6),  # K clients
    st.integers(4, 64),  # n params
    st.integers(0, 2**31 - 1),
)
@settings(**SET)
def test_fedavg_convex_combination(K, n, seed):
    kp, kw = jax.random.split(jax.random.PRNGKey(seed))
    params = jax.random.normal(kp, (K, n))
    w = jax.nn.softmax(jax.random.normal(kw, (K,)))
    out = np.asarray(ref.fedavg(params, w))
    lo = np.min(np.asarray(params), axis=0)
    hi = np.max(np.asarray(params), axis=0)
    assert np.all(out >= lo - 1e-5) and np.all(out <= hi + 1e-5)
    # identical clients -> identity
    same = jnp.broadcast_to(params[:1], params.shape)
    np.testing.assert_allclose(
        np.asarray(ref.fedavg(same, w)), np.asarray(params[0]), atol=1e-5
    )


# ---------------------------------------------------------------------------
# fedavg_grouped: group-compressed == dense-mask oracle, shard invariance
# ---------------------------------------------------------------------------


def _grouped_case(draw_ints, seed, G, ks, n):
    """Build a random grouped-aggregation instance: per-group column sets,
    panel zeroed outside each group's columns (the engine's scatter
    invariant), raw weights with a possible zero-weight group."""
    rng = jax.random.PRNGKey(seed)
    gid = np.repeat(np.arange(G), ks)  # client -> group
    K = int(gid.size)
    gmask = (jax.random.uniform(jax.random.fold_in(rng, 1), (G, n)) > 0.4
             ).astype(jnp.float32)
    mask = gmask[gid]  # dense per-client expansion
    p = jax.random.normal(rng, (K, n)) * mask
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 2), (K,))) + 0.1
    if draw_ints % 3 == 0 and G > 1:
        # zero out one whole group's weights: its unique columns must fall
        # back to prev via the zero-denominator passthrough
        w = w * jnp.asarray(gid != (draw_ints % G), jnp.float32)
    wsum = jnp.zeros((G,)).at[gid].add(w)
    prev = jax.random.normal(jax.random.fold_in(rng, 3), (n,))
    return p, w, mask, gmask, wsum, prev


@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 4),  # G groups
    st.lists(st.integers(1, 3), min_size=1, max_size=4),  # K_g per group
    st.integers(1, 300),  # n params — deliberately NOT tile-aligned
    st.integers(0, 100),
)
@settings(max_examples=25, deadline=None)
def test_fedavg_grouped_matches_masked_oracle(seed, G, ks, n, zsel):
    ks = (ks * G)[:G]
    p, w, mask, gmask, wsum, prev = _grouped_case(zsel, seed, G, ks, n)
    want = ref.fedavg_masked(p, w, mask, prev)
    got = ref.fedavg_grouped(p, w, gmask, wsum, prev)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5,
                               rtol=1e-5)


@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 3),
    st.integers(1, 200),
    st.integers(1, 4),  # shard count
)
@settings(max_examples=25, deadline=None)
def test_fedavg_grouped_shard_invariance(seed, G, n, n_shards):
    """Splitting the columns into tile-aligned shards and aggregating each
    independently is BITWISE identical to the unsharded oracle — the
    invariant the column-sharded engine path (fl/engine.py agg="sharded")
    rests on."""
    ks = [2] * G
    p, w, mask, gmask, wsum, prev = _grouped_case(1, seed, G, ks, n)
    want = ref.fedavg_grouped(p, w, gmask, wsum, prev)
    got = ref.fedavg_grouped_sharded(p, w, gmask, wsum, prev,
                                     n_shards=n_shards)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 2),
    st.integers(1, 128),
)
@settings(max_examples=8, deadline=None)
def test_fedavg_grouped_kernel_matches_ref(seed, G, n):
    """The Pallas kernel (interpret mode on CPU) against the jnp oracle at
    hypothesis-driven non-tile-aligned shapes."""
    from repro.kernels import fedavg as FK

    ks = [2] * G
    p, w, mask, gmask, wsum, prev = _grouped_case(1, seed, G, ks, n)
    want = ref.fedavg_grouped(p, w, gmask, wsum, prev)
    got = FK.fedavg_grouped(p, w, gmask, wsum, prev, bt=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# GroupLayout: scatter round-trip + column-shard partition invariants
# ---------------------------------------------------------------------------


@given(
    st.integers(0, 2**31 - 1),
    st.lists(st.integers(1, 8), min_size=1, max_size=4),  # per-group widths
    st.integers(1, 4),  # shard count
)
@settings(max_examples=20, deadline=None)
def test_group_layout_scatter_roundtrip(seed, widths, n_shards):
    """Scattering each group's packed subtree through the layout's column
    indices and gathering back must round-trip exactly; the group mask must
    be the indicator of those indices; the column-shard partition must be
    tile-aligned and cover every column exactly once."""
    from repro.fl import engine as ENG
    from repro.kernels.fedavg import AGG_TILE

    d, out = 8, 3
    rng = jax.random.PRNGKey(seed)
    gtr = {"w": jax.random.normal(rng, (d, out)), "b": jnp.zeros((out,))}
    plans = []
    for gi, f in enumerate(widths):
        sub = {"w": gtr["w"][:f], "b": gtr["b"]}
        xs = jnp.zeros((2, 4, d))
        ys = jnp.zeros((2, 4))
        rngs = jax.random.split(jax.random.fold_in(rng, gi), 2)
        plans.append(ENG.GroupPlan(
            lambda tr, fro, bn, xb, yb: (jnp.zeros(()), bn),
            sub, {}, {}, xs, ys, rngs, jnp.ones((2,)), 0.1, 1, 4,
        ))
    layout = ENG.make_group_layout(plans, gtr, {})
    if layout.identity:
        return  # single full-width group: no indices to round-trip
    for gi, plan in enumerate(plans):
        spec = ENG.make_pack_spec(plan.trainable)
        vec = jax.random.normal(jax.random.fold_in(rng, 50 + gi), (spec.n,))
        flat = jnp.zeros((layout.n,)).at[layout.idx[gi]].set(vec)
        np.testing.assert_array_equal(
            np.asarray(flat[layout.idx[gi]]), np.asarray(vec)
        )
        indicator = np.zeros(layout.n, np.float32)
        indicator[layout.idx[gi]] = 1.0
        np.testing.assert_array_equal(
            np.asarray(layout.gmask[gi]), indicator
        )
    cs = layout.column_shards(n_shards)
    assert cs.n_shard % AGG_TILE == 0
    assert cs.n_padded == cs.n_shard * n_shards >= layout.n
    # shard ranges tile the padded column space exactly
    covered = np.concatenate(
        [np.arange(o, o + cs.n_shard) for o in cs.offsets]
    )
    np.testing.assert_array_equal(covered, np.arange(cs.n_padded))


@given(
    st.integers(0, 2**31 - 1),
    st.lists(st.integers(1, 8), min_size=2, max_size=4),  # per-group widths
    st.integers(1, 4),  # shard count
)
@settings(max_examples=20, deadline=None)
def test_stream_plan_partitions_and_bounds(seed, widths, n_shards):
    """The shard-local stream plan (fl/engine.py::GroupLayout.stream_plan)
    must (1) route every group column to exactly the shard that owns it,
    exactly once; (2) keep every pass's per-shard slice within the
    tile-aligned even share ``m_chunk ≤ n_g/D + tile`` in at most D passes;
    (3) reconstruct, via numpy-simulated gather+scatter, exactly the panel
    the direct global scatter produces — the invariant the engine's
    bit-equality to the replicated path rests on."""
    from repro.fl import engine as ENG
    from repro.kernels.fedavg import AGG_TILE

    d, out = 8, 3
    rng = jax.random.PRNGKey(seed)
    gtr = {"w": jax.random.normal(rng, (d, out)), "b": jnp.zeros((out,))}
    plans = []
    for gi, f in enumerate(widths):
        sub = {"w": gtr["w"][:f], "b": gtr["b"]}
        xs = jnp.zeros((2, 4, d))
        ys = jnp.zeros((2, 4))
        rngs = jax.random.split(jax.random.fold_in(rng, gi), 2)
        plans.append(ENG.GroupPlan(
            lambda tr, fro, bn, xb, yb: (jnp.zeros(()), bn),
            sub, {}, {}, xs, ys, rngs, jnp.ones((2,)), 0.1, 1, 4,
        ))
    layout = ENG.make_group_layout(plans, gtr, {})
    if layout.identity:
        return
    cs = layout.column_shards(n_shards)
    nprng = np.random.default_rng(seed)
    for gi in range(layout.n_groups):
        ix = layout.idx[gi]
        n_g = int(ix.size)
        sp = layout.stream_plan(gi, n_shards)
        even = -(-n_g // n_shards)
        assert sp.m_chunk == min(n_g, -(-even // AGG_TILE) * AGG_TILE)
        assert 1 <= sp.n_chunks <= n_shards
        vec = nprng.normal(size=n_g).astype(np.float32)
        flat = np.zeros(cs.n_padded, np.float32)
        placed = 0
        for c in range(sp.n_chunks):
            for d_ in range(n_shards):
                src, dst = sp.src[c, d_], sp.dst[c, d_]
                valid = dst < cs.n_shard
                # every valid pair maps a group column to its OWNING shard
                np.testing.assert_array_equal(
                    cs.offsets[d_] + dst[valid], ix[src[valid]]
                )
                flat[cs.offsets[d_] + dst[valid]] = vec[src[valid]]
                placed += int(valid.sum())
        assert placed == n_g  # each column streamed exactly once
        want = np.zeros(cs.n_padded, np.float32)
        want[ix] = vec
        np.testing.assert_array_equal(flat, want)


@given(
    st.integers(0, 2**31 - 1),
    st.lists(st.integers(1, 8), min_size=2, max_size=4),  # per-group widths
    st.integers(1, 4),  # shard count
    st.floats(0.0, 1.0),  # frozen fraction
)
@settings(max_examples=20, deadline=None)
def test_frozen_layout_pack_scatter_stream_roundtrip(seed, widths, n_shards,
                                                     frac):
    """Fuzz random frozen masks through the layout machinery: stable global
    column ids are UNCHANGED versus the unfrozen layout, ``dst`` remaps them
    through the compressed column map, the gmask marks exactly the live
    destinations, values round-trip through the compressed scatter, and the
    stream plan routes every LIVE column to its owning shard exactly once —
    frozen columns appear in no panel, mask, or stream structure at all."""
    from repro.fl import engine as ENG
    from repro.kernels.fedavg import AGG_TILE

    d, out = 8, 3
    rng = jax.random.PRNGKey(seed)
    gtr = {"w": jax.random.normal(rng, (d, out)), "b": jnp.zeros((out,))}
    plans = []
    for gi, f in enumerate(widths):
        sub = {"w": gtr["w"][:f], "b": gtr["b"]}
        xs = jnp.zeros((2, 4, d))
        ys = jnp.zeros((2, 4))
        rngs = jax.random.split(jax.random.fold_in(rng, gi), 2)
        plans.append(ENG.GroupPlan(
            lambda tr, fro, bn, xb, yb: (jnp.zeros(()), bn),
            sub, {}, {}, xs, ys, rngs, jnp.ones((2,)), 0.1, 1, 4,
        ))
    base = ENG.make_group_layout(plans, gtr, {})
    nprng = np.random.default_rng(seed)
    fro = ENG.make_frozen_columns(nprng.random(base.n) < frac)
    if fro is None:  # all-live mask: nothing to compress
        return
    layout = ENG.make_group_layout(plans, gtr, {}, frozen=fro)
    assert layout.n_active == fro.n_active == base.n - fro.n_frozen
    col_map = np.full(layout.n, layout.n_active, np.int64)
    col_map[fro.active_idx] = np.arange(layout.n_active)
    cs = layout.column_shards(n_shards)
    for gi in range(layout.n_groups):
        ix = layout.idx[gi]
        # stable ids: identical to the unfrozen layout's indices
        np.testing.assert_array_equal(ix, base.idx[gi])
        np.testing.assert_array_equal(layout.dst[gi], col_map[ix])
        live = layout.group_active_cols(gi)
        assert np.all(live < layout.n_active)
        indicator = np.zeros(layout.n_active, np.float32)
        indicator[live] = 1.0
        np.testing.assert_array_equal(np.asarray(layout.gmask[gi]), indicator)
        # value round-trip through the compressed scatter: live positions
        # land on their dst columns and gather back exactly
        pos = np.nonzero(layout.dst[gi] < layout.n_active)[0]
        vec = nprng.normal(size=ix.size).astype(np.float32)
        flat = np.zeros(layout.n_active, np.float32)
        flat[layout.dst[gi][pos]] = vec[pos]
        np.testing.assert_array_equal(flat[layout.dst[gi][pos]], vec[pos])
        # stream plan: every live column exactly once, onto its owning
        # shard, with m_chunk sized from the LIVE count
        sp = layout.stream_plan(gi, n_shards)
        n_live = int(live.size)
        even = -(-n_live // n_shards) if n_live else 0
        want_chunk = (min(n_live, -(-even // AGG_TILE) * AGG_TILE)
                      if n_live else 0)
        assert sp.m_chunk == want_chunk
        placed = []
        for c in range(sp.n_chunks):
            for d_ in range(n_shards):
                src, dstv = sp.src[c, d_], sp.dst[c, d_]
                valid = dstv < cs.n_shard
                assert np.all(src[valid] < ix.size)
                # every streamed source position is LIVE...
                assert np.all(layout.dst[gi][src[valid]] < layout.n_active)
                # ...and lands on exactly the shard that owns its column
                np.testing.assert_array_equal(
                    cs.offsets[d_] + dstv[valid],
                    layout.dst[gi][src[valid]],
                )
                placed.append(src[valid])
        placed = (np.concatenate(placed) if placed
                  else np.zeros(0, np.int64))
        assert placed.size == n_live  # each live column streamed once
        np.testing.assert_array_equal(
            np.sort(layout.dst[gi][placed]), np.sort(live)
        )


# ---------------------------------------------------------------------------
# block partitioning invariants
# ---------------------------------------------------------------------------


@given(st.integers(1, 128), st.integers(1, 8))
@settings(**SET)
def test_boundaries_partition(n_groups, n_blocks):
    bs = B.group_boundaries(n_groups, n_blocks)
    assert bs[0] == 0 and bs[-1] == n_groups
    widths = [b2 - b1 for b1, b2 in zip(bs, bs[1:])]
    assert all(w >= 1 for w in widths)
    assert max(widths) - min(widths) <= 1  # near-even split


# ---------------------------------------------------------------------------
# attention invariants
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 32, 64]))
@settings(max_examples=10, deadline=None)
def test_attention_rows_are_convex(seed, S):
    """With v = one-hot basis, attention outputs are softmax rows: each sums
    to 1 and is causal (no weight on future positions)."""
    rng = jax.random.PRNGKey(seed)
    B_, H, hd = 1, 2, S  # hd == S so v can be identity
    q = jax.random.normal(rng, (B_, H, S, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B_, H, S, hd))
    v = jnp.broadcast_to(jnp.eye(S)[None, None], (B_, H, S, S))
    out = np.asarray(ref.attention(q, k, v, causal=True))  # rows of softmax
    np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-4)
    for i in range(S):
        assert np.all(np.abs(out[0, 0, i, i + 1:]) < 1e-6)  # causal


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_rope_preserves_norm_and_relativity(seed):
    rng = jax.random.PRNGKey(seed)
    x = jax.random.normal(rng, (1, 1, 8, 64))
    pos = jnp.arange(8)
    y = L.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.fold_in(rng, 1), (64,))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (64,))
    def dot_at(i, j):
        qr = L.rope(q[None], jnp.array([i]), 1e4)[0]
        kr = L.rope(k[None], jnp.array([j]), 1e4)[0]
        return float(qr @ kr)
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-3


# ---------------------------------------------------------------------------
# loss invariants
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31 - 1), st.integers(2, 50))
@settings(**SET)
def test_xent_nonnegative_and_uniform_bound(seed, V):
    rng = jax.random.PRNGKey(seed)
    logits = jax.random.normal(rng, (4, 7, V))
    labels = jax.random.randint(jax.random.fold_in(rng, 1), (4, 7), 0, V)
    l = float(softmax_xent(logits, labels))
    assert l >= 0.0
    # uniform logits give exactly log(V)
    lu = float(softmax_xent(jnp.zeros((4, 7, V)), labels))
    assert abs(lu - np.log(V)) < 1e-5


# ---------------------------------------------------------------------------
# kernel vs oracle under hypothesis-driven shapes
# ---------------------------------------------------------------------------


@given(
    st.integers(0, 2**31 - 1),
    st.sampled_from([(1, 2, 1), (2, 4, 2), (1, 4, 4)]),  # B, H, K
    st.sampled_from([64, 128]),
)
@settings(max_examples=8, deadline=None)
def test_chunked_attention_matches_oracle(seed, bhk, S):
    B_, H, K = bhk
    rng = jax.random.PRNGKey(seed)
    q = jax.random.normal(rng, (B_, H, S, 32))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B_, K, S, 32))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B_, K, S, 32))
    want = ref.attention(q, k, v, causal=True)
    got = ops.attention(q, k, v, causal=True, impl="chunked", bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# quantized wire (ISSUE 7): per-column int8 scheme + error feedback
# ---------------------------------------------------------------------------


@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 6),    # K panel rows
    st.integers(1, 17),   # n columns
    st.floats(1e-3, 1e3),  # magnitude spread across examples
)
@settings(**SET)
def test_quantize_columns_round_trip_bound(seed, K, n, mag):
    """``|dequantize(quantize(t)) - t| ≤ one per-column scale`` — the
    quantum the fused dequant kernel's reconstruction can be off by.  The
    bf16 scales must decode EXACTLY from the 4-bit exponents + group base
    (what the receiving shard reconstructs from the packed wire), values
    stay in ±127, exponents in 0..15."""
    rng = jax.random.PRNGKey(seed)
    t = jax.random.normal(rng, (K, n)) * mag
    q, scale, e, gbase = ref.quantize_columns(t)
    assert q.dtype == jnp.int8 and scale.dtype == jnp.bfloat16
    qn = np.asarray(q, np.int32)
    assert qn.min() >= -127 and qn.max() <= 127
    en = np.asarray(e, np.int32)
    assert en.min() >= 0 and en.max() <= 15
    np.testing.assert_array_equal(
        np.asarray(ref.decode_scale_exponents(e, gbase), np.float32),
        np.asarray(scale, np.float32),
    )
    deq = np.asarray(ref.dequantize_columns(q, scale), np.float32)
    err = np.abs(deq - np.asarray(t, np.float32))
    bound = np.asarray(scale, np.float32)[None, :]
    assert np.all(err <= bound + 1e-30), (float(err.max()), bound.max())


@given(st.lists(st.integers(0, 15), min_size=2, max_size=32))
@settings(**SET)
def test_scale_exponent_pack_roundtrip(vals):
    """Two-exponents-per-byte packing (the 0.5 B/column scale wire format)
    is exact for every 4-bit value sequence."""
    if len(vals) % 2:
        vals = vals + [0]
    e = jnp.asarray(vals, jnp.int8)
    packed = ref.pack_scale_exponents(e)
    assert packed.shape[0] == len(vals) // 2 and packed.dtype == jnp.int8
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_scale_exponents(packed)),
        np.asarray(vals, np.int32),
    )


@given(st.integers(0, 2**31 - 1), st.integers(2, 6))
@settings(max_examples=15, deadline=None)
def test_error_feedback_telescopes(seed, R):
    """Error feedback telescopes: round ``r`` quantizes ``t + ef_{r-1}``
    and ships ``t + ef_{r-1} - ef_r``, so the SUM of R dequantized rounds
    is ``R·t - ef_R`` — within one final-round scale of ``R·t`` per column.
    Quantization error cannot accumulate across rounds, which is what the
    engine's per-group ``_ef_state`` buys int8 training."""
    rng = jax.random.PRNGKey(seed)
    t = jax.random.normal(rng, (3, 11)) * 5.0
    tn = np.asarray(t, np.float64)
    ef = jnp.zeros_like(t)
    acc = np.zeros_like(tn)
    scale = None
    for _ in range(R):
        q, scale, e, gbase = ref.quantize_columns(t + ef)
        deq = ref.dequantize_columns(q, scale)
        ef = t + ef - deq
        acc += np.asarray(deq, np.float64)
    bound = np.asarray(scale, np.float64)[None, :] + 1e-4
    assert np.all(np.abs(acc - R * tn) <= bound), (
        float(np.max(np.abs(acc - R * tn))), float(bound.max())
    )


@given(
    st.integers(0, 2**31 - 1),
    st.lists(st.integers(1, 290), min_size=2, max_size=4),  # group widths
    st.integers(1, 4),  # shard count
)
@settings(max_examples=20, deadline=None)
def test_ragged_stream_plan_widths_invariants(seed, widths, n_shards):
    """The ragged-transfer metadata ISSUE 7 added to ``StreamPlan``:
    per-(pass, shard) live ``widths`` are tile-aligned (or capped at
    ``m_chunk``), bound every live destination of that pass — live entries
    are packed at the FRONT of the slice, which is exactly what lets
    ``put_model_ragged`` ship only ``sel[d, :, :w]`` — sum per shard to the
    memory model's ``_ragged_wire_cols`` wire term, and ``chunk_counts``
    counts each shard's non-empty passes (a shard owning none of the
    group's columns takes zero passes and zero wire)."""
    from repro.fl import engine as ENG
    from repro.fl import memory_model as MM
    from repro.kernels.fedavg import AGG_TILE

    d, out = 300, 3
    rng = jax.random.PRNGKey(seed)
    gtr = {"w": jax.random.normal(rng, (d, out)), "b": jnp.zeros((out,))}
    plans = []
    for gi, f in enumerate(widths):
        sub = {"w": gtr["w"][:f], "b": gtr["b"]}
        xs = jnp.zeros((2, 4, d))
        ys = jnp.zeros((2, 4))
        rngs = jax.random.split(jax.random.fold_in(rng, gi), 2)
        plans.append(ENG.GroupPlan(
            lambda tr, fro, bn, xb, yb: (jnp.zeros(()), bn),
            sub, {}, {}, xs, ys, rngs, jnp.ones((2,)), 0.1, 1, 4,
        ))
    layout = ENG.make_group_layout(plans, gtr, {})
    if layout.identity:
        return
    cs = layout.column_shards(n_shards)
    for gi in range(layout.n_groups):
        sp = layout.stream_plan(gi, n_shards)
        assert sp.widths.shape == (sp.n_chunks, n_shards)
        assert len(sp.chunk_counts) == n_shards
        assert sp.n_chunks == (max(sp.chunk_counts) if sp.chunk_counts
                               else 0)
        live = layout.group_active_cols(gi)
        for d_ in range(n_shards):
            lo = cs.offsets[d_]
            L = int(np.sum((live >= lo) & (live < lo + cs.n_shard)))
            assert sp.chunk_counts[d_] == (-(-L // sp.m_chunk) if L else 0)
            assert sum(int(w) for w in sp.widths[:, d_]) == \
                MM._ragged_wire_cols(L, sp.m_chunk, AGG_TILE)
            for c in range(sp.n_chunks):
                w = int(sp.widths[c, d_])
                assert 0 <= w <= sp.m_chunk
                assert w % AGG_TILE == 0 or w == sp.m_chunk
                if c >= sp.chunk_counts[d_]:
                    assert w == 0
                valid = np.nonzero(
                    np.asarray(sp.dst[c, d_]) < cs.n_shard
                )[0]
                assert valid.size <= w
                if valid.size:
                    # live entries packed at the front of the pass slice
                    assert int(valid.max()) < w


# ---------------------------------------------------------------------------
# async buffered aggregation (ISSUE 9): fl/async_server.py invariants
# ---------------------------------------------------------------------------

from repro.fl import async_server as AS  # noqa: E402
from repro.fl import engine as ENG  # noqa: E402


def _async_srv(gtr, **kw):
    return AS.AsyncAggServer(ENG.make_engine("packed"), gtr, {}, **kw)


@given(st.integers(0, 2**31 - 1), st.integers(2, 8), st.integers(3, 24))
@settings(max_examples=10, deadline=None)
def test_async_arrival_order_invariance(seed, k, n):
    """Any arrival-order permutation of same-version submissions carrying
    stable tags publishes the IDENTICAL model: the num/den merge is
    associative and the server folds in canonical (version, tag, seq)
    order, so arrival order cannot leak into the result."""
    rng = np.random.default_rng(seed)
    gtr = {"w": jnp.asarray(rng.normal(size=(n,)), jnp.float32)}
    vals = rng.normal(size=(k, n)).astype(np.float32)
    w = rng.uniform(0.1, 2.0, size=k).astype(np.float32)

    def run(order):
        srv = _async_srv(gtr, publish_at=k)
        for i in order:
            srv.submit_rows(vals[i:i + 1], w[i:i + 1], 0, tag=int(i))
        return srv.publish()

    a = run(range(k))
    b = run(rng.permutation(k))
    for x, y in zip(jax.tree.leaves(a.trainable), jax.tree.leaves(b.trainable)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


@given(st.integers(0, 2**31 - 1), st.integers(1, 5), st.integers(2, 8),
       st.floats(0.3, 1.0))
@settings(max_examples=10, deadline=None)
def test_async_staleness_discount_matches_host_reference(seed, V, k, beta):
    """A publish over rows with random staleness s must equal the host
    reference ``Σ w·β^s·vals / Σ w·β^s`` per column — the ``β^s`` discount
    the engine's ``_staged_side`` applies, priced per submission."""
    rng = np.random.default_rng(seed)
    n = 6
    gtr = {"w": jnp.asarray(rng.normal(size=(n,)), jnp.float32)}
    srv = _async_srv(gtr, publish_at=k, beta=float(beta))
    srv.version = V  # as if V publishes already happened
    s = rng.integers(0, V + 1, size=k)
    vals = rng.normal(size=(k, n)).astype(np.float32)
    w = rng.uniform(0.1, 2.0, size=k).astype(np.float32)
    for i in range(k):
        srv.submit_rows(vals[i:i + 1], w[i:i + 1], int(V - s[i]))
    res = srv.publish()
    disc = (w.astype(np.float64) * np.float64(beta) ** s)
    want = (disc[:, None] * vals.astype(np.float64)).sum(0) / disc.sum()
    np.testing.assert_allclose(
        np.asarray(res.trainable["w"], np.float64), want,
        rtol=2e-4, atol=2e-5,
    )
    hist = {}
    for si in s:
        hist[int(si)] = hist.get(int(si), 0) + 1
    assert ENG.AGG_STATS["async_staleness_hist"] == hist


@given(st.integers(0, 2**31 - 1), st.integers(1, 12), st.integers(1, 30))
@settings(max_examples=20, deadline=None)
def test_async_buffer_fifo_eviction_invariants(seed, max_buffer, n_subs):
    """Under any random submission stream the buffer stays row-bounded
    (modulo a lone over-sized submission), evicts strictly oldest-first
    (the retained entries are a contiguous SUFFIX of the stream), and
    conserves rows (submitted == held + evicted)."""
    rng = np.random.default_rng(seed)
    gtr = {"w": jnp.zeros((4,), jnp.float32)}
    srv = _async_srv(gtr, publish_at=1, max_buffer=max_buffer)
    total = 0
    for i in range(n_subs):
        k = int(rng.integers(1, 5))
        srv.submit_rows(np.zeros((k, 4), np.float32),
                        np.ones((k,), np.float32), 0)
        total += k
        if len(srv.buffer) > 1:
            assert srv.buffer_rows <= max_buffer
        seqs = [e.seq for e in srv.buffer]
        assert seqs == list(range(seqs[0], i + 1))
        assert total == srv.buffer_rows + srv.evicted


# ---------------------------------------------------------------------------
# population admission (ISSUE 10): monotone gates, exact quotas, pure cursor
# ---------------------------------------------------------------------------

import dataclasses
import functools

from repro.fl import population as POP


@functools.lru_cache(maxsize=1)
def _prop_pop():
    # one registry for all examples — the properties vary only the knobs
    # that sample_cohort reads (seed, round, budgets), never the build
    return POP.build_population(
        POP.PopulationConfig(n_clients=4000, n_groups=4, seed=5)
    )


@given(st.integers(0, 50), st.integers(0, 3999), st.booleans(),
       st.floats(0.0, 500.0))
@settings(**SET)
def test_cohort_admission_monotone_in_budget(rnd, client, boundary, delta):
    """Raising ONE client's budget never flips that client from admitted to
    rejected: the per-stratum Gumbel draw order is independent of budgets
    (one draw per member every round), so a budget edit can only turn the
    client's own device-gate rejection into an admission.  The ``boundary``
    arm draws the client from the one stratum the need vector genuinely
    rejects (budget below need[3]=750) with a raise that guarantees
    affordability, so the rejected→admitted direction is exercised too."""
    pop = _prop_pop()
    need = np.asarray([50.0, 250.0, 450.0, 750.0])
    if boundary:
        cands = pop.strata[3][pop.budgets_mb[pop.strata[3]] < 750.0]
        client = int(cands[client % len(cands)])
        delta = 500.0
    base = POP.sample_cohort(pop, rnd, cohort_size=64, need_mb=need)
    b2 = pop.budgets_mb.copy()
    b2[client] = b2[client] + np.float32(delta)
    pop2 = dataclasses.replace(pop, budgets_mb=b2)
    raised = POP.sample_cohort(pop2, rnd, cohort_size=64, need_mb=need)
    if client in base.ids:
        assert client in raised.ids
    # and nothing else about the draw reshuffles: the two cohorts differ
    # at most by admissions within the edited client's stratum
    g = int(pop.groups[client])
    same = base.groups != g
    np.testing.assert_array_equal(base.ids[same], raised.ids[raised.groups != g])


@given(
    st.lists(st.floats(0.5, 1000.0), min_size=1, max_size=12),
    st.integers(1, 512),
)
@settings(**SET)
def test_cohort_quotas_exact_and_proportional(shares, size):
    """Largest-remainder quotas: they sum EXACTLY to the cohort size and
    each stratum sits within one seat of its proportional share."""
    sh = np.asarray(shares, np.float64)
    q = POP._quotas(sh, size)
    assert int(q.sum()) == size
    raw = sh / sh.sum() * size
    assert np.all(q >= np.floor(raw)) and np.all(q <= np.ceil(raw))


@given(st.integers(0, 10**6), st.integers(1, 6), st.integers(0, 5))
@settings(**SET)
def test_cohort_cursor_resume_is_pure(seed, n_rounds, stop_at):
    """The resumable cursor: serializing mid-stream and restoring into a
    fresh sampler continues the exact sequence — because each round is a
    pure function of (seed, round), the cursor IS the whole state."""
    pop = _prop_pop()
    need = np.asarray([50.0, 250.0, 450.0, 750.0])
    stop_at = min(stop_at, n_rounds)
    kw = dict(cohort_size=32, need_mb=need, seed=seed)
    ref = POP.CohortSampler(pop, **kw)
    want = [ref.next_cohort() for _ in range(n_rounds)]
    a = POP.CohortSampler(pop, **kw)
    for _ in range(stop_at):
        a.next_cohort()
    b = POP.CohortSampler(pop, **kw)
    b.state_from_tree(a.state_to_tree())
    got = [b.next_cohort() for _ in range(n_rounds - stop_at)]
    for w, g in zip(want[stop_at:], got):
        assert w.round_idx == g.round_idx
        np.testing.assert_array_equal(w.ids, g.ids)
