"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed; "
                    "property tests run in the CI slow job")
from hypothesis import given, settings, strategies as st

from repro.core import blocks as B
from repro.core import effective_movement as EM
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.train.train_step import softmax_xent

jax.config.update("jax_platform_name", "cpu")

SET = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# effective movement invariants (paper §3.3)
# ---------------------------------------------------------------------------


@given(
    st.lists(
        st.lists(st.floats(-1, 1, allow_nan=False, width=32), min_size=8,
                 max_size=8),
        min_size=3, max_size=8,
    )
)
@settings(**SET)
def test_em_always_in_unit_interval(updates):
    """EM = |Σu| / Σ|u| ∈ [0, 1] for ANY update sequence."""
    cfg = EM.EMConfig(window_h=len(updates))
    p = jnp.zeros((8,))
    stt = EM.em_init({"w": p})
    em = None
    for u in updates:
        p = p + jnp.asarray(u, jnp.float32)
        em = EM.em_update(cfg, stt, {"w": p})
    if em is not None:
        assert -1e-6 <= em <= 1.0 + 1e-6


@given(st.floats(0.01, 2.0), st.integers(2, 6))
@settings(**SET)
def test_em_constant_direction_is_one(step, h):
    cfg = EM.EMConfig(window_h=h)
    p = jnp.zeros((16,))
    stt = EM.em_init({"w": p})
    em = None
    for _ in range(h):
        p = p + step
        em = EM.em_update(cfg, stt, {"w": p})
    assert em is not None and abs(em - 1.0) < 1e-5


# ---------------------------------------------------------------------------
# fedavg: convex combination bounds + exactness vs weights
# ---------------------------------------------------------------------------


@given(
    st.integers(2, 6),  # K clients
    st.integers(4, 64),  # n params
    st.integers(0, 2**31 - 1),
)
@settings(**SET)
def test_fedavg_convex_combination(K, n, seed):
    kp, kw = jax.random.split(jax.random.PRNGKey(seed))
    params = jax.random.normal(kp, (K, n))
    w = jax.nn.softmax(jax.random.normal(kw, (K,)))
    out = np.asarray(ref.fedavg(params, w))
    lo = np.min(np.asarray(params), axis=0)
    hi = np.max(np.asarray(params), axis=0)
    assert np.all(out >= lo - 1e-5) and np.all(out <= hi + 1e-5)
    # identical clients -> identity
    same = jnp.broadcast_to(params[:1], params.shape)
    np.testing.assert_allclose(
        np.asarray(ref.fedavg(same, w)), np.asarray(params[0]), atol=1e-5
    )


# ---------------------------------------------------------------------------
# block partitioning invariants
# ---------------------------------------------------------------------------


@given(st.integers(1, 128), st.integers(1, 8))
@settings(**SET)
def test_boundaries_partition(n_groups, n_blocks):
    bs = B.group_boundaries(n_groups, n_blocks)
    assert bs[0] == 0 and bs[-1] == n_groups
    widths = [b2 - b1 for b1, b2 in zip(bs, bs[1:])]
    assert all(w >= 1 for w in widths)
    assert max(widths) - min(widths) <= 1  # near-even split


# ---------------------------------------------------------------------------
# attention invariants
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 32, 64]))
@settings(max_examples=10, deadline=None)
def test_attention_rows_are_convex(seed, S):
    """With v = one-hot basis, attention outputs are softmax rows: each sums
    to 1 and is causal (no weight on future positions)."""
    rng = jax.random.PRNGKey(seed)
    B_, H, hd = 1, 2, S  # hd == S so v can be identity
    q = jax.random.normal(rng, (B_, H, S, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B_, H, S, hd))
    v = jnp.broadcast_to(jnp.eye(S)[None, None], (B_, H, S, S))
    out = np.asarray(ref.attention(q, k, v, causal=True))  # rows of softmax
    np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-4)
    for i in range(S):
        assert np.all(np.abs(out[0, 0, i, i + 1:]) < 1e-6)  # causal


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_rope_preserves_norm_and_relativity(seed):
    rng = jax.random.PRNGKey(seed)
    x = jax.random.normal(rng, (1, 1, 8, 64))
    pos = jnp.arange(8)
    y = L.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.fold_in(rng, 1), (64,))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (64,))
    def dot_at(i, j):
        qr = L.rope(q[None], jnp.array([i]), 1e4)[0]
        kr = L.rope(k[None], jnp.array([j]), 1e4)[0]
        return float(qr @ kr)
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-3


# ---------------------------------------------------------------------------
# loss invariants
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31 - 1), st.integers(2, 50))
@settings(**SET)
def test_xent_nonnegative_and_uniform_bound(seed, V):
    rng = jax.random.PRNGKey(seed)
    logits = jax.random.normal(rng, (4, 7, V))
    labels = jax.random.randint(jax.random.fold_in(rng, 1), (4, 7), 0, V)
    l = float(softmax_xent(logits, labels))
    assert l >= 0.0
    # uniform logits give exactly log(V)
    lu = float(softmax_xent(jnp.zeros((4, 7, V)), labels))
    assert abs(lu - np.log(V)) < 1e-5


# ---------------------------------------------------------------------------
# kernel vs oracle under hypothesis-driven shapes
# ---------------------------------------------------------------------------


@given(
    st.integers(0, 2**31 - 1),
    st.sampled_from([(1, 2, 1), (2, 4, 2), (1, 4, 4)]),  # B, H, K
    st.sampled_from([64, 128]),
)
@settings(max_examples=8, deadline=None)
def test_chunked_attention_matches_oracle(seed, bhk, S):
    B_, H, K = bhk
    rng = jax.random.PRNGKey(seed)
    q = jax.random.normal(rng, (B_, H, S, 32))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B_, K, S, 32))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B_, K, S, 32))
    want = ref.attention(q, k, v, causal=True)
    got = ops.attention(q, k, v, causal=True, impl="chunked", bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=1e-4)
