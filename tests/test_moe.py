"""MoE dispatch correctness: the sort/gather pipeline must equal a naive
per-token dense evaluation of the routed experts when capacity is ample,
and must drop (not corrupt) tokens when capacity binds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, LayerSpec, MoECfg
from repro.models import moe as M


def _cfg(E=6, K=2, shared=0, cf=8.0):
    return ArchConfig(
        name="moe-test",
        family="moe",
        source="test",
        n_layers=1,
        d_model=32,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab=64,
        pattern=(LayerSpec("attn", "moe"),),
        moe=MoECfg(n_experts=E, top_k=K, d_expert=48, n_shared=shared,
                   capacity_factor=cf),
    )


def _naive_moe(cfg, mcfg, p, x):
    """Dense per-token reference: every token through its top-k experts."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    logits = xf @ p["router"].astype(xf.dtype)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate, eidx = jax.lax.top_k(probs, mcfg.top_k)
    gate = gate / jnp.sum(gate, -1, keepdims=True)
    # all experts on all tokens, then select
    h = jnp.einsum("td,edf->tef", xf, p["w_gate"])
    u = jnp.einsum("td,edf->tef", xf, p["w_up"])
    y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["w_down"])
    sel = jnp.take_along_axis(y_all, eidx[..., None], 1)  # [T, K, D]
    y = jnp.sum(sel * gate[..., None].astype(x.dtype), 1)
    if "shared" in p:
        from repro.models import layers as L

        y = y + L.apply_mlp(cfg, p["shared"], xf)
    return y.reshape(B, S, D)


@pytest.mark.parametrize("E,K,shared", [
    (6, 2, 0),
    pytest.param(4, 1, 0, marks=pytest.mark.slow),
    pytest.param(6, 3, 2, marks=pytest.mark.slow),
])
def test_moe_matches_dense_reference(E, K, shared):
    cfg = _cfg(E, K, shared)
    p = M.init_moe(cfg, cfg.moe, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = M.apply_moe(cfg, cfg.moe, p, x)
    want = _naive_moe(cfg, cfg.moe, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=2e-5, rtol=1e-4)
    assert float(aux) > 0


@pytest.mark.slow
def test_moe_capacity_drops_but_never_corrupts():
    """With capacity_factor << 1 some tokens are dropped; the surviving
    outputs must be a subset of the ample-capacity outputs (per token,
    either equal-or-partial, never garbage)."""
    cfg_lo = _cfg(E=4, K=1, cf=0.3)
    cfg_hi = _cfg(E=4, K=1, cf=8.0)
    p = M.init_moe(cfg_lo, cfg_lo.moe, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg_lo.d_model))
    y_lo, _ = M.apply_moe(cfg_lo, cfg_lo.moe, p, x)
    y_hi, _ = M.apply_moe(cfg_hi, cfg_hi.moe, p, x)
    lo, hi = np.asarray(y_lo)[0], np.asarray(y_hi)[0]
    for t in range(64):
        full = np.allclose(lo[t], hi[t], atol=2e-5, rtol=1e-4)
        dropped = np.allclose(lo[t], 0.0, atol=1e-6)
        assert full or dropped, f"token {t} corrupted by capacity dropping"
    assert any(np.allclose(lo[t], 0.0, atol=1e-6) for t in range(64)), \
        "expected at least one dropped token at cf=0.3"


@pytest.mark.slow
def test_padded_experts_never_selected():
    """E=60-style padding: padded expert slots receive zero tokens."""
    assert M.padded_experts(60) == 64
    assert M.padded_experts(16) == 16
    assert M.padded_experts(4) == 4
    cfg = _cfg(E=20, K=2)  # pads to 32
    assert M.padded_experts(20) == 32
    p = M.init_moe(cfg, cfg.moe, jax.random.PRNGKey(0))
    assert p["router"].shape[1] == 32
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = M.apply_moe(cfg, cfg.moe, p, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    # routing never picks experts >= 20
    xf = x.reshape(-1, cfg.d_model)
    logits = jnp.einsum("td,de->te", xf, p["router"].astype(xf.dtype)
                        ).astype(jnp.float32)
    logits = logits - 1e30 * (jnp.arange(32) >= 20)
    _, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.moe.top_k)
    assert int(jnp.max(eidx)) < 20
