"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) and the
chunked-jnp path, asserted allclose against the ref.py pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _qkv(rng, B, H, K, Sq, Skv, hd, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (B, H, Sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, K, Skv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, K, Skv, hd), jnp.float32).astype(dtype)
    return q, k, v


ATTN_SHAPES = [
    # (B, H, K, Sq, Skv, hd, bq, bk)
    (1, 1, 1, 128, 128, 64, 64, 64),
    pytest.param((2, 4, 2, 256, 256, 64, 64, 128), marks=pytest.mark.slow),
    (1, 8, 8, 128, 128, 128, 128, 64),
    (2, 6, 2, 192, 192, 32, 64, 64),  # non-pow2 heads, GQA g=3
]


@pytest.mark.parametrize("impl", ["pallas", "chunked"])
@pytest.mark.parametrize("shape", ATTN_SHAPES)
# bf16 doubles the sweep for a dtype-cast-only code path: slow job only
@pytest.mark.parametrize("dtype", [
    jnp.float32, pytest.param(jnp.bfloat16, marks=pytest.mark.slow),
])
def test_attention_causal(impl, shape, dtype):
    B, H, K, Sq, Skv, hd, bq, bk = shape
    q, k, v = _qkv(jax.random.PRNGKey(0), B, H, K, Sq, Skv, hd, dtype)
    want = ref.attention(q, k, v, causal=True)
    got = ops.attention(q, k, v, causal=True, impl=impl, bq=bq, bk=bk)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("impl", ["pallas", "chunked"])
@pytest.mark.parametrize("window", [
    pytest.param(16, marks=pytest.mark.slow),
    64,
    pytest.param(100, marks=pytest.mark.slow),  # non-multiple of bk
])
def test_attention_sliding_window(impl, window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 4, 2, 256, 256, 64, jnp.float32)
    want = ref.attention(q, k, v, causal=True, window=window)
    got = ops.attention(q, k, v, causal=True, window=window, impl=impl, bq=64, bk=64)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_attention_noncausal():
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 4, 4, 128, 192, 64, jnp.float32)
    # kv longer than q (cross-attention shape), non-causal
    want = ref.attention(q, k, v, causal=False)
    got = ops.attention(q, k, v, causal=False, impl="chunked", bq=64, bk=64)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_attention_q_offset_matches_suffix():
    """Chunked attention with q_offset == decode-style suffix of full attn."""
    B, H, K, S, hd = 1, 2, 2, 128, 32
    q, k, v = _qkv(jax.random.PRNGKey(3), B, H, K, S, S, hd, jnp.float32)
    full = ref.attention(q, k, v, causal=True)
    tail = ops.attention(
        q[:, :, -16:], k, v, causal=True, q_offset=S - 16, impl="chunked",
        bq=16, bk=64,
    )
    np.testing.assert_allclose(tail, full[:, :, -16:], atol=2e-5, rtol=1e-4)


def test_chunked_attention_grad_finite():
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 2, 1, 128, 128, 32, jnp.float32)

    def f(q, k, v):
        return jnp.sum(ops.attention(q, k, v, impl="chunked", bq=64, bk=64) ** 2)

    gq, gk, gv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in (gq, gk, gv))
    # grads should also match the naive path's grads
    gq2, gk2, gv2 = jax.grad(
        lambda q, k, v: jnp.sum(ref.attention(q, k, v) ** 2), argnums=(0, 1, 2)
    )(q, k, v)
    np.testing.assert_allclose(gq, gq2, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(gk, gk2, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(gv, gv2, atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# effective movement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 4096, 100_001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_effective_movement_kernel(n, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    pn = jax.random.normal(k1, (n,), jnp.float32).astype(dtype)
    po = jax.random.normal(k2, (n,), jnp.float32).astype(dtype)
    net = jax.random.normal(k3, (n,), jnp.float32)
    want = ref.effective_movement_update(pn, po, net)
    got = ops.effective_movement_update(pn, po, net, impl="pallas")
    np.testing.assert_allclose(got[0], want[0], atol=1e-3, rtol=1e-5)
    np.testing.assert_allclose(got[1], want[1], atol=max(1e-2, 1e-6 * n), rtol=1e-4)
    np.testing.assert_allclose(got[2], want[2], atol=max(1e-2, 1e-6 * n), rtol=1e-4)


# ---------------------------------------------------------------------------
# fedavg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,n", [(2, 64), (5, 4096), (20, 65_537)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_kernel(K, n, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    p = jax.random.normal(k1, (K, n), jnp.float32).astype(dtype)
    w = jax.nn.softmax(jax.random.normal(k2, (K,)))
    want = ref.fedavg(p, w)
    got = ops.fedavg(p, w, impl="pallas")
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), atol=tol, rtol=tol
    )


# ---------------------------------------------------------------------------
# fedavg_masked (grouped heterogeneous cohorts)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,n", [(2, 64), (5, 4096), (7, 65_537)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_masked_kernel(K, n, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    p = jax.random.normal(k1, (K, n), jnp.float32).astype(dtype)
    w = jnp.arange(1.0, K + 1.0) ** 2  # raw, strongly uneven, unnormalized
    m = (jax.random.uniform(k2, (K, n)) > 0.3).astype(jnp.float32)
    prev = jax.random.normal(k3, (n,), jnp.float32).astype(dtype)
    want = ref.fedavg_masked(p, w, m, prev)
    got = ops.fedavg_masked(p, w, m, prev, impl="pallas")
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("K,n,bt", [(1, 97, 64), (3, 130, 64), (4, 64, 256)])
def test_fedavg_masked_kernel_nonaligned(K, n, bt):
    from repro.kernels import fedavg as _fedavg

    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    p = jax.random.normal(k1, (K, n))
    w = jnp.arange(1.0, K + 1.0)
    m = (jax.random.uniform(k2, (K, n)) > 0.4).astype(jnp.float32)
    m = m.at[:, 5].set(0.0)  # a column nobody covers
    prev = jnp.full((n,), 7.5)
    want = ref.fedavg_masked(p, w, m, prev)
    got = _fedavg.fedavg_masked(p, w, m, prev, bt=bt, interpret=True)
    assert got.shape == (n,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # zero-denominator columns pass the server's previous value through
    assert float(got[5]) == 7.5
    # full mask + K=1 degenerates to the identity regardless of the weight
    if K == 1:
        np.testing.assert_allclose(
            np.asarray(_fedavg.fedavg_masked(
                p, jnp.full((1,), 3.0), jnp.ones((1, n)), prev,
                bt=bt, interpret=True,
            )),
            np.asarray(p[0]), atol=1e-6,
        )


def test_fedavg_masked_full_mask_matches_fedavg():
    """With every client covering every column, masked num/den equals the
    plain weighted fedavg of the normalized weights."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(6))
    p = jax.random.normal(k1, (5, 200))
    w = jax.nn.softmax(jax.random.normal(k2, (5,)))
    want = ref.fedavg(p, w)
    got = ref.fedavg_masked(p, 13.0 * w, jnp.ones_like(p))  # scale cancels
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_fedavg_masked_prev_none_defaults_to_zero():
    p = jnp.ones((2, 8))
    got = ref.fedavg_masked(p, jnp.ones((2,)), jnp.zeros((2, 8)))
    np.testing.assert_array_equal(np.asarray(got), np.zeros(8))
    got_k = ops.fedavg_masked(
        p, jnp.ones((2,)), jnp.zeros((2, 8)), impl="pallas"
    )
    np.testing.assert_array_equal(np.asarray(got_k), np.zeros(8))


# ---------------------------------------------------------------------------
# fedavg_grouped (group-compressed masked aggregation)
# ---------------------------------------------------------------------------


def _grouped_world(key, K, n, G, dtype=jnp.float32):
    """Random grouped cohort honoring the kernel contract: clients split
    into G groups, each group owns a random column set, and the panel is
    zero outside its group's columns.  Returns the compact inputs plus the
    expanded per-client mask for the fedavg_masked cross-check."""
    k1, k2, k3 = jax.random.split(key, 3)
    gid = np.sort(np.arange(K) % G)  # group of each client row
    gmask = (jax.random.uniform(k2, (G, n)) > 0.3).astype(jnp.float32)
    mask = gmask[gid]  # [K, n] rows repeat within each group
    p = jax.random.normal(k1, (K, n), jnp.float32) * mask
    p = p.astype(dtype)
    w = jnp.arange(1.0, K + 1.0) ** 2  # raw, strongly uneven, unnormalized
    wsum = jnp.asarray(np.bincount(gid, np.asarray(w), minlength=G))
    prev = jax.random.normal(k3, (n,), jnp.float32).astype(dtype)
    return p, w, gmask, wsum, mask, prev


@pytest.mark.parametrize("K,n,G", [(4, 64, 2), (9, 4096, 3), (7, 65_537, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_grouped_kernel(K, n, G, dtype):
    p, w, gmask, wsum, mask, prev = _grouped_world(
        jax.random.PRNGKey(7), K, n, G, dtype
    )
    want = ref.fedavg_grouped(p, w, gmask, wsum, prev)
    got = ops.fedavg_grouped(p, w, gmask, wsum, prev, impl="pallas")
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), atol=tol, rtol=tol
    )
    # the compact formulation == the dense per-client mask formulation
    dense = ref.fedavg_masked(p, w, mask, prev)
    np.testing.assert_allclose(
        got.astype(np.float32), dense.astype(np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("K,n,G,bt", [(1, 97, 1, 64), (5, 130, 2, 64),
                                      (6, 64, 3, 256)])
def test_fedavg_grouped_kernel_nonaligned(K, n, G, bt):
    from repro.kernels import fedavg as _fedavg

    p, w, gmask, wsum, mask, prev = _grouped_world(
        jax.random.PRNGKey(8), K, n, G
    )
    gmask = gmask.at[:, 5].set(0.0)  # a column no group covers
    mask = mask.at[:, 5].set(0.0)
    p = p * mask
    prev = prev.at[5].set(7.5)
    want = ref.fedavg_masked(p, w, mask, prev)
    got = _fedavg.fedavg_grouped(p, w, gmask, wsum, prev, bt=bt,
                                 interpret=True)
    assert got.shape == (n,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # zero-denominator columns pass the server's previous value through
    assert float(got[5]) == 7.5


def test_fedavg_grouped_g1_identity():
    """G=1 with a full group mask and K=1 degenerates to the identity
    regardless of the (nonzero) weight scale."""
    from repro.kernels import fedavg as _fedavg

    p = jax.random.normal(jax.random.PRNGKey(9), (1, 97))
    got = _fedavg.fedavg_grouped(
        p, jnp.full((1,), 3.0), jnp.ones((1, 97)), jnp.full((1,), 3.0),
        jnp.zeros((97,)), bt=64, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(p[0]), atol=1e-6)
    # G=1 full coverage == plain normalized fedavg for K>1 too
    K = 4
    p = jax.random.normal(jax.random.PRNGKey(10), (K, 130))
    w = jnp.arange(1.0, K + 1.0)
    want = ref.fedavg(p, w / jnp.sum(w))
    got = ops.fedavg_grouped(
        p, w, jnp.ones((1, 130)), jnp.sum(w)[None], impl="pallas"
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_fedavg_grouped_zero_weight_group():
    """A group whose weight sum is zero contributes nothing; columns only it
    covers fall back to prev via the zero-denominator passthrough."""
    n = 40
    rng = jax.random.PRNGKey(11)
    gmask = jnp.zeros((2, n)).at[0, :30].set(1.0).at[1, 20:].set(1.0)
    # group 1 (clients 2..3) has zero weights -> columns 30: are only its own
    w = jnp.asarray([1.0, 2.0, 0.0, 0.0])
    mask = gmask[jnp.asarray([0, 0, 1, 1])]
    p = jax.random.normal(rng, (4, n)) * mask
    wsum = jnp.asarray([3.0, 0.0])
    prev = jnp.full((n,), -2.5)
    want = ref.fedavg_masked(p, w, mask, prev)
    got = ops.fedavg_grouped(p, w, gmask, wsum, prev, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got[30:]), np.full((10,), -2.5))


def test_fedavg_grouped_prev_none_defaults_to_zero():
    p = jnp.zeros((2, 8))
    got = ref.fedavg_grouped(
        p, jnp.ones((2,)), jnp.zeros((1, 8)), jnp.asarray([2.0])
    )
    np.testing.assert_array_equal(np.asarray(got), np.zeros(8))
    got_k = ops.fedavg_grouped(
        p, jnp.ones((2,)), jnp.zeros((1, 8)), jnp.asarray([2.0]),
        impl="pallas",
    )
    np.testing.assert_array_equal(np.asarray(got_k), np.zeros(8))


# ---------------------------------------------------------------------------
# packed-panel edge cases for the cohort engine: K=1 cohorts and parameter
# counts that do NOT divide the kernel tile (exercises the pad/slice path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,n,bt", [(1, 97, 64), (3, 130, 64), (4, 64, 256)])
def test_fedavg_kernel_nonaligned(K, n, bt):
    from repro.kernels import fedavg as _fedavg

    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    p = jax.random.normal(k1, (K, n))
    w = jax.nn.softmax(jax.random.normal(k2, (K,)))
    want = ref.fedavg(p, w)
    got = _fedavg.fedavg(p, w, bt=bt, interpret=True)
    assert got.shape == (n,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # K=1, weight 1 -> exact identity
    if K == 1:
        np.testing.assert_allclose(
            np.asarray(_fedavg.fedavg(p, jnp.ones((1,)), bt=bt, interpret=True)),
            np.asarray(p[0]), atol=1e-6,
        )


@pytest.mark.parametrize("n,bt", [(101, 64), (1, 64), (130, 128)])
def test_effective_movement_kernel_nonaligned(n, bt):
    from repro.kernels import effective_movement as _em

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    pn = jax.random.normal(k1, (n,))
    po = jax.random.normal(k2, (n,))
    net = jax.random.normal(k3, (n,))
    want = ref.effective_movement_update(pn, po, net)
    got = _em.effective_movement_update(pn, po, net, bt=bt, interpret=True)
    assert got[0].shape == (n,)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), atol=1e-5)
    # padding must not leak into the scalar reductions
    np.testing.assert_allclose(float(got[1]), float(want[1]), rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(float(got[2]), float(want[2]), rtol=1e-6, atol=1e-5)
