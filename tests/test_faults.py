"""Fault-injection unit suite (ISSUE 8) + the fault-adjacent regressions.

The conformance-matrix side of the fault axis (bit-equality of fault-free
plans, dropped/corrupt/straggler equivalences, round contracts under
injection, AGG_STATS twins, composed-mesh case) lives in
tests/test_contract.py.  Here:

* :mod:`repro.fl.faults` unit behavior — verdict validation, plan
  splitting, seeded sampling determinism, the injection hook;
* the memory-model fault twins (``fault_counts`` / ``fault_staging_bytes``
  / the ``staging_bytes`` peak term);
* int8 error-feedback residuals SURVIVE checkpoint save/restore
  (``ef_state_to_tree`` / ``ef_state_from_tree`` round-trip restores the
  next round bit-for-bit) and RESET when a FrozenColumns epoch changes the
  column space;
* ``engine.clear_caches`` actually empties the kernels' sharded-call
  caches (the ``ops.clear_shard_caches`` wiring);
* seeded cohort-sampling determinism for ``fl/data.py`` across two fresh
  subprocesses (same seed ⇒ identical partitions and client batches).
"""
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import engine as ENG
from repro.fl import faults as FLT
from repro.fl import memory_model as MM
from repro.kernels import ops as OPS
from repro.train import checkpoint as CKPT


# ---------------------------------------------------------------------------
# a compact 2-group world (width slice + full structure)
# ---------------------------------------------------------------------------


def _small_loss(f):
    def loss_fn(tr, fro, bn, xb, yb):
        h = xb[:, :f] @ tr["w"] + tr["b"]
        return jnp.mean((h.sum(-1) - yb) ** 2), bn

    return loss_fn


_LOSSES = {f: _small_loss(f) for f in (3, 6)}


def build_small_world():
    d, out = 6, 2
    rng = jax.random.PRNGKey(0)
    gtr = {"w": jax.random.normal(rng, (d, out)), "b": jnp.zeros((out,))}
    plans = []
    for gi, (f, kg) in enumerate([(3, 2), (6, 3)]):
        sub = {"w": gtr["w"][:f], "b": gtr["b"]}
        xs = jax.random.normal(jax.random.fold_in(rng, gi), (kg, 8, d))
        ys = jax.random.normal(jax.random.fold_in(rng, 10 + gi), (kg, 8))
        rngs = jax.random.split(jax.random.fold_in(rng, 20 + gi), kg)
        w = jnp.arange(1.0, kg + 1.0)
        plans.append(ENG.GroupPlan(
            _LOSSES[f], sub, {}, {}, xs, ys, rngs, w, 0.1, 2, 4
        ))
    return plans, gtr, {}


@pytest.fixture(scope="module")
def small_world():
    return build_small_world()


# ---------------------------------------------------------------------------
# verdicts and plans
# ---------------------------------------------------------------------------


def test_client_fault_validation():
    assert FLT.OK.kind == "ok"
    FLT.ClientFault("dropped")
    FLT.ClientFault("straggler", delay=3)
    FLT.ClientFault("corrupt", mode="nan")
    with pytest.raises(ValueError):
        FLT.ClientFault("lost")
    with pytest.raises(ValueError):
        FLT.ClientFault("straggler", delay=0)
    with pytest.raises(ValueError):
        FLT.ClientFault("ok", delay=1)
    with pytest.raises(ValueError):
        FLT.ClientFault("corrupt", mode="zeros")
    with pytest.raises(ValueError):
        FLT.ClientFault("dropped", mode="nan")


def test_fault_plan_counts_and_split():
    plan = FLT.FaultPlan(verdicts=(
        FLT.OK, FLT.ClientFault("dropped"),
        FLT.ClientFault("straggler", delay=2),
        FLT.ClientFault("corrupt", mode="inf"), FLT.OK,
    ))
    assert plan.k_total == 5 and plan.any_faults
    assert plan.counts() == {"ok": 2, "dropped": 1, "straggler": 1,
                             "corrupt": 1}
    groups = plan.for_cohort([2, 3])
    assert [len(g) for g in groups] == [2, 3]
    assert groups[0] == plan.verdicts[:2]
    assert groups[1] == plan.verdicts[2:]
    with pytest.raises(ValueError):
        plan.for_cohort([2, 2])
    ok = FLT.all_ok(4)
    assert not ok.any_faults and ok.k_total == 4
    assert ok.counts()["ok"] == 4
    with pytest.raises(ValueError):
        FLT.FaultPlan(verdicts=(FLT.OK,), norm_bound=0.0)
    with pytest.raises(ValueError):
        FLT.FaultPlan(verdicts=(FLT.OK,), beta=0.0)
    with pytest.raises(ValueError):
        FLT.FaultPlan(verdicts=(FLT.OK,), max_staged=-1)
    with pytest.raises(TypeError):
        FLT.FaultPlan(verdicts=("dropped",))


def test_sample_fault_plan_deterministic():
    cfg = FLT.FaultConfig(seed=7, p_drop=0.2, p_straggle=0.2, p_corrupt=0.2,
                          max_delay=3)
    a = FLT.sample_fault_plan(cfg, 64, round_idx=5)
    b = FLT.sample_fault_plan(cfg, 64, round_idx=5)
    assert a == b  # pure function of (seed, round)
    c = FLT.sample_fault_plan(cfg, 64, round_idx=6)
    assert a != c  # rounds draw independent verdicts
    d = FLT.sample_fault_plan(
        FLT.FaultConfig(seed=8, p_drop=0.2, p_straggle=0.2, p_corrupt=0.2,
                        max_delay=3), 64, round_idx=5)
    assert a != d
    # the knobs ride along onto the sampled plan
    cfg2 = FLT.FaultConfig(seed=1, norm_bound=5.0, beta=0.9, max_staged=3)
    p = FLT.sample_fault_plan(cfg2, 4, 1)
    assert (p.norm_bound, p.beta, p.max_staged) == (5.0, 0.9, 3)
    assert not p.any_faults  # all probabilities zero
    with pytest.raises(ValueError):
        FLT.FaultConfig(p_drop=0.9, p_corrupt=0.2)
    with pytest.raises(ValueError):
        FLT.FaultConfig(max_delay=0)
    with pytest.raises(ValueError):
        FLT.FaultConfig(corrupt_modes=("nan", "flip"))


def test_sample_fault_plan_hits_every_kind():
    cfg = FLT.FaultConfig(seed=3, p_drop=0.25, p_straggle=0.25,
                          p_corrupt=0.25, max_delay=2)
    plan = FLT.sample_fault_plan(cfg, 256, 1)
    c = plan.counts()
    assert all(c[k] > 0 for k in FLT.KINDS), c
    assert all(1 <= v.delay <= 2 for v in plan.verdicts
               if v.kind == "straggler")
    assert all(v.mode in FLT.CORRUPT_MODES for v in plan.verdicts
               if v.kind == "corrupt")


def test_inject_panel_modes():
    panel = jnp.ones((3, 4))
    assert FLT.inject_panel(panel, 1, FLT.OK) is panel
    nanp = FLT.inject_panel(panel, 1, FLT.ClientFault("corrupt", mode="nan"))
    assert bool(jnp.all(jnp.isnan(nanp[1]))) and bool(
        jnp.all(jnp.isfinite(nanp[0]))
    )
    infp = FLT.inject_panel(panel, 2, FLT.ClientFault("corrupt", mode="inf"))
    assert bool(jnp.all(jnp.isinf(infp[2])))
    big = FLT.inject_panel(
        jnp.zeros((2, 3)), 0, FLT.ClientFault("corrupt", mode="norm_blowup")
    )
    # additive: exact-zero entries are perturbed too, and the row stays
    # finite (only a norm bound catches it, not the finite check)
    assert bool(jnp.all(big[0] == FLT.NORM_BLOWUP_ADD))
    assert bool(jnp.all(jnp.isfinite(big)))
    assert bool(jnp.all(big[1] == 0.0))


# ---------------------------------------------------------------------------
# memory-model twins
# ---------------------------------------------------------------------------


def test_memory_model_fault_twins():
    plan = FLT.FaultPlan(verdicts=(
        FLT.OK, FLT.ClientFault("dropped"),
        FLT.ClientFault("straggler", delay=1), FLT.OK,
    ))
    assert MM.fault_counts([v.kind for v in plan.verdicts]) == plan.counts()
    with pytest.raises(ValueError):
        MM.fault_counts(["ok", "lost"])
    assert MM.fault_staging_bytes([]) == 0
    assert MM.fault_staging_bytes([10, 3]) == 4 * 13
    base = MM.server_aggregation_peak_bytes(8, 100, 2)
    with_staging = MM.server_aggregation_peak_bytes(
        8, 100, 2, staging_bytes=MM.fault_staging_bytes([100, 100])
    )
    assert with_staging == base + 800


def test_agg_stats_staging_bytes_twin(small_world):
    """The engine's measured staging occupancy equals the analytic twin
    computed from the parked row widths."""
    plans, gtr, gbn = small_world
    eng = ENG.make_engine("packed")
    verdicts = [FLT.OK] * 5
    verdicts[1] = FLT.ClientFault("straggler", delay=2)
    verdicts[3] = FLT.ClientFault("straggler", delay=2)
    eng.grouped_round(plans, gtr, gbn,
                      faults=FLT.FaultPlan(verdicts=tuple(verdicts)))
    st = dict(ENG.AGG_STATS)
    widths = [int(e.vals.shape[0]) for e in eng._staging]
    assert st["fault_staged_rows"] == 2
    assert st["fault_staging_bytes"] == MM.fault_staging_bytes(widths) > 0


# ---------------------------------------------------------------------------
# int8 error feedback: checkpoint round-trip + frozen-epoch reset
# ---------------------------------------------------------------------------


def test_ef_state_checkpoint_roundtrip(small_world, tmp_path):
    """EF residuals survive save/restore: an engine restored from the
    checkpoint continues the quantized trajectory BIT-FOR-BIT, where a
    fresh engine (no residuals) demonstrably diverges."""
    plans, gtr, gbn = small_world
    eng_a = ENG.make_engine("packed", stream_dtype="int8")
    eng_a.grouped_round(plans, gtr, gbn)
    assert eng_a._ef_state
    path = str(tmp_path / "ef.npz")
    CKPT.save(path, ENG.ef_state_to_tree(eng_a))

    eng_b = ENG.make_engine("packed", stream_dtype="int8")
    ENG.ef_state_from_tree(eng_b, CKPT.load(path))
    assert set(eng_b._ef_state) == set(eng_a._ef_state)
    assert eng_b._ef_epoch == eng_a._ef_epoch is None

    r2a = eng_a.grouped_round(plans, gtr, gbn)
    r2b = eng_b.grouped_round(plans, gtr, gbn)
    np.testing.assert_array_equal(np.asarray(r2a.packed),
                                  np.asarray(r2b.packed))
    # power check: without the restore the second round differs
    r2c = ENG.make_engine("packed", stream_dtype="int8").grouped_round(
        plans, gtr, gbn
    )
    assert not np.array_equal(np.asarray(r2a.packed), np.asarray(r2c.packed))


def test_ef_epoch_roundtrips_through_checkpoint(small_world, tmp_path):
    """The FrozenColumns epoch tag rides the checkpoint: without it the
    restored residuals would be wiped by the next round's epoch check."""
    plans, gtr, gbn = small_world
    mask = np.zeros(ENG.make_pack_spec(gtr).n, bool)
    mask[:2] = True
    fro = ENG.make_frozen_columns(mask)
    eng = ENG.make_engine("packed", stream_dtype="int8")
    eng.grouped_round(plans, gtr, gbn, frozen=fro)
    assert eng._ef_epoch == (fro.n, fro.digest)
    tree = ENG.ef_state_to_tree(eng)
    assert tree["__ef_epoch__"].shape == (2,)
    path = str(tmp_path / "ef_frozen.npz")
    CKPT.save(path, tree)
    eng_b = ENG.make_engine("packed", stream_dtype="int8")
    ENG.ef_state_from_tree(eng_b, CKPT.load(path))
    assert eng_b._ef_epoch == eng._ef_epoch
    r2a = eng.grouped_round(plans, gtr, gbn, frozen=fro)
    r2b = eng_b.grouped_round(plans, gtr, gbn, frozen=fro)
    np.testing.assert_array_equal(np.asarray(r2a.packed),
                                  np.asarray(r2b.packed))


def test_ef_state_resets_on_frozen_epoch_change(small_world):
    """A FrozenColumns epoch change re-keys the packed column space, so
    stale residuals must NOT leak across it: the first round after the
    change matches a residual-free engine bit-for-bit."""
    plans, gtr, gbn = small_world
    mask = np.zeros(ENG.make_pack_spec(gtr).n, bool)
    mask[:2] = True
    fro = ENG.make_frozen_columns(mask)

    eng = ENG.make_engine("packed", stream_dtype="int8")
    eng.grouped_round(plans, gtr, gbn)  # unfrozen epoch seeds residuals
    assert eng._ef_state and eng._ef_epoch is None
    got = eng.grouped_round(plans, gtr, gbn, frozen=fro)  # epoch change
    assert eng._ef_epoch == (fro.n, fro.digest)
    want = ENG.make_engine("packed", stream_dtype="int8").grouped_round(
        plans, gtr, gbn, frozen=fro
    )
    np.testing.assert_array_equal(np.asarray(want.packed),
                                  np.asarray(got.packed))
    # and back: dropping the frozen epoch clears the residuals again
    got_back = eng.grouped_round(plans, gtr, gbn)
    first = ENG.make_engine("packed", stream_dtype="int8").grouped_round(
        plans, gtr, gbn
    )
    np.testing.assert_array_equal(np.asarray(first.packed),
                                  np.asarray(got_back.packed))


# ---------------------------------------------------------------------------
# clear_caches wiring: the sharded-call caches actually empty
# ---------------------------------------------------------------------------


def test_clear_caches_empties_shard_call_caches(small_world):
    """``engine.clear_caches`` must reach through to
    ``ops.clear_shard_caches``: after a sharded round both mesh-keyed call
    caches hold entries, after clearing they hold none (the conftest
    session hook relies on this to drop device buffers between runs)."""
    plans, gtr, gbn = small_world
    ENG.make_engine("packed").grouped_round(plans, gtr, gbn, agg="sharded")
    assert OPS._sharded_agg_call.cache_info().currsize > 0
    assert OPS._stream_scatter_call.cache_info().currsize > 0
    ENG.clear_caches()
    assert OPS._sharded_agg_call.cache_info().currsize == 0
    assert OPS._stream_scatter_call.cache_info().currsize == 0


# ---------------------------------------------------------------------------
# fl/data.py seeded cohort sampling: cross-process determinism
# ---------------------------------------------------------------------------

_DATA_DETERMINISM_SCRIPT = r"""
import hashlib
import jax
import numpy as np
from repro.fl import data as D

xtr, ytr, xte, yte = D.make_synthetic(
    jax.random.PRNGKey(7), n_classes=4, n_train=256, n_test=32, size=8
)
parts_iid = D.partition_iid(jax.random.PRNGKey(1), len(ytr), 8)
parts_dir = D.partition_dirichlet(jax.random.PRNGKey(2), ytr, 8,
                                  min_per_client=4)
rng = np.random.default_rng(3)
sel = rng.choice(8, 4, replace=False)  # the cohort draw (fl/baselines idiom)
batches = [D.client_batch(xtr, ytr, parts_dir[c], 16, rng) for c in sel]

h = hashlib.sha256()
for p in parts_iid + parts_dir:
    h.update(np.ascontiguousarray(p).tobytes())
h.update(np.ascontiguousarray(sel).tobytes())
for xb, yb in batches:
    h.update(np.ascontiguousarray(xb).tobytes())
    h.update(np.ascontiguousarray(yb).tobytes())
print("DATA_DIGEST", h.hexdigest())
"""


def test_data_cohort_sampling_deterministic_across_processes():
    """Same seeds ⇒ the identical partitions, cohort selection, and client
    batches in two FRESH interpreter processes — the property fault
    injection's (seed, round) reproducibility builds on."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_compile_cache")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    digests = []
    for _ in range(2):
        out = subprocess.run(
            [sys.executable, "-c", _DATA_DETERMINISM_SCRIPT],
            env=env, capture_output=True, text=True, timeout=300,
        )
        assert out.returncode == 0, out.stdout + out.stderr
        line = [l for l in out.stdout.splitlines()
                if l.startswith("DATA_DIGEST")]
        assert line, out.stdout
        digests.append(line[0].split()[1])
    assert digests[0] == digests[1]
