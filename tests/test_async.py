"""Async buffered-aggregation server lifecycle (ISSUE 9).

The conformance story (sync bit-equality across the engine matrix, round
contracts per publish flavor, AGG_STATS == memory-model twins) lives in
tests/test_contract.py's ASYNC axis; the algebraic properties
(arrival-order invariance, the ``β^s`` discount, FIFO eviction) in
tests/test_properties.py.  Here: everything stateful about the server
itself —

* the version counter and the bounded checkout table (old versions age out
  with a KeyError);
* the checkpoint round-trip through ``train/checkpoint.py``: a server
  stopped MID-STREAM with stale buffered rows and live int8 error-feedback
  residuals restores into a fresh process and publishes bit-identically to
  the never-stopped server, publish after publish;
* cache hygiene: materialized row panels are device buffers and must be
  RELEASED by ``engine.clear_caches()`` (weakref liveness, mirroring the
  layout-cache drop test) and lazily rebuilt to the same bits;
* constructor/submission validation and the ``AsyncConfig`` knob bounds;
* the seeded :class:`ArrivalSimulator` schedule (pure function of
  ``(seed, round)``, conservation of submissions);
* the ``FLConfig.async_agg`` wiring: the baselines and the ProFL loop under
  staleness-0 scheduling reproduce their sync runs exactly, and — the slow
  convergence smoke — a moderately-stale ``β < 1`` run on the non-IID CNN
  fixture lands within a documented tolerance of the sync FedAvg baseline.
"""
import gc
import weakref

import jax
import numpy as np
import pytest

from repro.core.effective_movement import EMConfig
from repro.fl import async_server as AS
from repro.fl import baselines as BL
from repro.fl import data as D
from repro.fl import engine as ENG
from repro.fl import faults as FLT
from repro.fl import memory_model as MM
from repro.fl.server import FLConfig, ProFLServer
from repro.models.cnn import CNNConfig
from repro.train import checkpoint as CK

from test_contract import _K_MIXED, _bit_equal_rounds, build_mixed_world


@pytest.fixture()
def mixed():
    plans, gtr, gbn = build_mixed_world()
    return plans, gtr, gbn


def _submit_cohort(srv, plans):
    for p in plans:
        srv.submit(p, srv.version)


# ---------------------------------------------------------------------------
# version counter + bounded checkout table
# ---------------------------------------------------------------------------


def test_version_counter_and_checkout_table(mixed):
    plans, gtr, gbn = mixed
    srv = AS.AsyncAggServer(ENG.make_engine("packed"), gtr, gbn,
                            publish_at=_K_MIXED, max_versions=2)
    assert srv.version == 0 and srv.publishes == 0
    v, tr, bn = srv.checkout()
    assert v == 0 and tr is gtr and bn is gbn
    assert srv.poll() == []  # empty buffer: the async steady state

    results = []
    for _ in range(3):
        _submit_cohort(srv, plans)
        results.append(srv.publish())
    assert srv.version == 3 and srv.publishes == 3
    assert srv.buffer_rows == 0 and not srv.ready()

    # the table retains exactly max_versions entries, newest last
    v, tr, bn = srv.checkout()
    assert v == 3 and tr is results[-1].trainable
    v2, tr2, _ = srv.checkout(2)
    assert v2 == 2 and tr2 is results[-2].trainable
    with pytest.raises(KeyError):
        srv.checkout(1)  # aged out of the bounded table
    with pytest.raises(KeyError):
        srv.checkout(0)

    st = ENG.AGG_STATS
    assert st["async_version"] == 3
    assert st["async_versions_retained"] == 2
    assert st["async_version_table_bytes"] == MM.async_version_table_bytes(
        2, srv._n
    )


# ---------------------------------------------------------------------------
# checkpoint round-trip: restore mid-stream -> identical publishes
# ---------------------------------------------------------------------------


def test_checkpoint_restore_midstream_bit_equal_publishes(mixed, tmp_path):
    """A server stopped with STALE rows in the buffer and live int8
    error-feedback residuals, restored through train/checkpoint.py into a
    fresh engine + server, publishes bit-identically to the never-stopped
    server — for the restored stale publish AND the publish after it (the
    EF residuals carry across too)."""
    plans, gtr, gbn = mixed
    path = str(tmp_path / "async.npz")

    eng_a = ENG.make_engine("packed")
    srv_a = AS.AsyncAggServer(eng_a, gtr, gbn, publish_at=_K_MIXED,
                              beta=0.5, stream_dtype="int8")
    _submit_cohort(srv_a, plans)
    srv_a.publish()  # v1; creates the int8 EF residual state
    assert eng_a._ef_state  # the stream really was quantized
    # two groups report in late, trained against v0 -> stale at s=1
    srv_a.submit(plans[0], 0)
    srv_a.submit(plans[1], 0)

    # one combined checkpoint: model + async buffer + EF residuals.  The
    # model component is saved as f32 (npz has no bf16) and cast back by
    # ``like=`` on load — exact for bf16 upcasts.
    CK.save(path, {
        "model": jax.tree.map(lambda l: np.asarray(l, np.float32),
                              (srv_a.trainable, srv_a.bn_state)),
        "async": AS.async_state_to_tree(srv_a),
        "ef": ENG.ef_state_to_tree(eng_a),
    })

    # the never-stopped server publishes twice more
    _submit_cohort(srv_a, plans)
    res_a1 = srv_a.publish()  # fresh cohort + the two stale parked rows
    _submit_cohort(srv_a, plans)
    res_a2 = srv_a.publish()  # fresh-only, EF residuals from the mixed round

    # fresh process: new engine, server rebuilt around the restored model
    flat = CK.load(path)
    tr_b, bn_b = CK.load(
        path, like={"model": (srv_a.trainable, srv_a.bn_state)}
    )["model"]
    eng_b = ENG.make_engine("packed")
    srv_b = AS.AsyncAggServer(eng_b, tr_b, bn_b, publish_at=_K_MIXED,
                              beta=0.5, stream_dtype="int8")
    AS.async_state_from_tree(srv_b, CK.subtree(flat, "async"))
    ENG.ef_state_from_tree(eng_b, CK.subtree(flat, "ef"))

    assert srv_b.version == 1 and srv_b.publishes == 1
    k01 = int(plans[0].xs.shape[0]) + int(plans[1].xs.shape[0])
    assert len(srv_b.buffer) == 2 and srv_b.buffer_rows == k01
    assert all(e.plan is None and e.version == 0 for e in srv_b.buffer)
    # the version table re-seeds with the restored model only
    with pytest.raises(KeyError):
        srv_b.checkout(0)
    assert srv_b.checkout()[0] == 1
    # the restored EF residual tree matches the saved one leaf-for-leaf
    for k, v in ENG.ef_state_to_tree(eng_b).items():
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(flat["ef/" + k]))

    _submit_cohort(srv_b, plans)
    res_b1 = srv_b.publish()
    _bit_equal_rounds(res_a1, res_b1)
    _submit_cohort(srv_b, plans)
    res_b2 = srv_b.publish()
    _bit_equal_rounds(res_a2, res_b2)
    for a, b in zip(jax.tree.leaves(srv_a.trainable),
                    jax.tree.leaves(srv_b.trainable)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# clear_caches drops materialized row device buffers
# ---------------------------------------------------------------------------


def test_clear_caches_drops_materialized_row_buffers(mixed):
    """Mirrors test_contract.py's layout-cache drop test: materialized
    row panels are DEVICE buffers cached on buffer entries; a cache clear
    must actually release them (weakref liveness, not just the attribute)
    and the entry must lazily re-materialize to the same bits."""
    plans, gtr, gbn = mixed
    srv = AS.AsyncAggServer(ENG.make_engine("packed"), gtr, gbn,
                            publish_at=_K_MIXED)
    e = srv.submit(plans[0], 0)
    vals, w, idx = srv._materialize(e)
    assert e.rows is not None
    before = np.asarray(vals, np.float32).copy()
    wr = weakref.ref(vals)
    del vals

    ENG.clear_caches()
    assert e.rows is None  # plan entries drop their cached panel
    gc.collect()
    assert wr() is None  # ... and the device buffer really was released

    vals2, w2, idx2 = srv._materialize(e)  # lazy rebuild, same bits
    np.testing.assert_array_equal(before, np.asarray(vals2, np.float32))
    np.testing.assert_array_equal(np.asarray(w), np.asarray(w2))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx2))

    # row-only submissions hold HOST arrays — a clear must NOT lose them
    # (there is no plan to re-run)
    r = srv.submit_rows(np.ones((1, srv._n), np.float32),
                        np.ones((1,), np.float32), 0)
    ENG.clear_caches()
    assert r.rows is not None


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_server_validation_errors(mixed):
    plans, gtr, gbn = mixed
    eng = ENG.make_engine("packed")
    with pytest.raises(ValueError):
        AS.AsyncAggServer(eng, gtr, gbn, publish_at=0)
    with pytest.raises(ValueError):
        AS.AsyncAggServer(eng, gtr, gbn, publish_at=2, beta=0.0)
    with pytest.raises(ValueError):
        AS.AsyncAggServer(eng, gtr, gbn, publish_at=2, beta=1.5)
    with pytest.raises(ValueError):
        AS.AsyncAggServer(eng, gtr, gbn, publish_at=4, max_buffer=3)
    with pytest.raises(ValueError):
        AS.AsyncAggServer(eng, gtr, gbn, publish_at=2, max_versions=0)

    srv = AS.AsyncAggServer(eng, gtr, gbn, publish_at=2)
    with pytest.raises(ValueError):
        srv.publish()  # empty buffer
    with pytest.raises(ValueError):
        srv.submit(plans[0], 1)  # the future is not a checkable version
    with pytest.raises(ValueError):
        srv.submit(plans[0], -1)
    with pytest.raises(ValueError):  # vals do not cover idx
        srv.submit_rows(np.ones((2, 3), np.float32),
                        np.ones((2,), np.float32), 0,
                        idx=np.arange(4))
    with pytest.raises(ValueError):  # weights must be [k]
        srv.submit_rows(np.ones((2, srv._n), np.float32),
                        np.ones((3,), np.float32), 0)


def test_publish_rejects_mismatched_fault_beta(mixed):
    """An explicitly faulted publish with stale rows in flight must carry
    the server's beta — one staleness price per publish."""
    plans, gtr, gbn = mixed
    srv = AS.AsyncAggServer(ENG.make_engine("packed"), gtr, gbn,
                            publish_at=_K_MIXED, beta=0.5)
    _submit_cohort(srv, plans)
    srv.publish()
    srv.submit(plans[0], 0)  # stale
    _submit_cohort(srv, plans)
    with pytest.raises(ValueError, match="beta"):
        srv.publish(faults=FLT.all_ok(_K_MIXED, beta=0.9))
    # matching beta goes through (fresh engine state for a clean publish)
    srv2 = AS.AsyncAggServer(ENG.make_engine("packed"), gtr, gbn,
                             publish_at=_K_MIXED, beta=0.5)
    _submit_cohort(srv2, plans)
    srv2.publish()
    srv2.submit(plans[0], 0)
    _submit_cohort(srv2, plans)
    res = srv2.publish(faults=FLT.all_ok(_K_MIXED, beta=0.5))
    assert np.isfinite(np.float32(res.loss))
    assert ENG.AGG_STATS["async_stale_rows"] == int(plans[0].xs.shape[0])


def test_async_config_validation():
    AS.AsyncConfig()  # defaults are valid
    with pytest.raises(ValueError):
        AS.AsyncConfig(publish_at=-1)
    with pytest.raises(ValueError):
        AS.AsyncConfig(beta=0.0)
    with pytest.raises(ValueError):
        AS.AsyncConfig(max_buffer=0)
    with pytest.raises(ValueError):
        AS.AsyncConfig(max_versions=0)
    with pytest.raises(ValueError):
        AS.AsyncConfig(p_slow=1.5)
    with pytest.raises(ValueError):
        AS.AsyncConfig(max_delay=0)


# ---------------------------------------------------------------------------
# arrival simulator
# ---------------------------------------------------------------------------


def test_arrival_simulator_deterministic_and_conserving():
    cfg = AS.AsyncConfig(seed=3, p_slow=0.5, max_delay=3)
    sims = [AS.ArrivalSimulator(cfg) for _ in range(2)]
    waves = [[f"r{r}c{i}" for i in range(5)] for r in range(4)]
    arrived = [[], []]
    for r, wave in enumerate(waves):
        for j, sim in enumerate(sims):
            arrived[j].append(sim.step(r, wave))
    # pure function of (seed, round sequence): identical schedules
    assert arrived[0] == arrived[1]
    assert sims[0].in_flight == sims[1].in_flight
    # drain: everything submitted eventually arrives, exactly once
    total = [x for wave_got in arrived[0] for x in wave_got]
    r = len(waves)
    while sims[0].in_flight:
        total += sims[0].step(r, [])
        r += 1
        assert r < len(waves) + cfg.max_delay + 1
    assert sorted(total) == sorted(x for w in waves for x in w)

    # p_slow=0: staleness-0 scheduling, same-round in-order arrival
    sim = AS.ArrivalSimulator(AS.AsyncConfig(p_slow=0.0))
    assert sim.step(0, ["a", "b"]) == ["a", "b"] and sim.in_flight == 0
    # p_slow=1: NOTHING arrives in its own round
    sim = AS.ArrivalSimulator(AS.AsyncConfig(p_slow=1.0, max_delay=2))
    assert sim.step(0, ["a", "b", "c"]) == [] and sim.in_flight == 3


# ---------------------------------------------------------------------------
# FLConfig wiring: staleness-0 async == the sync run, exactly
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_world():
    rng = jax.random.PRNGKey(0)
    xtr, ytr, xte, yte = D.make_synthetic(rng, n_train=600, n_test=200,
                                          size=16)
    parts = D.partition_iid(jax.random.PRNGKey(1), len(xtr), 40)
    budgets = MM.assign_budgets_mb(np.random.default_rng(0), 40)
    return xtr, ytr, xte, yte, parts, budgets


def _fl(**kw):
    base = dict(
        n_clients=40, clients_per_round=6, local_steps=3, batch_size=16,
        n_local_fixed=24, max_rounds_per_step=4, distill_rounds=1,
        eval_every=100,
        em=EMConfig(window_h=2, slope_phi=0.05, patience_w=2, fit_points=3,
                    em_level=0.95, min_rounds=2),
    )
    base.update(kw)
    return FLConfig(**base)


def test_heterofl_async_staleness0_matches_sync(tiny_world):
    """The wiring end of the sync-oracle contract: run_heterofl under
    ``async_agg`` with staleness-0 scheduling (p_slow=0, publish_at=cohort)
    is the sync run BIT-exactly — same curve, same final params."""
    xtr, ytr, xte, yte, parts, budgets = tiny_world
    cfg = CNNConfig("vgg11", width_mult=0.0625, in_size=16)
    fl_kw = dict(clients_per_round=6, local_steps=2, batch_size=8,
                 n_local_fixed=16)
    want = BL.run_heterofl(cfg, _fl(**fl_kw), xtr, ytr, xte, yte, parts,
                           budgets, 2)
    got = BL.run_heterofl(
        cfg, _fl(async_agg=AS.AsyncConfig(p_slow=0.0), **fl_kw),
        xtr, ytr, xte, yte, parts, budgets, 2,
    )
    assert got["curve"] == want["curve"]
    for a, b in zip(jax.tree.leaves((want["params"], want["bn"])),
                    jax.tree.leaves((got["params"], got["bn"]))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    st = ENG.AGG_STATS
    assert st["async_publishes"] == 2 and st["async_stale_rows"] == 0


@pytest.mark.slow
def test_profl_async_staleness0_matches_sync(tiny_world):
    """Full ProFL loop (growth stages, distillation, freezing) under
    staleness-0 async scheduling reproduces the sync run: identical round
    losses and final accuracy (the publish makes the verbatim
    grouped_round call; distillation stays sync by design)."""
    xtr, ytr, xte, yte, parts, budgets = tiny_world
    cfg = CNNConfig("vgg11", width_mult=0.125, in_size=16)
    a = ProFLServer(cfg, _fl(), xtr, ytr, xte, yte, parts, budgets).run()
    b = ProFLServer(
        cfg, _fl(async_agg=AS.AsyncConfig(p_slow=0.0)),
        xtr, ytr, xte, yte, parts, budgets,
    ).run()
    assert [(s["stage"], s["t"], s["rounds"]) for s in a["steps"]] == \
           [(s["stage"], s["t"], s["rounds"]) for s in b["steps"]]
    la = [h["loss"] for h in a["history"]]
    lb = [h["loss"] for h in b["history"]]
    np.testing.assert_array_equal(np.asarray(la, np.float32),
                                  np.asarray(lb, np.float32))
    assert a["final_acc"] == b["final_acc"]


@pytest.mark.slow
def test_async_convergence_smoke_non_iid(tiny_world):
    """The convergence end: moderate staleness (p_slow=0.4, delays up to 2
    rounds) with β=0.7 staleness discounting on the NON-IID CNN fixture,
    vs the sync FedAvg-style baseline (the grouped weighted average
    run_heterofl performs).  Delayed arrivals mean the async run publishes
    FEWER updates in the same number of rounds, so the documented
    tolerance is at MATCHED UPDATE COUNT: async accuracy after its P
    publishes within 0.15 of the sync run after P rounds — isolating the
    staleness discount's quality cost from the throughput deficit of
    waiting on stragglers (measured here: the publish-matched gap is
    ~0.01; the same-round gap is ~0.19 and is a scheduling artifact, not
    an aggregation-quality one)."""
    xtr, ytr, xte, yte, _, budgets = tiny_world
    parts = D.partition_dirichlet(jax.random.PRNGKey(0), ytr, 40, alpha=0.5)
    cfg = CNNConfig("vgg11", width_mult=0.0625, in_size=16)
    fl_kw = dict(clients_per_round=6, local_steps=2, batch_size=8,
                 n_local_fixed=16)
    rounds = 16
    sync = BL.run_heterofl(cfg, _fl(**fl_kw), xtr, ytr, xte, yte, parts,
                           budgets, rounds)
    asy = BL.run_heterofl(
        cfg,
        _fl(async_agg=AS.AsyncConfig(p_slow=0.4, max_delay=2, beta=0.7),
            **fl_kw),
        xtr, ytr, xte, yte, parts, budgets, rounds,
    )
    st = ENG.AGG_STATS
    publishes = st["async_publishes"]
    assert publishes >= rounds // 2  # the stream really flowed
    assert all(s >= 0 and rows > 0
               for s, rows in st["async_staleness_hist"].items())
    # matched update count, smoothed over 3 eval points (accuracy on the
    # 200-image test set is discrete in 0.005 steps and noisy round to
    # round): async's last 3 rounds vs sync's rounds publishes-2..publishes
    a_acc = float(np.mean(asy["curve"][-3:]))
    s_acc = float(np.mean(sync["curve"][max(0, publishes - 3):publishes]))
    assert abs(a_acc - s_acc) <= 0.15, (a_acc, s_acc, publishes)
    assert asy["curve"][-1] > 0.25  # and it genuinely learned (chance=0.1)


# ---------------------------------------------------------------------------
# step-boundary drops under growth (ISSUE 10 bugfix): counted, never silent
# ---------------------------------------------------------------------------


def _toy_plan(tr, k, seed=0):
    """A degenerate one-group plan over ``tr`` (the ProFL round shape)."""
    import jax.numpy as jnp

    d = int(tr["w"].shape[0])

    def loss(trn, fro, bn, xb, yb):
        return jnp.mean((xb @ trn["w"] - yb) ** 2), bn

    rng = jax.random.PRNGKey(seed)
    xs = jax.random.normal(rng, (k, 8, d))
    ys = jax.random.normal(jax.random.fold_in(rng, 1), (k, 8))
    rngs = jax.random.split(jax.random.fold_in(rng, 2), k)
    return ENG.GroupPlan(loss, tr, {}, {}, xs, ys, rngs,
                         jnp.arange(1.0, k + 1.0), 0.1, 1, 8)


def test_async_dropped_on_growth_counted(tiny_world):
    """A model-structure change under async aggregation drops the buffered
    and in-flight submissions (they trained against the dead pack spec).
    The drop used to vanish silently; now it lands in
    ``AGG_STATS["async_dropped_on_growth"]`` with the resident bytes
    pinned to the ``memory_model.async_buffer_bytes`` twin, and the
    cumulative counters survive later publishes (which clear AGG_STATS)."""
    import jax.numpy as jnp

    xtr, ytr, xte, yte, parts, budgets = tiny_world
    cfg = CNNConfig("vgg11", width_mult=0.0625, in_size=16)
    srv = ProFLServer(
        cfg, _fl(async_agg=AS.AsyncConfig(p_slow=0.0, publish_at=8)),
        xtr, ytr, xte, yte, parts, budgets,
    )
    tr1 = {"w": jnp.zeros((4,))}
    plan1 = _toy_plan(tr1, k=3)
    # two rounds buffer 6 rows — under the publish_at=8 threshold
    assert srv._async_grouped(plan1, tr1, None) is None
    assert srv._async_grouped(plan1, tr1, None) is None
    entries = [(e.k, e.n_cols) for e in srv._async_srv.buffer]
    want_rows = srv._async_srv.buffer_rows + sum(
        int(item[0].xs.shape[0]) for _, _, item in srv._async_sim._pending
    )
    want_bytes = srv._async_srv.buffer_bytes()
    assert want_rows == 6 and want_bytes == MM.async_buffer_bytes(entries)
    # growth: a wider trainable is a new pack spec — the server rebuilds
    # and the stranded submissions are dropped AND counted
    tr2 = {"w": jnp.zeros((6,))}
    plan2 = _toy_plan(tr2, k=3, seed=1)
    assert srv._async_grouped(plan2, tr2, None) is None
    assert srv.async_dropped_on_growth == want_rows
    assert srv.async_dropped_bytes_on_growth == want_bytes
    assert ENG.AGG_STATS["async_dropped_on_growth"] == want_rows
    assert ENG.AGG_STATS["async_dropped_bytes_on_growth"] == want_bytes
    # two more cohorts push the new buffer to 9 >= 8: the publish clears
    # AGG_STATS, but the cumulative drop counters must stay visible
    assert srv._async_grouped(plan2, tr2, None) is None
    res = srv._async_grouped(plan2, tr2, None)
    assert res is not None
    assert ENG.AGG_STATS["async_dropped_on_growth"] == want_rows
    assert ENG.AGG_STATS["async_dropped_bytes_on_growth"] == want_bytes
    assert srv.async_dropped_on_growth == want_rows
    # a second growth accumulates on top of the first
    res2 = srv._async_grouped(plan1, tr1, None)
    assert res2 is None
    assert srv.async_dropped_on_growth == want_rows  # buffer was empty
    assert srv._async_grouped(plan1, tr1, None) is None  # 6 rows buffered
    dropped2 = srv._async_srv.buffer_rows
    srv._async_grouped(plan2, tr2, None)
    assert srv.async_dropped_on_growth == want_rows + dropped2
