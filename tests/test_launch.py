"""Launch-layer tests on the single-device debug mesh: sharding env,
input_specs, lower+compile of train/prefill/decode for a reduced arch
(the 512-device production sweep runs via `python -m repro.launch.dryrun`)."""
import jax
import pytest

from repro.configs.base import INPUT_SHAPES, InputShape, get_config
from repro.launch import dryrun
from repro.launch.mesh import make_debug_mesh

TINY_TRAIN = InputShape("tiny_train", 64, 4, "train")
TINY_DECODE = InputShape("tiny_decode", 64, 4, "decode")


@pytest.mark.parametrize("arch", [
    "qwen3-8b",
    pytest.param("rwkv6-7b", marks=pytest.mark.slow),
    pytest.param("qwen2-moe-a2.7b", marks=pytest.mark.slow),
])
def test_lower_combo_debug_mesh(arch):
    cfg = get_config(arch).reduced()
    mesh = make_debug_mesh(1, 1)
    r = dryrun.lower_combo(cfg, TINY_TRAIN, mesh)
    assert r["flops"] > 0
    assert r["per_device"]["temp_bytes"] >= 0


def test_lower_decode_debug_mesh():
    cfg = get_config("qwen3-8b").reduced()
    mesh = make_debug_mesh(1, 1)
    r = dryrun.lower_combo(cfg, TINY_DECODE, mesh)
    assert r["per_device"]["argument_bytes"] > 0  # params + cache


@pytest.mark.slow  # the fast equivalent claim is test_system.py::test_progressive_state_is_smaller_than_full
def test_progressive_lower_debug_mesh():
    cfg = get_config("qwen1.5-0.5b").reduced().with_(n_prog_blocks=2)
    mesh = make_debug_mesh(1, 1)
    full = dryrun.lower_combo(cfg, TINY_TRAIN, mesh)
    prog = dryrun.lower_combo(cfg, TINY_TRAIN, mesh, progressive_t=1)
    # step-1 training carries less state (params+opt args) than full
    assert (prog["per_device"]["argument_bytes"]
            < full["per_device"]["argument_bytes"])


def test_input_specs_cover_all_archs():
    from repro.configs.base import list_configs

    for name in list_configs():
        cfg = get_config(name)
        for shape in INPUT_SHAPES.values():
            if (name, shape.name) in dryrun.SKIPS:
                continue
            spec = dryrun.input_specs(cfg, shape)
            assert isinstance(spec, dict) and spec
            for leaf in jax.tree.leaves(spec):
                assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")


def test_collective_parse_smoke():
    hlo = """
HloModule m
%body.1 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[8]{0} all-reduce(f32[8]{0} %x), replica_groups={}
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}
%cond.1 (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main () -> f32[8] {
  %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[16]{0} all-gather(f32[8]{0} %y), dimensions={0}
}
"""
    sizes = dryrun._collective_bytes(hlo)
    assert sizes["all-reduce"] == 8 * 4 * 12  # trip-count multiplied
    assert sizes["all-gather"] == 16 * 4
