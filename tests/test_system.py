"""End-to-end behaviour tests: full training loop improves, progressive
training memory claim at the optimizer level, serving pipeline, checkpoint
roundtrip, sharding rules."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import blocks as B
from repro.core import progressive as P
from repro.models import transformer as T
from repro.train import checkpoint as CKPT
from repro.train import serve
from repro.train.optimizer import AdamWCfg, adamw, sgd
from repro.train.train_step import init_train_state, make_train_step


def _toy_cfg():
    return get_config("qwen1.5-0.5b").reduced(d_model=128, vocab=64).with_(
        n_prog_blocks=2
    )


@pytest.mark.slow
def test_full_training_reduces_loss():
    cfg = _toy_cfg()
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw(AdamWCfg(lr=3e-3, warmup=5, weight_decay=0.0))
    state = init_train_state(cfg, params, opt)
    step = jax.jit(make_train_step(cfg, opt, remat=False))
    # memorize a fixed batch
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab)}
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_progressive_state_is_smaller_than_full():
    """The paper's memory claim at the optimizer level: step-t training
    carries moments ONLY for the active block + output module."""
    cfg = get_config("qwen3-8b").reduced().with_(n_prog_blocks=4)
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw(AdamWCfg())
    full_state = init_train_state(cfg, params, opt)
    full_bytes = sum(x.nbytes for x in jax.tree.leaves(full_state["opt"]))

    for t in range(1, B.n_blocks(cfg)):
        frozen, trainable = P.submodel_init(cfg, params, jax.random.PRNGKey(1), t)
        prog_opt = opt.init(trainable)
        prog_bytes = sum(x.nbytes for x in jax.tree.leaves(prog_opt))
        assert prog_bytes < 0.75 * full_bytes, (t, prog_bytes, full_bytes)


@pytest.mark.slow
def test_progressive_training_improves_submodel():
    cfg = _toy_cfg()
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    t = 1
    frozen, trainable = P.submodel_init(cfg, params, jax.random.PRNGKey(1), t)
    opt = sgd(lr=0.2)
    step = jax.jit(P.make_progressive_train_step(cfg, opt, t))
    state = {"params": trainable, "opt": opt.init(trainable),
             "step": jnp.zeros((), jnp.int32)}
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                          cfg.vocab)}
    losses = []
    for _ in range(25):
        state, m = step(state, frozen, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::8]


@pytest.mark.slow  # decode==forward consistency stays in tier-1 via test_smoke_archs
def test_serve_batched_generation():
    """prefill + N greedy decode steps produce a coherent batched rollout."""
    cfg = _toy_cfg()
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    Bz, S, N = 3, 12, 5
    toks = jax.random.randint(jax.random.PRNGKey(1), (Bz, S), 0, cfg.vocab)
    logits, cache, pos = serve.prefill(cfg, params, {"tokens": toks},
                                       cache_len=S + N)
    out = []
    cur = jnp.argmax(logits, -1)
    dstep = jax.jit(lambda c, t, p: serve.decode_step(cfg, params, c, t, p))
    for i in range(N):
        out.append(cur)
        logits, cache = dstep(cache, cur, jnp.int32(S + i))
        cur = jnp.argmax(logits, -1)
    gen = jnp.stack(out, 1)
    assert gen.shape == (Bz, N)
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab)))


def test_checkpoint_roundtrip():
    cfg = _toy_cfg()
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        CKPT.save(path, params)
        like = jax.tree.map(lambda x: jnp.zeros_like(x), params)
        restored = CKPT.load(path, like)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_param_sharding_rules_divide():
    """Every sharded dim produced by the rules divides the mesh axis size
    (sanitization invariant) for every full-size arch."""
    from repro.configs.base import list_configs
    from repro.launch import sharding

    # abstract mesh spec check: emulate 16x16 axis sizes without devices
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    env = sharding.AxisEnv(mesh=FakeMesh(), dp_axes=("data",), tp_axis="model")
    for name in list_configs():
        cfg = get_config(name)
        params = jax.eval_shape(
            lambda c=cfg: T.init_model(c, jax.random.PRNGKey(0)))
        specs = jax.tree_util.tree_map_with_path(
            lambda p, l: sharding.spec_for_path(env, p, l), params)
        for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))[0],
        ):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is not None:
                    n = sharding._axis_size(env, ax)
                    assert dim % n == 0, (name, path, leaf.shape, spec)
