import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)

# Persistent XLA compilation cache: repeat tier-1 runs skip the multi-second
# CPU compiles that dominate this suite (first/cold run is unaffected).
try:
    jax.config.update("jax_compilation_cache_dir", "/tmp/jax_compile_cache")
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
except Exception:  # older jaxlib without the persistent cache
    pass


def pytest_sessionfinish(session, exitstatus):
    # Drop the FL layer's module-level caches (pack specs, group layouts,
    # loss closures) so long sweeps / looped suites don't accumulate them.
    # Mid-session the caches are LRU-bounded (fl/engine.py::BoundedCache).
    from repro.fl.engine import clear_caches

    clear_caches()
