"""Deliverable (f): per-architecture smoke tests — a REDUCED variant of each
assigned family (≤2 pattern repeats, d_model≤512, ≤4 experts) runs one
forward and one train step on CPU; output shapes + no NaNs asserted.
The FULL configs are exercised only via launch/dryrun.py (no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, list_configs
from repro.models import transformer as T
from repro.train import serve
from repro.train.optimizer import AdamWCfg, adamw
from repro.train.train_step import init_train_state, make_train_step

# tier-1 smokes the two cheapest-to-compile archs (and only one train/decode
# compile between them); the full matrix (MoE, SSM, hybrid, encoder, vision —
# multi-minute XLA compiles on CPU) runs in the CI slow job via `pytest -m slow`
FAST_ARCHS = {"qwen1.5-0.5b", "qwen3-8b"}
HEAVY_TIER1 = {"qwen3-8b"}  # GQA + sliding window: the richer of the two


def _arch_params(heavy_set):
    return [
        a if a in heavy_set else pytest.param(a, marks=pytest.mark.slow)
        for a in list_configs()
    ]


ARCHS = _arch_params(FAST_ARCHS)
ARCHS_HEAVY = _arch_params(HEAVY_TIER1)


def make_batch(cfg, rng, B=2, S=16):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if cfg.frontend is not None:
        batch["frontend_embeds"] = jax.random.normal(
            rng, (B, cfg.frontend.n_tokens, cfg.frontend.embed_dim)
        )
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(rng, (B, cfg.encoder.n_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    rng = jax.random.PRNGKey(0)
    params = T.init_model(cfg, rng)
    batch = make_batch(cfg, rng)
    logits, aux, npre = T.forward(cfg, params, batch, remat=False)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S + npre, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS_HEAVY)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    rng = jax.random.PRNGKey(0)
    params = T.init_model(cfg, rng)
    opt = adamw(AdamWCfg(lr=1e-3, warmup=1))
    state = init_train_state(cfg, params, opt)
    step = make_train_step(cfg, opt, remat=False)
    batch = make_batch(cfg, rng)
    state, metrics = jax.jit(step)(state, batch)
    assert int(state["step"]) == 1
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state["params"], params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS_HEAVY)
def test_decode_matches_forward(arch):
    """prefill(S) + decode_step(S) == forward(S+1) at the last position."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:  # avoid capacity-drop nondeterminism between runs
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    rng = jax.random.PRNGKey(0)
    params = T.init_model(cfg, rng)
    B, S = 2, 9
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab)
    bf = dict(make_batch(cfg, rng, B, S + 1), tokens=toks)
    bp = dict(bf, tokens=toks[:, :S])
    logits_full, _, npre = T.forward(cfg, params, bf, remat=False)
    _, cache, _ = serve.prefill(cfg, params, bp, cache_len=npre + S + 1)
    lg, new_cache = serve.decode_step(
        cfg, params, cache, toks[:, S], jnp.int32(npre + S)
    )
    assert lg.shape == (B, cfg.vocab)
    err = float(jnp.max(jnp.abs(lg - logits_full[:, -1])))
    assert err < 5e-3, f"{arch}: decode/forward mismatch {err}"
    # cache structure preserved
    jax.tree.map(lambda a, b: None if a.shape == b.shape else 1 / 0,
                 cache, new_cache)


@pytest.mark.slow
def test_sliding_window_decode_long_context():
    """Rotating-window cache: decoding with a window-sized cache matches
    windowed full attention."""
    cfg = get_config("qwen3-8b").reduced().with_(sliding_window=8)
    rng = jax.random.PRNGKey(0)
    params = T.init_model(cfg, rng)
    B, S = 1, 24
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab)
    logits_full, _, _ = T.forward(cfg, params, {"tokens": toks}, remat=False)
    _, cache, _ = serve.prefill(cfg, params, {"tokens": toks[:, :S]},
                                cache_len=S)
    lg, _ = serve.decode_step(cfg, params, cache, toks[:, S], jnp.int32(S))
    err = float(jnp.max(jnp.abs(lg - logits_full[:, -1])))
    assert err < 5e-3, f"windowed decode mismatch {err}"
