"""Client-population registry + memory-budgeted cohort admission (ISSUE 10).

The registry/sampler contract (``fl/population.py``):

* :func:`build_population` is a pure function of the config seed — two
  fresh interpreter processes build the identical registry and draw the
  identical cohort (the subprocess digest test, mirroring the fault
  module's (seed, round) reproducibility test);
* :func:`sample_cohort` is a pure function of ``(seed, round_idx)``:
  replaying a round re-derives the identical admission decisions, and the
  two memory gates (device budget via
  ``memory_model.submodel_train_memory_mb``-built need vectors, server
  peak via ``memory_model.server_aggregation_peak_bytes``) hold on every
  admitted client;
* :class:`CohortSampler`'s cursor round-trips through
  ``train/checkpoint.py`` — a restored run continues the exact cohort
  sequence it would have drawn (algebraic monotonicity/quota properties
  live in tests/test_properties.py).

Also here: the unit tests for ``benchmarks/check_bench_record.py`` — the
declarative CI bench-artifact gate.  The spec must keep covering every
gated bench section, and a section or key dropping out of a record must
fail loud (the inline-Python predecessor only watched two sections).
"""
import importlib.util
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.fl import memory_model as MM
from repro.fl import population as POP
from repro.models.cnn import CNNConfig
from repro.train import checkpoint as CK

# small registry for unit tests: big enough for ~even strata, small enough
# to build in milliseconds (the 1M registry runs in the hierarchy bench)
_N = 20_000


@pytest.fixture(scope="module")
def pop():
    return POP.build_population(
        POP.PopulationConfig(n_clients=_N, n_groups=4, seed=7)
    )


@pytest.fixture(scope="module")
def need():
    # resnet34's tier ladder pokes above group 3's budget floor, so the
    # device gate genuinely rejects (same choice as the hierarchy bench)
    return POP.group_train_need_mb(CNNConfig("resnet34"), 4)


def test_registry_invariants(pop):
    cfg = pop.cfg
    assert pop.n_clients == _N
    assert pop.groups.dtype == np.int16
    assert pop.budgets_mb.shape == (_N,) and pop.weights.shape == (_N,)
    assert np.all(pop.weights >= 1.0)
    assert np.all((pop.budgets_mb >= cfg.budget_lo)
                  & (pop.budgets_mb <= cfg.budget_hi))
    # groups ARE the budget tiers: searchsorted against the thresholds
    want = np.searchsorted(pop.thresholds, pop.budgets_mb)
    np.testing.assert_array_equal(pop.groups, want)
    # strata partition the id space
    allids = np.sort(np.concatenate(pop.strata))
    np.testing.assert_array_equal(allids, np.arange(_N))
    for g, ids in enumerate(pop.strata):
        assert np.all(pop.groups[ids] == g)


def test_registry_deterministic_in_seed():
    cfg = POP.PopulationConfig(n_clients=3000, seed=11)
    a, b = POP.build_population(cfg), POP.build_population(cfg)
    np.testing.assert_array_equal(a.groups, b.groups)
    np.testing.assert_array_equal(a.budgets_mb, b.budgets_mb)
    np.testing.assert_array_equal(a.weights, b.weights)
    c = POP.build_population(POP.PopulationConfig(n_clients=3000, seed=12))
    assert not np.array_equal(a.budgets_mb, c.budgets_mb)


def test_registry_validation():
    with pytest.raises(ValueError):
        POP.build_population(POP.PopulationConfig(n_clients=0))
    with pytest.raises(ValueError):
        POP.build_population(POP.PopulationConfig(n_groups=0))


def test_sample_cohort_pure_in_seed_and_round(pop, need):
    a = POP.sample_cohort(pop, 5, cohort_size=64, need_mb=need)
    b = POP.sample_cohort(pop, 5, cohort_size=64, need_mb=need)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.groups, b.groups)
    np.testing.assert_array_equal(a.weights, b.weights)
    assert (a.considered, a.rejected_budget, a.rejected_server) == \
           (b.considered, b.rejected_budget, b.rejected_server)
    # a different round or a different seed is a different draw
    c = POP.sample_cohort(pop, 6, cohort_size=64, need_mb=need)
    assert not np.array_equal(a.ids, c.ids)
    d = POP.sample_cohort(pop, 5, cohort_size=64, need_mb=need, seed=99)
    assert not np.array_equal(a.ids, d.ids)


def test_sample_cohort_admission_gates_hold(pop, need):
    co = POP.sample_cohort(pop, 2, cohort_size=128, need_mb=need)
    assert co.k <= 128 and co.k > 0
    assert len(set(co.ids.tolist())) == co.k  # without replacement
    np.testing.assert_array_equal(co.groups, pop.groups[co.ids])
    np.testing.assert_array_equal(co.weights, pop.weights[co.ids])
    # the device gate: every admitted client affords its group's footprint
    assert np.all(pop.budgets_mb[co.ids] >= np.asarray(need)[co.groups])
    assert co.rejected_budget > 0  # resnet34's top tier genuinely rejects
    assert co.considered == co.k + co.rejected_budget
    assert co.rejected_server == 0  # no server budget configured


def test_sample_cohort_server_gate_caps_cohort(pop, need):
    n_cols = 4096
    full = POP.sample_cohort(pop, 2, cohort_size=128, need_mb=need)
    budget = int(MM.server_aggregation_peak_bytes(40, n_cols, 4))
    capped = POP.sample_cohort(
        pop, 2, cohort_size=128, need_mb=need,
        server_peak_budget_bytes=budget, n_cols=n_cols,
    )
    assert 0 < capped.k < full.k
    assert capped.rejected_server > 0
    assert MM.server_aggregation_peak_bytes(capped.k, n_cols, 4) <= budget
    # the admitted prefix is a SUBSET of the uncapped round's draw — the
    # gate truncates, it never reshuffles
    assert set(capped.ids.tolist()) <= set(full.ids.tolist())


def test_sample_cohort_validation(pop, need):
    with pytest.raises(ValueError):
        POP.sample_cohort(pop, 0, cohort_size=0, need_mb=need)
    with pytest.raises(ValueError):
        POP.sample_cohort(pop, 0, cohort_size=8, need_mb=[1.0, 2.0])
    with pytest.raises(ValueError):
        POP.sample_cohort(pop, 0, cohort_size=8, need_mb=need,
                          server_peak_budget_bytes=10**9)  # n_cols missing


def test_cohort_sampler_checkpoint_roundtrip(pop, need, tmp_path):
    """Stop mid-stream, save the cursor through train/checkpoint.py, restore
    into a FRESH sampler: the continued cohort sequence is bit-identical to
    never having stopped."""
    kw = dict(cohort_size=48, need_mb=need)
    ref = POP.CohortSampler(pop, **kw)
    want = [ref.next_cohort() for _ in range(5)]
    a = POP.CohortSampler(pop, **kw)
    for _ in range(2):
        a.next_cohort()
    path = str(tmp_path / "cursor.npz")
    CK.save(path, a.state_to_tree())
    b = POP.CohortSampler(pop, **kw)
    b.state_from_tree(CK.load(path))
    assert b.round == 2
    got = [b.next_cohort() for _ in range(3)]
    for w, g in zip(want[2:], got):
        assert w.round_idx == g.round_idx
        np.testing.assert_array_equal(w.ids, g.ids)
        np.testing.assert_array_equal(w.weights, g.weights)


_POP_DETERMINISM_SCRIPT = r"""
import hashlib
import numpy as np
from repro.fl import population as POP
from repro.models.cnn import CNNConfig

pop = POP.build_population(
    POP.PopulationConfig(n_clients=50_000, n_groups=4, seed=3)
)
need = POP.group_train_need_mb(CNNConfig("resnet34"), 4)
h = hashlib.sha256()
h.update(np.ascontiguousarray(pop.groups).tobytes())
h.update(np.ascontiguousarray(pop.budgets_mb).tobytes())
h.update(np.ascontiguousarray(pop.weights).tobytes())
for rnd in (0, 1, 7):
    co = POP.sample_cohort(pop, rnd, cohort_size=96, need_mb=need)
    h.update(np.ascontiguousarray(co.ids).tobytes())
    h.update(np.ascontiguousarray(co.groups).tobytes())
    h.update(np.asarray([co.considered, co.rejected_budget,
                         co.rejected_server], np.int64).tobytes())
print("POP_DIGEST", h.hexdigest())
"""


def test_population_deterministic_across_processes():
    """Same seeds ⇒ the identical registry AND cohort stream in two FRESH
    interpreter processes — the reproducibility the resumable cursor and
    the bench's admission-replay gate build on."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_compile_cache")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    digests = []
    for _ in range(2):
        out = subprocess.run(
            [sys.executable, "-c", _POP_DETERMINISM_SCRIPT],
            env=env, capture_output=True, text=True, timeout=300,
        )
        assert out.returncode == 0, out.stdout + out.stderr
        line = [l for l in out.stdout.splitlines()
                if l.startswith("POP_DIGEST")]
        assert line, out.stdout
        digests.append(line[0].split()[1])
    assert digests[0] == digests[1]


# ---------------------------------------------------------------------------
# benchmarks/check_bench_record.py: the declarative CI bench-artifact gate
# ---------------------------------------------------------------------------


def _load_checker():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "check_bench_record.py")
    spec = importlib.util.spec_from_file_location("check_bench_record", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _full_record(chk):
    """A minimal record satisfying every REQUIRED_SECTIONS entry."""
    rec = {}
    for section, keys in chk.REQUIRED_SECTIONS.items():
        sec = {}
        for path in keys:
            cur = sec
            parts = path.split(".")
            for p in parts[:-1]:
                cur = cur.setdefault(p, {})
            cur[parts[-1]] = 1
        rec[section] = sec
    return rec


def test_check_bench_record_spec_covers_gated_sections():
    """Every gated bench section is registered — adding a gated section to
    bench_kernels.py without declaring it here must fail THIS test, so the
    CI gate can never silently lag the bench."""
    chk = _load_checker()
    assert set(chk.REQUIRED_SECTIONS) == {
        "transport", "async", "faults", "freeze_decay", "hierarchy"
    }
    # the hierarchy entry pins the admission counts and both edge tiers
    hier = chk.REQUIRED_SECTIONS["hierarchy"]
    assert "admission.rejected_budget" in hier
    assert "edges.4.hier_server_peak_bytes" in hier
    assert "edges.8.hier_server_peak_bytes" in hier


def test_check_bench_record_passes_complete_record():
    chk = _load_checker()
    assert chk.check_record(_full_record(chk)) == []


def test_check_bench_record_fails_missing_section_and_key():
    chk = _load_checker()
    rec = _full_record(chk)
    del rec["faults"]
    del rec["transport"]["int8_over_f32_wire"]
    rec["async"]["buffer_peak_bytes"] = None  # present but null: still fails
    problems = chk.check_record(rec)
    assert any("'faults' missing" in p for p in problems)
    assert any("int8_over_f32_wire" in p for p in problems)
    assert any("buffer_peak_bytes" in p for p in problems)
    assert len(problems) == 3


def test_check_bench_record_cli_exit_codes(tmp_path):
    chk = _load_checker()
    good = tmp_path / "good.json"
    good.write_text(json.dumps(_full_record(chk)))
    bad = tmp_path / "bad.json"
    rec = _full_record(chk)
    del rec["hierarchy"]
    bad.write_text(json.dumps(rec))
    garbled = tmp_path / "garbled.json"
    garbled.write_text("{not json")
    assert chk.main(["check", str(good)]) == 0
    assert chk.main(["check", str(bad)]) == 1
    assert chk.main(["check", str(tmp_path / "absent.json")]) == 1
    assert chk.main(["check", str(garbled)]) == 1
    assert chk.main(["check"]) == 2


def test_check_bench_record_accepts_committed_seed():
    """The committed BENCH_kernels.json seed must satisfy the spec — the
    artifact CI gates against is the shape the repo actually records."""
    chk = _load_checker()
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_kernels.json")
    with open(path) as f:
        rec = json.load(f)
    assert chk.check_record(rec) == []
