"""Engine-contract conformance suite.

ONE parametrized matrix asserts that every engine mode × grouped impl ×
aggregation placement combination produces the same grouped-round result as
the vmap/serial oracle (atol 1e-5) on three shared fixtures:

* ``mixed``       — synthetic multi-structure cohort with bf16 + f32 leaves,
                    a HeteroFL-style width slice, a DepthFL-style block
                    prefix, and a full-structure group (fast: the whole
                    matrix runs in tier-1);
* ``cnn``         — a real reduced-width VGG forward (full group + a
                    leading-corner-sliced group);
* ``transformer`` — a real reduced transformer progressive loss (full group
                    + width-sliced group).

This replaces the per-pair equivalence tests that used to accumulate (and
drift) in tests/test_engine.py: a new engine impl or agg mode gets covered
by adding one axis value here, not N new tests.  Heavy fixture combos are
marked ``slow``; a small allowlist keeps representative cells in tier-1.

Also here: the column-sharded aggregation contracts — exactly one logical
dispatch (with per-shard launch accounting), exactly one host sync per
round, tile-aligned column shard geometry, the server aggregation memory
model regression (per-device panel bytes ≈ K_total·n/D, transient stream
bytes ≈ max_g K_g·n_g/D + tile padding, both pinned against the measured
``AGG_STATS`` metadata), and the 8-virtual-device subprocess case
exercising the composed ``clients × model`` mesh (sharded local SGD +
column-sharded aggregation + shard-local group-panel streaming in one
round, bit-equal to the replicated path, with n not divisible by the shard
count and a wide-group case where the stream slice is strictly smaller
than the full group panel).

The TRANSPORT axis (ISSUE 7) extends the conformance idea to the wire:
``stream_dtype="f32"`` (any ``inflight``) must be BIT-equal to the default
round on both aggregation placements, the quantized wire dtypes
(``"bf16"``/``"int8"``) must stay within their documented tolerance of the
f32 oracle on every fixture, and the engine's measured transport telemetry
(``AGG_STATS``'s ``wire_bytes`` / ``wire_bytes_uniform`` / per-device byte
fields) must equal ``memory_model``'s analytic twins exactly — including on
the composed mesh, where a DepthFL-style concentrated group pins the
ragged-vs-uniform saving and the quantized panel's never-f32 residency.

The FROZEN-column axis (ISSUE 6) re-runs the conformance idea against a
freezing-aware layout: ``grouped_round(frozen=...)`` must be identical to
simply not updating the frozen columns (bit-equal passthrough, live
columns vs the unfrozen oracle), keep every round contract over the
shrunken panel, and make the measured per-device panel/stream figures
decay by the frozen fraction exactly as the memory model's
``n_frozen`` term predicts — including on the composed mesh.

The FAULTS axis (ISSUE 8) stresses the same matrix with adversity: a
fault-free :class:`fl.faults.FaultPlan` must be BIT-equal to ``faults=None``
in every cell, dropped clients must match the zero-weight oracle bit-exactly
(whole dropped groups falling back to the zero-denominator→prev
passthrough), injected NaN/Inf/norm-blowup rows must leave the global
params finite and within matrix tolerance of the without-that-client
oracle (the in-kernel quarantine gate), stragglers must park and later
merge at the staleness-discounted weight ``w·beta**s`` identically on the
fused and serial paths, the one-dispatch/one-sync round contracts must
hold UNDER injection, and ``AGG_STATS``'s fault telemetry must equal the
``fl/memory_model.py`` twins exactly — including on the composed mesh.

The ASYNC axis (ISSUE 9) re-proves every round contract with the control
flow inverted: ``fl/async_server.py::AsyncAggServer`` at staleness-0
scheduling with ``publish_at == cohort size`` must reproduce
``grouped_round`` BIT-exactly in every matrix cell (the sync round is a
special case of the async server, not a parallel code path — including
frozen, faulted, and int8-stream cells), every publish — fresh, mixed
fresh+stale, and stale-only — must stay one logical ``fedavg_grouped``
dispatch + one ``block_until_ready``, stale publishes keep replicated ≡
sharded bit-equality, the ``async_*`` telemetry must equal the
``fl/memory_model.py`` buffer/version-table/staleness twins exactly, and
the composed mesh runs the same equivalence + stale-publish contracts in
the 8-virtual-device subprocess.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import progressive as P
from repro.fl import async_server as AS
from repro.fl import engine as ENG
from repro.fl import faults as FLT
from repro.fl import memory_model as MM
from repro.kernels import ops as OPS
from repro.kernels.fedavg import AGG_TILE
from repro.models import cnn as C
from repro.train.train_step import softmax_xent

MODES = ("vmap", "packed", "sharded")
IMPLS = ("serial", "fused", "fused_masked")
AGGS = ("replicated", "sharded")
FIXTURES = ("mixed", "cnn", "transformer")

# tier-1 allowlist per heavy fixture; None = the full matrix stays tier-1.
# Everything outside the allowlist still runs — in the slow job.
TIER1 = {
    "mixed": None,
    "cnn": {
        ("packed", "fused", "replicated"),
        ("packed", "fused", "sharded"),
        ("sharded", "fused", "sharded"),
    },
    "transformer": {("packed", "fused", "sharded")},
}


def _tree_close(a, b, atol=1e-5):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        tol = atol
        if getattr(x, "dtype", None) == jnp.bfloat16:
            # the f32 aggregates agree at 1e-5 (pinned via .packed below);
            # bf16 STORAGE can still flip one ulp when an f32 reduction-order
            # delta crosses a round-to-nearest-even boundary — allow one ulp
            # at the leaf's magnitude on low-precision leaves only
            tol = max(atol, float(np.max(np.abs(np.asarray(x, np.float32))))
                      / 128.0)
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), atol=tol
        )


def _grouped_close(want, got, atol=1e-5):
    _tree_close(want.trainable, got.trainable, atol=atol)
    _tree_close(want.bn_state, got.bn_state, atol=atol)
    np.testing.assert_allclose(float(want.loss), float(got.loss), atol=atol)


# ---------------------------------------------------------------------------
# shared fixtures: (plans, global_trainable, global_bn, oracle result)
# ---------------------------------------------------------------------------


def _mixed_loss(f: int, dep: int):
    def loss_fn(tr, fro, bn, xb, yb):
        h = xb[:, :f] @ tr["w"].astype(jnp.float32) + tr["b"]
        for i in range(dep):
            h = jnp.tanh(h @ tr["blocks"][i])
        mu = bn["mu"] * 0.9 + 0.1 * jnp.mean(h)
        return jnp.mean((h.sum(-1) - yb) ** 2), {"mu": mu}

    return loss_fn


_MIXED_LOSSES = {
    (f, dep): _mixed_loss(f, dep) for f, dep in [(4, 1), (6, 2), (8, 2)]
}


def build_mixed_world():
    """Width slice + depth prefix + full structure over a mixed-dtype global
    tree (bf16 ``w``, f32 everything else), strongly uneven weights."""
    d, out = 8, 3
    rng = jax.random.PRNGKey(0)
    gtr = {
        "w": jax.random.normal(rng, (d, out)).astype(jnp.bfloat16),
        "b": jnp.zeros((out,)),
        "blocks": [
            jax.random.normal(jax.random.fold_in(rng, 9 + i), (out, out))
            for i in range(2)
        ],
    }
    gbn = {"mu": jnp.zeros(())}
    plans = []
    for gi, (f, dep, kg) in enumerate([(4, 1, 2), (6, 2, 3), (8, 2, 2)]):
        sub = {
            "w": gtr["w"][:f],
            "b": gtr["b"],
            "blocks": gtr["blocks"][:dep],
        }
        xs = jax.random.normal(jax.random.fold_in(rng, gi), (kg, 10, d))
        ys = jax.random.normal(jax.random.fold_in(rng, 100 + gi), (kg, 10))
        rngs = jax.random.split(jax.random.fold_in(rng, 200 + gi), kg)
        w = jnp.arange(1.0, kg + 1.0) * (gi + 0.5)
        plans.append(ENG.GroupPlan(
            _MIXED_LOSSES[(f, dep)], sub, {}, gbn, xs, ys, rngs, w, 0.1, 3, 4
        ))
    return plans, gtr, gbn


@pytest.fixture(scope="module")
def mixed_world():
    plans, gtr, gbn = build_mixed_world()
    want = ENG.make_engine("vmap").grouped_round(plans, gtr, gbn)
    return plans, gtr, gbn, want


def _reg_loss(tr, fro, bn, xb, yb):
    reg = sum(
        jnp.sum(l.astype(jnp.float32) ** 2) for l in jax.tree.leaves(tr)
    )
    return reg / 100.0, bn


def _half_leaf(l):
    return l[: max(1, l.shape[0] // 2)] if l.ndim > 0 else l


@pytest.fixture(scope="module")
def cnn_world():
    """Real reduced-width VGG group + a leading-corner-sliced group (the
    slice group trains an L2 objective — layout coverage, not semantics)."""
    cfg = C.CNNConfig("vgg11", width_mult=0.0625, in_size=16)
    params, bn = C.init_cnn(cfg, jax.random.PRNGKey(0))

    def loss_fn(trainable, frozen, bn_state, xb, yb):
        logits, new_bn = C.forward_cnn(cfg, trainable, bn_state, xb,
                                       train=True)
        return softmax_xent(logits, yb), new_bn

    K, n_local = 2, 8
    rng = jax.random.PRNGKey(1)
    xs = jax.random.normal(rng, (K, n_local, 16, 16, 3))
    ys = jax.random.randint(jax.random.fold_in(rng, 1), (K, n_local), 0, 10)
    rngs = jax.random.split(jax.random.PRNGKey(2), K)
    sub = jax.tree.map(_half_leaf, params)
    xs2 = jax.random.normal(jax.random.fold_in(rng, 2), (K, n_local, 16, 16, 3))
    rngs2 = jax.random.split(jax.random.PRNGKey(3), K)
    plans = [
        ENG.GroupPlan(loss_fn, params, {}, bn, xs, ys, rngs,
                      jnp.asarray([3.0, 1.0]), 0.05, 2, 4),
        ENG.GroupPlan(_reg_loss, sub, {}, {}, xs2, ys, rngs2,
                      jnp.asarray([2.0, 0.5]), 0.05, 2, 4),
    ]
    want = ENG.make_engine("vmap").grouped_round(plans, params, bn)
    return plans, params, bn, want


@pytest.fixture(scope="module")
def transformer_world():
    """Real reduced transformer progressive loss (full group) + a width
    slice of every leaf under an L2 objective (scatter coverage on a
    many-leaf tree)."""
    from repro.configs.base import get_config
    from repro.models import transformer as T

    cfg = get_config("qwen1.5-0.5b").reduced(d_model=64, vocab=32).with_(
        n_prog_blocks=2
    )
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    t = 1
    frozen, trainable = P.submodel_init(cfg, params, jax.random.PRNGKey(1), t)
    prog_loss = P.make_progressive_loss(cfg, t)

    def loss_fn(trainable, frozen, bn_state, xb, yb):
        loss, _ = prog_loss(trainable, frozen, {"tokens": xb})
        return loss, bn_state

    K, n_local, S = 2, 6, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (K, n_local, S), 0,
                              cfg.vocab)
    ys = jnp.zeros((K, n_local), jnp.int32)
    rngs = jax.random.split(jax.random.PRNGKey(3), K)
    sub = jax.tree.map(_half_leaf, trainable)
    toks2 = jax.random.randint(jax.random.PRNGKey(4), (K, n_local, S), 0,
                               cfg.vocab)
    rngs2 = jax.random.split(jax.random.PRNGKey(5), K)
    plans = [
        ENG.GroupPlan(loss_fn, trainable, frozen, {}, toks, ys, rngs,
                      jnp.asarray([1.0, 4.0]), 0.05, 2, 2),
        ENG.GroupPlan(_reg_loss, sub, frozen, {}, toks2, ys, rngs2,
                      jnp.asarray([2.0, 3.0]), 0.05, 2, 2),
    ]
    want = ENG.make_engine("vmap").grouped_round(plans, trainable, {})
    return plans, trainable, {}, want


# ---------------------------------------------------------------------------
# THE matrix: every mode × impl × agg combination vs the vmap oracle
# ---------------------------------------------------------------------------


def _matrix():
    for fixture in FIXTURES:
        fast = TIER1[fixture]
        for mode in MODES:
            for impl in IMPLS:
                for agg in AGGS:
                    marks = ()
                    if fast is not None and (mode, impl, agg) not in fast:
                        marks = (pytest.mark.slow,)
                    yield pytest.param(
                        fixture, mode, impl, agg, marks=marks,
                        id=f"{fixture}-{mode}-{impl}-{agg}",
                    )


@pytest.mark.parametrize("fixture,mode,impl,agg", list(_matrix()))
def test_engine_contract(fixture, mode, impl, agg, request):
    plans, gtr, gbn, want = request.getfixturevalue(fixture + "_world")
    got = ENG.make_engine(mode).grouped_round(
        plans, gtr, gbn, impl=impl, agg=agg
    )
    _grouped_close(want, got)
    if impl != "serial":
        # fused paths also return the packed flat aggregate; it must be
        # exactly the pack of the returned tree (the EM fast path reads it)
        assert got.packed is not None
        np.testing.assert_array_equal(
            np.asarray(got.packed),
            np.asarray(ENG.make_pack_spec(gtr).pack(got.trainable)),
        )


def test_sharded_agg_bit_equal_to_replicated(mixed_world):
    """The per-column ratio has no cross-column coupling, so the column
    split must be EXACT — not just 1e-5-close — to the replicated path."""
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed")
    got_r = eng.grouped_round(plans, gtr, gbn, agg="replicated")
    got_s = eng.grouped_round(plans, gtr, gbn, agg="sharded")
    for a, b in zip(jax.tree.leaves(got_r.trainable),
                    jax.tree.leaves(got_s.trainable)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# frozen-column layouts: the freeze-at-round-r conformance axis
# ---------------------------------------------------------------------------

# the leaf frozen in the mixed fixture (both blocks[1] trainable columns;
# no bn leaf matches, so the epoch is trainable-only here)
_FROZEN_PREFIX = "['blocks'][1]"

# tier-1 allowlist for the frozen axis; everything else runs in the slow job
FROZEN_TIER1 = {
    ("vmap", "serial", "replicated"),
    ("packed", "serial", "replicated"),
    ("packed", "fused", "replicated"),
    ("packed", "fused", "sharded"),
    ("packed", "fused_masked", "replicated"),
    ("sharded", "fused", "sharded"),
}


@pytest.fixture(scope="module")
def mixed_frozen(mixed_world):
    plans, gtr, gbn, want = mixed_world
    fro = ENG.frozen_columns_for_paths(gtr, gbn, [_FROZEN_PREFIX])
    assert fro is not None and 0 < fro.n_frozen < fro.n
    return plans, gtr, gbn, want, fro


def _frozen_matrix():
    for mode in MODES:
        for impl in IMPLS:
            for agg in AGGS:
                marks = ()
                if (mode, impl, agg) not in FROZEN_TIER1:
                    marks = (pytest.mark.slow,)
                yield pytest.param(mode, impl, agg, marks=marks,
                                   id=f"{mode}-{impl}-{agg}")


@pytest.mark.parametrize("mode,impl,agg", list(_frozen_matrix()))
def test_frozen_contract(mode, impl, agg, mixed_frozen):
    """Freezing columns must be IDENTICAL to simply not updating them: the
    frozen leaf passes through BIT-equal to the round's input, live leaves
    match the unfrozen vmap oracle, and the packed fast-path vector still
    re-packs the returned tree exactly."""
    plans, gtr, gbn, want, fro = mixed_frozen
    got = ENG.make_engine(mode).grouped_round(
        plans, gtr, gbn, impl=impl, agg=agg, frozen=fro
    )
    np.testing.assert_array_equal(
        np.asarray(got.trainable["blocks"][1]), np.asarray(gtr["blocks"][1])
    )
    oracle = {
        "w": want.trainable["w"],
        "b": want.trainable["b"],
        "blocks": [want.trainable["blocks"][0], gtr["blocks"][1]],
    }
    _tree_close(oracle, got.trainable)
    _tree_close(want.bn_state, got.bn_state)
    np.testing.assert_allclose(float(want.loss), float(got.loss), atol=1e-5)
    if impl != "serial":
        assert got.packed is not None
        np.testing.assert_array_equal(
            np.asarray(got.packed),
            np.asarray(ENG.make_pack_spec(gtr).pack(got.trainable)),
        )


def test_frozen_bit_equal_replicated_vs_sharded(mixed_frozen):
    """The frozen epoch preserves the exactness contract: column-sharded
    aggregation over the SHRUNKEN panel is bit-equal to replicated."""
    plans, gtr, gbn, _, fro = mixed_frozen
    eng = ENG.make_engine("packed")
    got_r = eng.grouped_round(plans, gtr, gbn, agg="replicated", frozen=fro)
    got_s = eng.grouped_round(plans, gtr, gbn, agg="sharded", frozen=fro)
    for a, b in zip(jax.tree.leaves(got_r.trainable),
                    jax.tree.leaves(got_s.trainable)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_frozen_round_contracts_hold(mixed_frozen):
    """The round contracts survive a freeze transition: still exactly one
    logical ``fedavg_grouped`` dispatch and one ``block_until_ready`` with
    the compressed panel."""
    plans, gtr, gbn, _, fro = mixed_frozen
    eng = ENG.make_engine("packed")
    eng.grouped_round(plans, gtr, gbn, agg="sharded", frozen=fro)  # warm
    real = jax.block_until_ready
    calls = []

    def counting(x):
        calls.append(1)
        return real(x)

    OPS.reset_dispatches()
    jax.block_until_ready = counting
    try:
        ENG.reset_syncs()
        eng.grouped_round(plans, gtr, gbn, agg="sharded", frozen=fro)
    finally:
        jax.block_until_ready = real
    assert OPS.DISPATCHES["fedavg_grouped"] == 1
    assert len(calls) == 1, f"expected 1 host sync, saw {len(calls)}"
    assert ENG.SYNCS["aggregation_barrier"] == 1
    ENG.reset_syncs()
    OPS.reset_dispatches()


def test_frozen_agg_stats_decay_and_match_model(mixed_frozen):
    """After the freeze event the measured per-device panel and stream
    figures still equal the analytic model WITH its frozen-fraction term,
    and they decay versus the unfrozen round wherever the model says they
    must (replicated always; sharded up to tile padding)."""
    plans, gtr, gbn, _, fro = mixed_frozen
    eng = ENG.make_engine("packed")
    layout = ENG.make_group_layout(plans, gtr, gbn, frozen=fro)
    g_n = [int(ix.size) for ix in layout.idx]
    g_f = [int(np.sum(d >= layout.n_active)) for d in layout.dst]
    for agg in AGGS:
        eng.grouped_round(plans, gtr, gbn, agg=agg)
        st0 = dict(ENG.AGG_STATS)
        eng.grouped_round(plans, gtr, gbn, agg=agg, frozen=fro)
        st1 = dict(ENG.AGG_STATS)
        assert st1["n_frozen"] == fro.n_frozen
        assert st1["n_active"] == fro.n_active
        D = st1["n_shards"]
        panel_model = st1["k_total"] * MM.agg_columns_per_device(
            layout.n, n_devices=D, agg=agg, n_frozen=fro.n_frozen
        )
        stream_model = max(
            MM.agg_stream_elems_per_device(k, n_g, n_devices=D, agg=agg,
                                           n_frozen=f)
            for k, n_g, f in zip(layout.ks, g_n, g_f)
        )
        assert st1["per_device_panel_elems"] == panel_model
        assert st1["per_device_stream_elems"] == stream_model
        # decay exactly when the model (tile padding included) decays; the
        # replicated figures have no padding, so they must strictly drop
        panel_model0 = st0["k_total"] * MM.agg_columns_per_device(
            layout.n, n_devices=D, agg=agg
        )
        assert (st1["per_device_panel_elems"] < st0["per_device_panel_elems"]) \
            == (panel_model < panel_model0)
        if agg == "replicated":
            assert st1["per_device_panel_elems"] < st0["per_device_panel_elems"]
            assert st1["per_device_stream_elems"] < st0["per_device_stream_elems"]


def test_em_tracking_keeps_single_host_sync(mixed_world):
    """EM bookkeeping riding a fused round (the server's fast path feeds
    ``res.packed`` straight into ``em_update_flat``) adds ZERO host syncs
    mid-window: still one ``block_until_ready`` per round, at the
    aggregation barrier — the regression the per-round ``float()`` syncs
    used to cause."""
    from repro.core import effective_movement as EM

    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed")
    cfg = EM.EMConfig(window_h=10)  # the window never closes in this test
    res = eng.grouped_round(plans, gtr, gbn)  # warm engine compiles
    st = EM.em_init(gtr)
    EM.em_update_flat(cfg, st, res.packed)  # warm the EM kernel
    real = jax.block_until_ready
    calls = []

    def counting(x):
        calls.append(1)
        return real(x)

    jax.block_until_ready = counting
    try:
        ENG.reset_syncs()
        r = eng.grouped_round(plans, gtr, gbn)
        # the guard turns ANY implicit device↔host transfer (the old
        # per-round float() syncs) into an error, both directions
        with jax.transfer_guard("disallow"):
            assert EM.em_update_flat(cfg, st, r.packed) is None
    finally:
        jax.block_until_ready = real
    assert len(calls) == 1, f"expected 1 host sync, saw {len(calls)}"
    assert ENG.SYNCS["aggregation_barrier"] == 1
    ENG.reset_syncs()


# ---------------------------------------------------------------------------
# sharded-aggregation contracts: dispatches, syncs, shard geometry, stats
# ---------------------------------------------------------------------------


def test_sharded_agg_single_logical_dispatch(mixed_world):
    """agg="sharded" keeps the one-logical-dispatch contract: exactly one
    ``fedavg_grouped`` per round, with the per-shard kernel launches it
    fans out to recorded separately under ``fedavg_grouped_shards``."""
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed")
    eng.grouped_round(plans, gtr, gbn, agg="sharded")  # warm compiles
    OPS.reset_dispatches()
    eng.grouped_round(plans, gtr, gbn, agg="sharded")
    assert OPS.DISPATCHES["fedavg_grouped"] == 1
    assert OPS.DISPATCHES["fedavg_grouped_shards"] == \
        ENG.AGG_STATS["n_shards"]
    assert OPS.DISPATCHES["fedavg_masked"] == 0
    OPS.reset_dispatches()


def test_sharded_agg_single_host_sync(mixed_world):
    """The column-sharded round still performs exactly ONE
    jax.block_until_ready, at the aggregation barrier (the panel creation,
    per-shard scatters, and device_put streams are all async)."""
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed")
    eng.grouped_round(plans, gtr, gbn, agg="sharded")  # warm compiles
    real = jax.block_until_ready
    calls = []

    def counting(x):
        calls.append(1)
        return real(x)

    jax.block_until_ready = counting
    try:
        ENG.reset_syncs()
        eng.grouped_round(plans, gtr, gbn, agg="sharded")
    finally:
        jax.block_until_ready = real
    assert len(calls) == 1, f"expected 1 host sync, saw {len(calls)}"
    assert ENG.SYNCS["aggregation_barrier"] == 1
    ENG.reset_syncs()


def test_agg_stats_and_column_shards(mixed_world):
    """AGG_STATS reflects the actual panel sharding metadata, and
    GroupLayout.column_shards produces a tile-aligned partition that covers
    every column exactly once."""
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed")
    eng.grouped_round(plans, gtr, gbn, agg="sharded")
    st = dict(ENG.AGG_STATS)
    layout = ENG.make_group_layout(plans, gtr, gbn)
    cs = layout.column_shards(st["n_shards"])
    assert st["agg"] == "sharded" and st["n"] == layout.n
    assert st["n_padded"] == cs.n_padded
    assert st["per_device_panel_elems"] == layout.k_total * cs.n_shard
    assert cs.n_shard % AGG_TILE == 0
    assert cs.n_padded == cs.n_shard * cs.n_shards >= layout.n
    assert cs.offsets == tuple(
        i * cs.n_shard for i in range(cs.n_shards)
    )
    # replicated rounds report the full panel on one device
    eng.grouped_round(plans, gtr, gbn, agg="replicated")
    st_r = dict(ENG.AGG_STATS)
    assert st_r["agg"] == "replicated" and st_r["n_shards"] == 1
    assert st_r["per_device_panel_elems"] == layout.k_total * layout.n


def test_transient_stream_stats_match_model(mixed_world):
    """AGG_STATS transient-stream fields vs the analytic model: under the
    shard-local stream the measured per-device stream footprint (read from
    the real transfer sharding) equals ``max_g``
    :func:`MM.agg_stream_elems_per_device` exactly, and the replicated
    stream records the full ``max_g K_g·n_g`` group-panel footprint."""
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed")
    layout = ENG.make_group_layout(plans, gtr, gbn)
    kns = [(k, int(ix.size)) for k, ix in zip(layout.ks, layout.idx)]

    eng.grouped_round(plans, gtr, gbn, agg="sharded")
    st = dict(ENG.AGG_STATS)
    assert st["stream"] == "sharded"
    model = max(
        MM.agg_stream_elems_per_device(k, n_g, n_devices=st["n_shards"],
                                       agg="sharded")
        for k, n_g in kns
    )
    assert st["per_device_stream_elems"] == model
    # one scatter pass per group here (every group fits one m_chunk slice)
    assert st["stream_chunks"] >= layout.n_groups

    eng.grouped_round(plans, gtr, gbn, agg="replicated")
    st_r = dict(ENG.AGG_STATS)
    assert st_r["stream"] == "replicated"
    assert st_r["per_device_stream_elems"] == max(k * n_g for k, n_g in kns)
    assert st_r["stream_chunks"] == layout.n_groups


def test_agg_knob_validation(mixed_world):
    plans, gtr, gbn, _ = mixed_world
    with pytest.raises(ValueError):
        ENG.make_engine("packed", agg="columnwise")
    with pytest.raises(ValueError):
        ENG.make_engine("packed").grouped_round(plans, gtr, gbn, agg="magic")
    with pytest.raises(ValueError):
        from repro.launch.mesh import make_client_mesh

        ENG.make_engine("packed", agg_mesh=make_client_mesh())


def test_clear_caches_drops_sharded_layout_buffers(mixed_world):
    """The column-sharded gmask staged per mesh is a device buffer like the
    replicated one: clear_caches must drop it off caller-held layouts."""
    from repro.launch.mesh import make_model_mesh

    plans, gtr, gbn, _ = mixed_world
    layout = ENG.make_group_layout(plans, gtr, gbn)
    _ = layout.gmask_sharded(make_model_mesh())
    assert layout._gmask_sharded
    ENG.clear_caches()
    assert layout._gmask_sharded is None
    # lazy rebuild keeps the layout usable
    gm = layout.gmask_sharded(make_model_mesh())
    assert gm.shape[0] == layout.n_groups


# ---------------------------------------------------------------------------
# server aggregation memory model regression
# ---------------------------------------------------------------------------


def test_memory_model_matches_engine_tile():
    assert MM.AGG_TILE == AGG_TILE


def test_server_agg_memory_model_sharded_divides_by_d():
    """Pin the headline contract: sharded-agg per-device panel bytes ≈
    K_total·n/D (within one tile of padding per device), never the full
    panel."""
    K, n, G = 64, 1_000_000, 8
    full = MM.server_aggregation_peak_bytes(K, n, G)
    assert full == 4 * (K * n + G * n + 4 * n + K + G)
    for D in (2, 4, 8):
        per_dev = MM.server_aggregation_peak_bytes(
            K, n, G, n_devices=D, agg="sharded"
        )
        panel_dev = 4 * K * MM.agg_columns_per_device(
            n, n_devices=D, agg="sharded"
        )
        # panel term ≈ K·n/D: within one tile of padding per device
        assert panel_dev >= 4 * K * n / D
        assert panel_dev <= 4 * K * (n / D + MM.AGG_TILE)
        # and strictly below the replicated panel — the full [K, n] panel
        # never fits on (or lands on) a single device
        assert per_dev < full / (D * 0.9)
    with pytest.raises(ValueError):
        MM.server_aggregation_peak_bytes(K, n, G, agg="magic")


def test_server_agg_memory_model_matches_measured_stats(mixed_world):
    """The analytic per-device panel bytes must agree with the sharding
    metadata AGG_STATS records from the real panel."""
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed")
    eng.grouped_round(plans, gtr, gbn, agg="sharded")
    st = dict(ENG.AGG_STATS)
    n_dev_cols = MM.agg_columns_per_device(
        st["n"], n_devices=st["n_shards"], agg="sharded"
    )
    assert st["per_device_panel_elems"] == st["k_total"] * n_dev_cols


def test_agg_stream_model_bound():
    """Pin the transient-stream contract: under the shard-local stream a
    group's per-device footprint is within ``K_g·n_g/D`` + one tile of
    padding, never exceeds the replicated ``K_g·n_g``, and the ≤D chunked
    passes still cover every column."""
    tile = MM.AGG_TILE
    k_g = 7
    for D in (1, 2, 4, 8):
        for n_g in (1, 50, 1000, 12345, 1_000_000):
            elems = MM.agg_stream_elems_per_device(
                k_g, n_g, n_devices=D, agg="sharded"
            )
            cols = MM.agg_stream_cols_per_device(n_g, n_devices=D,
                                                 agg="sharded")
            assert elems == k_g * cols
            assert elems <= k_g * (n_g / D + tile)  # the headline bound
            assert elems <= k_g * n_g  # never worse than the replicated stream
            assert cols * D >= n_g  # D passes of m_chunk cover the panel
            assert MM.agg_stream_elems_per_device(k_g, n_g, n_devices=D) \
                == k_g * n_g  # replicated default
    with pytest.raises(ValueError):
        MM.agg_stream_cols_per_device(10, agg="magic")


def test_server_agg_peak_includes_stream_term():
    """``server_aggregation_peak_bytes(groups=...)`` adds exactly the
    largest group's transient stream footprint on top of the persistent
    buffers, per agg mode."""
    K, n, G, D = 64, 1_000_000, 8, 4
    groups = [(8, 200_000), (16, 500_000), (40, 990_000)]
    for agg in ("replicated", "sharded"):
        base = MM.server_aggregation_peak_bytes(K, n, G, n_devices=D, agg=agg)
        full = MM.server_aggregation_peak_bytes(K, n, G, n_devices=D, agg=agg,
                                                groups=groups)
        stream = max(
            MM.agg_stream_elems_per_device(kg, ng, n_devices=D, agg=agg)
            for kg, ng in groups
        )
        assert full == base + 4 * stream
    # the sharded stream term divides by D (up to tile padding) — the
    # near-full-width majority group no longer re-approaches K·n
    s_repl = MM.server_aggregation_peak_bytes(
        K, n, G, n_devices=D, agg="replicated", groups=groups
    ) - MM.server_aggregation_peak_bytes(K, n, G, n_devices=D,
                                         agg="replicated")
    s_shard = MM.server_aggregation_peak_bytes(
        K, n, G, n_devices=D, agg="sharded", groups=groups
    ) - MM.server_aggregation_peak_bytes(K, n, G, n_devices=D, agg="sharded")
    assert s_shard <= s_repl / D + 4 * 40 * MM.AGG_TILE
    assert s_shard < s_repl


# ---------------------------------------------------------------------------
# 8-virtual-device composed clients × model mesh (subprocess so the
# host-device-count flag applies before jax initializes)
# ---------------------------------------------------------------------------

_COMPOSED_MESH_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.fl import engine as ENG
from repro.kernels import ops as OPS
from repro.launch.mesh import make_fl_cohort_mesh

mesh = make_fl_cohort_mesh(n_clients=4, n_model=2)
assert dict(mesh.shape) == {"clients": 4, "model": 2}, dict(mesh.shape)
eng = ENG.CohortEngine("sharded", mesh)
assert eng.agg_mesh is mesh  # the model axis is picked up from the mesh

def width_loss(f):
    def loss_fn(tr, fro, bn, xb, yb):
        pred = xb[:, :f] @ tr["w"] + tr["b"]
        return jnp.mean((pred - yb[:, None]) ** 2), bn
    return loss_fn

losses = {f: width_loss(f) for f in (3, 5)}
d, out, n_local = 5, 3, 8
rng = jax.random.PRNGKey(0)
# n = 5*3 + 3 + 1 = 19 columns: odd, so NOT divisible by the 2 column shards
tr = {"w": jax.random.normal(rng, (d, out)), "b": jnp.zeros((out,)),
      "c": jnp.zeros((1,))}
plans = []
for gi, f in enumerate((3, 5)):
    sub = {"w": tr["w"][:f], "b": tr["b"], "c": tr["c"]}
    gxs = jax.random.normal(jax.random.fold_in(rng, 10 + gi), (3, n_local, d))
    gys = jax.random.normal(jax.random.fold_in(rng, 20 + gi), (3, n_local))
    grngs = jax.random.split(jax.random.fold_in(rng, 30 + gi), 3)
    plans.append(ENG.GroupPlan(
        losses[f], sub, {}, {}, gxs, gys, grngs,
        jnp.arange(1.0, 4.0) * (gi + 1), 0.1, 3, 4,
    ))

# K_total = 6 does not divide the 4-slot clients axis (ghost padding), and
# each group's K_g = 3 does not divide its 2-slot clients sub-mesh either
want = ENG.make_engine("vmap").grouped_round(plans, tr, {})
got_r = eng.grouped_round(plans, tr, {}, agg="replicated")
OPS.reset_dispatches()
got_s = eng.grouped_round(plans, tr, {}, agg="sharded")

# one LOGICAL dispatch, two per-shard kernel launches under it
assert OPS.DISPATCHES["fedavg_grouped"] == 1, dict(OPS.DISPATCHES)
assert OPS.DISPATCHES["fedavg_grouped_shards"] == 2, dict(OPS.DISPATCHES)

# the full [K_total, n] panel never materialized on one device: each
# device's panel block is exactly K_total x (n_padded / 2)
st = ENG.AGG_STATS
assert st["n_shards"] == 2, st
assert st["per_device_panel_elems"] == st["k_total"] * st["n_padded"] // 2, st
assert st["per_device_panel_elems"] < st["k_total"] * st["n_padded"], st

# the group-panel STREAM is shard-local too: the measured per-device stream
# footprint (from the real transfer sharding) equals the analytic model
from repro.fl import memory_model as MM
layout_s = ENG.make_group_layout(plans, tr, {})
kns = [(k, int(ix.size)) for k, ix in zip(layout_s.ks, layout_s.idx)]
model = max(MM.agg_stream_elems_per_device(k, n_g, n_devices=2, agg="sharded")
            for k, n_g in kns)
assert st["stream"] == "sharded", st
assert st["per_device_stream_elems"] == model, (st, model)

# column-sharded aggregation is BIT-EQUAL to the replicated path
for a, b in zip(jax.tree.leaves(got_r.trainable),
                jax.tree.leaves(got_s.trainable)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

err = max(
    float(jnp.max(jnp.abs(a - b)))
    for a, b in zip(jax.tree.leaves(want.trainable),
                    jax.tree.leaves(got_s.trainable))
)
err = max(err, abs(float(want.loss) - float(got_s.loss)))
print("COMPOSED_MAXERR", err)
assert err <= 1e-5, err

# a SECOND round fed the first round's outputs (committed to the default
# device) and device-0-committed plan trees: _align_for_mesh must stream
# them onto each group's sub-mesh instead of aborting with 'incompatible
# devices' (this is how real multi-round baselines run on a mesh)
tr2 = jax.device_put(got_s.trainable, jax.devices()[0])
plans2 = [
    p._replace(trainable={"w": tr2["w"][:f], "b": tr2["b"], "c": tr2["c"]})
    for p, f in zip(plans, (3, 5))
]
again = eng.grouped_round(plans2, tr2, {}, agg="sharded")
assert all(bool(jnp.all(jnp.isfinite(l)))
           for l in jax.tree.leaves(again.trainable))
print("SECOND_ROUND_OK")

# gmask_sharded must key on the model-axis size, not just the device set:
# the 2-shard composed mesh and an 8-shard 1-D model mesh cover the SAME
# devices but need different paddings
from repro.launch.mesh import make_model_mesh
layout = ENG.make_group_layout(plans, tr, {})
gm2 = layout.gmask_sharded(mesh)               # model axis 2
gm8 = layout.gmask_sharded(make_model_mesh())  # model axis 8, same devices
assert gm2.shape[1] == layout.column_shards(2).n_padded, gm2.shape
assert gm8.shape[1] == layout.column_shards(8).n_padded, gm8.shape
print("GMASK_KEYING_OK")

# WIDE groups (n_g > tile x D): the shard-local stream must move strictly
# LESS than a full [K_g, n_g] replica per agg device — this is the peak the
# PR 4 replicated stream could not bound (a near-full-width majority group
# transiently re-approached K x n on every agg device)
d2 = 512
losses_w = {f: width_loss(f) for f in (128, 256)}
tr_w = {"w": jax.random.normal(jax.random.fold_in(rng, 99), (d2, out)),
        "b": jnp.zeros((out,)), "c": jnp.zeros((1,))}
plans_w = []
for gi, f in enumerate((128, 256)):
    sub = {"w": tr_w["w"][:f], "b": tr_w["b"], "c": tr_w["c"]}
    gxs = jax.random.normal(jax.random.fold_in(rng, 40 + gi), (3, n_local, d2))
    gys = jax.random.normal(jax.random.fold_in(rng, 50 + gi), (3, n_local))
    grngs = jax.random.split(jax.random.fold_in(rng, 60 + gi), 3)
    plans_w.append(ENG.GroupPlan(
        losses_w[f], sub, {}, {}, gxs, gys, grngs,
        jnp.arange(1.0, 4.0) * (gi + 1), 0.1, 2, 4,
    ))
wide = eng.grouped_round(plans_w, tr_w, {}, agg="sharded")
assert all(bool(jnp.all(jnp.isfinite(l)))
           for l in jax.tree.leaves(wide.trainable))
st_w = ENG.AGG_STATS
layout_w = ENG.make_group_layout(plans_w, tr_w, {})
kns_w = [(k, int(ix.size)) for k, ix in zip(layout_w.ks, layout_w.idx)]
model_w = max(
    MM.agg_stream_elems_per_device(k, n_g, n_devices=2, agg="sharded")
    for k, n_g in kns_w
)
full_w = max(k * n_g for k, n_g in kns_w)
assert st_w["stream"] == "sharded", st_w
assert st_w["per_device_stream_elems"] == model_w, (st_w, model_w)
assert st_w["per_device_stream_elems"] < full_w, (st_w, full_w)
# and the analytic bound itself: max_g K_g*n_g/D + tile padding
from repro.kernels.fedavg import AGG_TILE
assert model_w <= max(k * (n_g / 2 + AGG_TILE) for k, n_g in kns_w)
print("STREAM_SHARDED_OK", st_w["per_device_stream_elems"], "<", full_w)

# FROZEN epoch on the composed mesh: a random half-frozen mask must keep
# replicated and sharded bit-equal over the SHRUNKEN panel, pass frozen
# columns through untouched, and make the measured per-device panel AND
# stream figures decay below the unfrozen round while still matching the
# memory model's frozen-fraction term — the paper's decay claim, measured
# on the real 2-shard mesh
st_w = dict(st_w)  # snapshot before the next round clears AGG_STATS
mask = np.zeros(layout_w.n, bool)
mask[np.random.default_rng(7).choice(layout_w.n, layout_w.n // 2,
                                     replace=False)] = True
fro = ENG.make_frozen_columns(mask)
got_fr = eng.grouped_round(plans_w, tr_w, {}, agg="replicated", frozen=fro)
got_fs = eng.grouped_round(plans_w, tr_w, {}, agg="sharded", frozen=fro)
st_f = dict(ENG.AGG_STATS)
for a, b in zip(jax.tree.leaves(got_fr.trainable),
                jax.tree.leaves(got_fs.trainable)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
spec_w = ENG.make_pack_spec(tr_w)
prev_w = np.asarray(spec_w.pack(tr_w))
out_w = np.asarray(spec_w.pack(got_fs.trainable))
np.testing.assert_array_equal(out_w[mask], prev_w[mask])
assert not np.array_equal(out_w[~mask], prev_w[~mask])  # live cols moved
layout_f = ENG.make_group_layout(plans_w, tr_w, {}, frozen=fro)
g_n = [int(ix.size) for ix in layout_f.idx]
g_f = [int(np.sum(d >= layout_f.n_active)) for d in layout_f.dst]
panel_model = st_f["k_total"] * MM.agg_columns_per_device(
    layout_f.n, n_devices=2, agg="sharded", n_frozen=fro.n_frozen)
stream_model = max(
    MM.agg_stream_elems_per_device(k, n_g, n_devices=2, agg="sharded",
                                   n_frozen=f)
    for k, n_g, f in zip(layout_f.ks, g_n, g_f))
assert st_f["n_frozen"] == fro.n_frozen, st_f
assert st_f["per_device_panel_elems"] == panel_model, (st_f, panel_model)
assert st_f["per_device_stream_elems"] == stream_model, (st_f, stream_model)
assert st_f["per_device_panel_elems"] < st_w["per_device_panel_elems"], (
    st_f, st_w)
assert st_f["per_device_stream_elems"] < st_w["per_device_stream_elems"], (
    st_f, st_w)
print("FROZEN_OK", st_w["per_device_panel_elems"], "->",
      st_f["per_device_panel_elems"])

# TRANSPORT (ISSUE 7) on the real 2-shard mesh, back on the small world:
# with AGG_TILE=128 every one of the 19 columns lives in shard 0, so BOTH
# groups are DepthFL-style concentrated — the ragged transfer ships shard 1
# nothing at all while the uniform axis-0 split would send it a full pad
# row per pass (2x the wire).  Measured wire == the memory model's analytic
# twin, per wire dtype; the quantized panel/stream/scales reside at the
# wire dtype on every agg device (never f32).
from repro.fl import memory_model as MM2
cs2 = layout.column_shards(2)

def wire_groups(agg):
    if agg == "replicated":
        return [(k, int(layout.group_active_cols(gi).size))
                for gi, k in enumerate(layout.ks)]
    return [
        (k, [int(np.sum((layout.group_active_cols(gi) >= o)
                        & (layout.group_active_cols(gi) < o + cs2.n_shard)))
             for o in cs2.offsets])
        for gi, k in enumerate(layout.ks)
    ]

g_sh = wire_groups("sharded")
assert all(per[1] == 0 for _, per in g_sh), g_sh  # concentrated: shard 1 idle
for sd in ("f32", "bf16", "int8"):
    got_t = eng.grouped_round(plans, tr, {}, agg="sharded", stream_dtype=sd)
    st_t = dict(ENG.AGG_STATS)
    eb = ENG.STREAM_ELEM_BYTES[sd]
    assert st_t["stream_dtype"] == sd and st_t["n_shards"] == 2, st_t
    want_w = MM2.agg_wire_bytes(g_sh, agg="sharded", stream_dtype=sd)
    want_u = MM2.agg_wire_bytes_uniform(g_sh, agg="sharded", stream_dtype=sd)
    assert st_t["wire_bytes"] == want_w, (sd, st_t["wire_bytes"], want_w)
    assert st_t["wire_bytes_uniform"] == want_u, (sd, st_t, want_u)
    assert st_t["wire_bytes"] <= want_u // 2, (sd, want_w, want_u)
    assert st_t["panel_elem_bytes"] == eb, st_t
    assert st_t["per_device_panel_bytes"] == \
        st_t["per_device_panel_elems"] * eb, st_t
    assert st_t["per_device_stream_bytes"] == \
        st_t["per_device_stream_elems"] * eb, st_t
    assert st_t["per_device_scales_bytes"] == \
        (2 * layout.n_groups * cs2.n_shard if sd == "int8" else 0), st_t
    if sd == "f32":  # the ragged+paced f32 wire is the replicated result, bit-for-bit
        for a, b in zip(jax.tree.leaves(got_r.trainable),
                        jax.tree.leaves(got_t.trainable)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    else:  # quantized wire: documented tolerance of the f32 result
        for a, b in zip(jax.tree.leaves(got_r.trainable),
                        jax.tree.leaves(got_t.trainable)):
            aa, bb = np.asarray(a, np.float32), np.asarray(b, np.float32)
            tol = max(1.0, float(np.max(np.abs(aa)))) / (
                32.0 if sd == "int8" else 128.0)
            np.testing.assert_allclose(bb, aa, atol=tol)

# pacing tokens are pure dependency sequencing: any inflight depth is the
# default f32 round bit-for-bit
for infl in (1, 3):
    got_p = eng.grouped_round(plans, tr, {}, agg="sharded", inflight=infl)
    for a, b in zip(jax.tree.leaves(got_r.trainable),
                    jax.tree.leaves(got_p.trainable)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# int8 round 2: the EF residual (committed to the agg mesh) rides the next
# round's quantization without disturbing the round contracts
got_q2 = eng.grouped_round(plans, tr, {}, agg="sharded", stream_dtype="int8")
assert all(bool(jnp.all(jnp.isfinite(l)))
           for l in jax.tree.leaves(got_q2.trainable))
print("TRANSPORT_OK", MM2.agg_wire_bytes(g_sh, agg="sharded"), "ragged vs",
      MM2.agg_wire_bytes_uniform(g_sh, agg="sharded"), "uniform")

# FAULTS (ISSUE 8) on the real composed mesh: a fault-free plan is
# bit-equal to faults=None on the column-sharded path; a dropped + poisoned
# round stays finite, matches the zero-weight vmap oracle without those
# clients, and keeps replicated/sharded bit-equal; a straggler parks and
# merges one round later with the telemetry to prove it
from repro.fl import faults as FLT
ok6 = FLT.all_ok(6)
got_ok = eng.grouped_round(plans, tr, {}, agg="sharded", faults=ok6)
for a, b in zip(jax.tree.leaves(got_s.trainable),
                jax.tree.leaves(got_ok.trainable)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

fp = FLT.FaultPlan(verdicts=(
    FLT.OK, FLT.ClientFault("dropped"), FLT.OK,
    FLT.OK, FLT.ClientFault("corrupt", mode="nan"), FLT.OK,
))
got_fr = eng.grouped_round(plans, tr, {}, agg="replicated", faults=fp)
got_ff = eng.grouped_round(plans, tr, {}, agg="sharded", faults=fp)
assert all(bool(jnp.all(jnp.isfinite(l)))
           for l in jax.tree.leaves(got_ff.trainable))
for a, b in zip(jax.tree.leaves(got_fr.trainable),
                jax.tree.leaves(got_ff.trainable)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# client 1 is group 0 row 1, client 4 is group 1 row 1
plans_zw = [p._replace(weights=p.weights * jnp.asarray([1.0, 0.0, 1.0]))
            for p in plans]
want_zw = ENG.make_engine("vmap").grouped_round(plans_zw, tr, {})
err_f = max(
    float(jnp.max(jnp.abs(a - b)))
    for a, b in zip(jax.tree.leaves(want_zw.trainable),
                    jax.tree.leaves(got_ff.trainable))
)
assert err_f <= 1e-5, err_f

sp = FLT.FaultPlan(verdicts=(
    FLT.OK, FLT.OK, FLT.ClientFault("straggler", delay=1),
    FLT.OK, FLT.OK, FLT.OK,
))
eng.grouped_round(plans, tr, {}, agg="sharded", faults=sp)
assert ENG.AGG_STATS["fault_staged_rows"] == 1, dict(ENG.AGG_STATS)
merged = eng.grouped_round(plans, tr, {}, agg="sharded", faults=ok6)
assert ENG.AGG_STATS["fault_merged_rows"] == 1, dict(ENG.AGG_STATS)
assert ENG.AGG_STATS["fault_staged_rows"] == 0, dict(ENG.AGG_STATS)
assert all(bool(jnp.all(jnp.isfinite(l)))
           for l in jax.tree.leaves(merged.trainable))
print("FAULTS_OK", err_f)

# ASYNC (ISSUE 9) on the composed mesh: staleness-0 + publish_at=cohort
# reproduces the sync column-sharded round bit-exactly, and a stale
# follow-up publish folds through one dispatch + one sync and stays finite
from repro.fl import async_server as ASY
from repro.kernels import ops as OPS3
want_async = eng.grouped_round(plans, tr, {}, agg="sharded")
srv = ASY.AsyncAggServer(eng, tr, {}, publish_at=6, agg="sharded", beta=0.5)
for p in plans:
    srv.submit(p, srv.version)
got_async = srv.publish()
for a, b in zip(jax.tree.leaves(want_async.trainable),
                jax.tree.leaves(got_async.trainable)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
srv.submit(plans[0], 0)  # stale: trained against v0, server is at v1
OPS3.reset_dispatches()
ENG.reset_syncs()
got_stale = srv.publish()
assert OPS3.DISPATCHES["fedavg_grouped"] == 1, dict(OPS3.DISPATCHES)
assert ENG.SYNCS["aggregation_barrier"] == 1, dict(ENG.SYNCS)
assert all(bool(jnp.all(jnp.isfinite(l)))
           for l in jax.tree.leaves(got_stale.trainable))
assert ENG.AGG_STATS["async_stale_rows"] == 3, dict(ENG.AGG_STATS)
print("ASYNC_OK", srv.version)

# HIER (ISSUE 10) on the composed mesh: edges=1 IS the flat sharded round
# (verbatim routing, bit-equal); a 3-edge two-tier fold matches it to fp
# tolerance while keeping ONE logical carrier dispatch + 3 per-edge folds,
# and the measured per-tier bytes equal the memory-model twins on the
# real 2-shard model axis
from repro.fl import memory_model as MM4
want_h = eng.grouped_round(plans, tr, {}, agg="sharded")
got_h1 = eng.grouped_round(plans, tr, {}, agg="sharded", edges=1)
for a, b in zip(jax.tree.leaves(want_h.trainable),
                jax.tree.leaves(got_h1.trainable)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
OPS3.reset_dispatches()
got_h3 = eng.grouped_round(plans, tr, {}, agg="sharded", edges=3)
assert OPS3.DISPATCHES["fedavg_grouped"] == 1, dict(OPS3.DISPATCHES)
assert OPS3.DISPATCHES["fedavg_grouped_edges"] == 3, dict(OPS3.DISPATCHES)
st_h = dict(ENG.AGG_STATS)
assert st_h["hier_edges_used"] == 3, st_h
assert st_h["hier_server_peak_bytes"] == MM4.hier_server_peak_bytes(
    st_h["n"], 3, n_devices=st_h["n_shards"], agg="sharded"
), st_h
assert st_h["hier_edge_partial_bytes"] == MM4.edge_partial_bytes(st_h["n"])
err_h = max(
    float(jnp.max(jnp.abs(a - b)))
    for a, b in zip(jax.tree.leaves(want_h.trainable),
                    jax.tree.leaves(got_h3.trainable))
)
assert err_h <= 1e-5, err_h
print("HIER_OK", err_h)
"""


def test_composed_mesh_sharded_agg_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_compile_cache")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", _COMPOSED_MESH_SCRIPT],
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "COMPOSED_MAXERR" in out.stdout
    assert "SECOND_ROUND_OK" in out.stdout
    assert "GMASK_KEYING_OK" in out.stdout
    assert "STREAM_SHARDED_OK" in out.stdout
    assert "FROZEN_OK" in out.stdout
    assert "TRANSPORT_OK" in out.stdout
    assert "FAULTS_OK" in out.stdout
    assert "ASYNC_OK" in out.stdout
    assert "HIER_OK" in out.stdout


# ---------------------------------------------------------------------------
# transport axis (ISSUE 7): stream_dtype × agg conformance, wire accounting
# ---------------------------------------------------------------------------

# tier-1 allowlist for the quantized-dtype cells per heavy fixture; the
# mixed fixture runs its full (dtype × agg) square in tier-1
STREAM_TIER1 = {
    "mixed": None,
    "cnn": {("int8", "sharded")},
    "transformer": {("int8", "sharded")},
}


def _wire_groups(layout, n_shards, agg):
    """Per-group wire-model entries for ``MM.agg_wire_bytes``: ``(K_g,
    n_live)`` replicated, ``(K_g, live-per-shard)`` sharded (the live
    column histogram over the layout's column-shard ranges)."""
    if agg == "replicated":
        return [(k, int(layout.group_active_cols(gi).size))
                for gi, k in enumerate(layout.ks)]
    cs = layout.column_shards(n_shards)
    out = []
    for gi, k in enumerate(layout.ks):
        live = layout.group_active_cols(gi)
        out.append((k, [int(np.sum((live >= o) & (live < o + cs.n_shard)))
                        for o in cs.offsets]))
    return out


def test_stream_elem_bytes_maps_pinned():
    """The engine's wire-dtype table and the memory model's mirror must
    never drift apart — every byte-accounting cross-check rests on it."""
    assert ENG.STREAM_DTYPES == ("f32", "bf16", "int8")
    assert ENG.STREAM_ELEM_BYTES == MM.STREAM_ELEM_BYTES
    assert ENG.STREAM_ELEM_BYTES == {"f32": 4, "bf16": 2, "int8": 1}


def test_stream_dtype_f32_bit_equal_to_default(mixed_world):
    """Explicit ``stream_dtype="f32"`` — at ANY inflight depth — is the
    default path: bit-equal results on both aggregation placements (the
    ragged transfer lands identical values and the pacing token is pure
    dependency sequencing, so no knob may perturb a single bit)."""
    plans, gtr, gbn, _ = mixed_world
    base_eng = ENG.make_engine("packed")
    for agg in AGGS:
        base = base_eng.grouped_round(plans, gtr, gbn, agg=agg)
        for inflight in (1, 3):
            got = ENG.make_engine(
                "packed", stream_dtype="f32", inflight=inflight
            ).grouped_round(plans, gtr, gbn, agg=agg)
            for a, b in zip(jax.tree.leaves(base.trainable),
                            jax.tree.leaves(got.trainable)):
                np.testing.assert_array_equal(np.asarray(a, np.float32),
                                              np.asarray(b, np.float32))


def _stream_matrix():
    for fixture in FIXTURES:
        fast = STREAM_TIER1[fixture]
        for sd in ("bf16", "int8"):
            for agg in AGGS:
                marks = ()
                if fast is not None and (sd, agg) not in fast:
                    marks = (pytest.mark.slow,)
                yield pytest.param(fixture, sd, agg, marks=marks,
                                   id=f"{fixture}-{sd}-{agg}")


@pytest.mark.parametrize("fixture,sd,agg", list(_stream_matrix()))
def test_stream_dtype_contract(fixture, sd, agg, request):
    """Quantized wire dtypes vs the f32 oracle at the DOCUMENTED tolerance:
    ``bf16`` rounds each panel entry to 8 mantissa bits (aggregate within
    ``absmax/128`` — one bf16 ulp at the panel's magnitude, with margin);
    ``int8`` errs at most one per-column scale per entry, and the scale is
    at most ``2·colmax/127`` (aggregate within ``absmax/16``, 4× margin for
    panel entries above the aggregate's absmax).  The loss is computed from
    local SGD BEFORE the wire, so it must match at the matrix tolerance."""
    plans, gtr, gbn, want = request.getfixturevalue(fixture + "_world")
    got = ENG.make_engine("packed", stream_dtype=sd).grouped_round(
        plans, gtr, gbn, agg=agg
    )
    ref_flat = np.asarray(ENG.make_pack_spec(gtr).pack(want.trainable),
                          np.float32)
    got_flat = np.asarray(got.packed, np.float32)
    absmax = max(float(np.max(np.abs(ref_flat))), 1e-3)
    tol = absmax / (128.0 if sd == "bf16" else 16.0)
    np.testing.assert_allclose(got_flat, ref_flat, atol=tol + 1e-5)
    np.testing.assert_allclose(float(got.loss), float(want.loss), atol=1e-5)


@pytest.mark.parametrize("agg", AGGS)
@pytest.mark.parametrize("sd", ENG.STREAM_DTYPES)
def test_wire_bytes_match_model(mixed_world, sd, agg):
    """The measured transport telemetry equals the analytic memory model
    EXACTLY, per wire dtype and placement: ``wire_bytes`` (ragged payload +
    int8's packed scale exponents), the uniform counterfactual, and every
    per-device resident-bytes field at the wire dtype — no agg device holds
    an f32 panel when the wire is quantized."""
    plans, gtr, gbn, _ = mixed_world
    layout = ENG.make_group_layout(plans, gtr, gbn)
    eng = ENG.make_engine("packed", stream_dtype=sd)
    eng.grouped_round(plans, gtr, gbn, agg=agg)
    st = dict(ENG.AGG_STATS)
    eb = ENG.STREAM_ELEM_BYTES[sd]
    assert st["stream_dtype"] == sd and st["inflight"] == 2
    assert st["panel_elem_bytes"] == eb
    groups = _wire_groups(layout, st["n_shards"], agg)
    assert st["wire_bytes"] == MM.agg_wire_bytes(
        groups, agg=agg, stream_dtype=sd
    )
    assert st["wire_bytes_uniform"] == MM.agg_wire_bytes_uniform(
        groups, agg=agg, stream_dtype=sd
    )
    assert st["wire_bytes"] <= st["wire_bytes_uniform"]
    assert st["per_device_panel_bytes"] == st["per_device_panel_elems"] * eb
    assert st["per_device_stream_bytes"] == st["per_device_stream_elems"] * eb
    if sd == "int8":
        n_dev_cols = (st["n_padded"] // st["n_shards"]
                      if agg == "sharded" else st["n_active"])
        assert st["per_device_scales_bytes"] == 2 * layout.n_groups * n_dev_cols
        # the quantized wire is strictly cheaper than the f32 wire
        assert st["wire_bytes"] < MM.agg_wire_bytes(
            groups, agg=agg, stream_dtype="f32"
        )
    else:
        assert st["per_device_scales_bytes"] == 0


def test_stream_dtype_int8_single_dispatch_single_sync(mixed_world):
    """The quantized round keeps BOTH fused-path contracts: exactly one
    logical ``fedavg_grouped`` dispatch (the dequant variant shares the
    counter key) and exactly one host sync — quantization, EF update, scale
    packing/decoding, and the ragged stream are all async."""
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed", stream_dtype="int8")
    eng.grouped_round(plans, gtr, gbn, agg="sharded")  # warm + seed EF
    OPS.reset_dispatches()
    real = jax.block_until_ready
    calls = []

    def counting(x):
        calls.append(1)
        return real(x)

    jax.block_until_ready = counting
    try:
        ENG.reset_syncs()
        eng.grouped_round(plans, gtr, gbn, agg="sharded")
    finally:
        jax.block_until_ready = real
    assert len(calls) == 1, f"expected 1 host sync, saw {len(calls)}"
    assert ENG.SYNCS["aggregation_barrier"] == 1
    assert OPS.DISPATCHES["fedavg_grouped"] == 1
    assert OPS.DISPATCHES["fedavg_grouped_shards"] == \
        ENG.AGG_STATS["n_shards"]
    ENG.reset_syncs()
    OPS.reset_dispatches()


def test_stream_dtype_knob_validation(mixed_world):
    plans, gtr, gbn, _ = mixed_world
    with pytest.raises(ValueError):
        ENG.make_engine("packed", stream_dtype="fp8")
    with pytest.raises(ValueError):
        ENG.make_engine("packed", inflight=0)
    eng = ENG.make_engine("packed")
    with pytest.raises(ValueError):
        eng.grouped_round(plans, gtr, gbn, stream_dtype="f16")
    with pytest.raises(ValueError):
        eng.grouped_round(plans, gtr, gbn, inflight=0)
    # the legacy dense-mask kernel has no dequant variant: quantized wire
    # dtypes are rejected, not silently upcast
    for sd in ("bf16", "int8"):
        with pytest.raises(ValueError):
            eng.grouped_round(plans, gtr, gbn, impl="fused_masked",
                              stream_dtype=sd)
    # the serial oracle never touches the wire: knobs are accepted, ignored
    want = eng.grouped_round(plans, gtr, gbn, impl="serial")
    got = eng.grouped_round(plans, gtr, gbn, impl="serial",
                            stream_dtype="int8", inflight=1)
    for a, b in zip(jax.tree.leaves(want.trainable),
                    jax.tree.leaves(got.trainable)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_int8_ef_state_lifecycle(mixed_world):
    """Error-feedback residuals live on the ENGINE across rounds: seeded by
    the first int8 round (one entry per group), carried into the next round
    (which therefore differs from the first on identical inputs), dropped by
    ``reset_ef`` (restoring the first round bit-for-bit), and never touched
    by f32 rounds."""
    plans, gtr, gbn, _ = mixed_world
    layout = ENG.make_group_layout(plans, gtr, gbn)
    eng = ENG.make_engine("packed", stream_dtype="int8")
    assert not eng._ef_state
    r1 = eng.grouped_round(plans, gtr, gbn, agg="replicated")
    assert len(eng._ef_state) == layout.n_groups
    r2 = eng.grouped_round(plans, gtr, gbn, agg="replicated")
    assert not np.array_equal(np.asarray(r1.packed), np.asarray(r2.packed))
    eng.reset_ef()
    assert not eng._ef_state
    r3 = eng.grouped_round(plans, gtr, gbn, agg="replicated")
    np.testing.assert_array_equal(np.asarray(r1.packed),
                                  np.asarray(r3.packed))
    eng_f32 = ENG.make_engine("packed")
    eng_f32.grouped_round(plans, gtr, gbn)
    assert not eng_f32._ef_state


@pytest.mark.slow
def test_int8_ef_mean_converges_to_fedavg(cnn_world):
    """EF telescopes: repeating the SAME CNN round on one int8 engine, round
    ``r`` ships ``t + ef_{r-1} - ef_r``, so the running mean of the
    quantized aggregates converges to the exact f32 FedAvg aggregate at
    ``O(scale/R)`` (``fedavg_grouped`` is linear in the panel, so per-column
    telescoping carries through the weighted mean).  This is the
    convergence-to-FedAvg guarantee error feedback buys on a non-IID
    fixture — without EF the per-round quantization error would not
    average out."""
    plans, gtr, gbn, _ = cnn_world
    exact = np.asarray(
        ENG.make_engine("packed").grouped_round(plans, gtr, gbn).packed,
        np.float64,
    )
    eng = ENG.make_engine("packed", stream_dtype="int8")
    outs = [
        np.asarray(eng.grouped_round(plans, gtr, gbn).packed, np.float64)
        for _ in range(8)
    ]
    err1 = float(np.max(np.abs(outs[0] - exact)))
    err_mean = float(np.max(np.abs(np.mean(outs, axis=0) - exact)))
    # |mean - exact| = |agg(ef_R)|/R <= scale/R: an ~8x drop from the
    # single-round error bound (scale), asserted at 2x to absorb the
    # randomness of the final residual
    assert err_mean <= max(err1 / 2.0, 1e-7), (err_mean, err1)


# ---------------------------------------------------------------------------
# fault-tolerance axis (ISSUE 8): dropouts, stragglers, poisoned updates
# ---------------------------------------------------------------------------

# mixed-world client index -> (group, row): 0-1 -> g0, 2-4 -> g1, 5-6 -> g2
_K_MIXED = 7

# tier-1 allowlist for the fault-free bit-equality cells; the rest run slow
FAULTS_TIER1 = {
    ("vmap", "serial", "replicated"),
    ("packed", "serial", "replicated"),
    ("packed", "fused", "replicated"),
    ("packed", "fused", "sharded"),
    ("packed", "fused_masked", "replicated"),
    ("sharded", "fused", "sharded"),
}


def _plan_with(faults_by_client, **kw):
    """A mixed-world FaultPlan with the given {client_index: ClientFault}."""
    verdicts = [FLT.OK] * _K_MIXED
    for i, v in faults_by_client.items():
        verdicts[i] = v
    return FLT.FaultPlan(verdicts=tuple(verdicts), **kw)


def _zero_weight_plans(plans, dead):
    """The oracle cohort: the same plans with the DEAD clients' aggregation
    weights zeroed (they still train locally — exactly the engine's dropped
    semantics)."""
    out, o = [], 0
    for p in plans:
        k = int(p.xs.shape[0])
        w = np.asarray(p.weights, np.float32).copy()
        for i in range(k):
            if o + i in dead:
                w[i] = 0.0
        out.append(p._replace(weights=jnp.asarray(w)))
        o += k
    return out


def _bit_equal_rounds(a, b):
    for x, y in zip(jax.tree.leaves(a.trainable), jax.tree.leaves(b.trainable)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
    for x, y in zip(jax.tree.leaves(a.bn_state), jax.tree.leaves(b.bn_state)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
    np.testing.assert_array_equal(np.float32(a.loss), np.float32(b.loss))


def _faults_matrix():
    for mode in MODES:
        for impl in IMPLS:
            for agg in AGGS:
                marks = ()
                if (mode, impl, agg) not in FAULTS_TIER1:
                    marks = (pytest.mark.slow,)
                yield pytest.param(mode, impl, agg, marks=marks,
                                   id=f"{mode}-{impl}-{agg}")


@pytest.mark.parametrize("mode,impl,agg", list(_faults_matrix()))
def test_faults_fault_free_bit_equal(mode, impl, agg, mixed_world):
    """A fault-free FaultPlan at the default ``norm_bound=inf`` is BIT-equal
    to ``faults=None`` in every matrix cell: the unarmed plan takes every
    fast path (no forced layout, clean kernel bodies, no ``*1.0``)."""
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine(mode)
    base = eng.grouped_round(plans, gtr, gbn, impl=impl, agg=agg)
    got = eng.grouped_round(plans, gtr, gbn, impl=impl, agg=agg,
                            faults=FLT.all_ok(_K_MIXED))
    _bit_equal_rounds(base, got)


@pytest.mark.parametrize("sd", ("bf16", "int8"))
def test_faults_fault_free_bit_equal_quantized(sd, mixed_world):
    """The fault-free bit-equality survives the quantized wire too (fresh
    engines per side so the int8 EF residuals start identical)."""
    plans, gtr, gbn, _ = mixed_world
    base = ENG.make_engine("packed", stream_dtype=sd).grouped_round(
        plans, gtr, gbn, agg="sharded"
    )
    got = ENG.make_engine("packed", stream_dtype=sd).grouped_round(
        plans, gtr, gbn, agg="sharded", faults=FLT.all_ok(_K_MIXED)
    )
    _bit_equal_rounds(base, got)


@pytest.mark.parametrize("impl", ("fused", "fused_masked"))
def test_faults_dropped_matches_zero_weight_oracle(impl, mixed_world):
    """Dropped clients ARE zero-weight columns: bit-exact against the same
    impl run on zero-weight plans (no re-trace, no new layout epoch), and
    matrix-close to the vmap zero-weight oracle."""
    plans, gtr, gbn, _ = mixed_world
    dead = {1, 3}
    fp = _plan_with({i: FLT.ClientFault("dropped") for i in dead})
    eng = ENG.make_engine("packed")
    got = eng.grouped_round(plans, gtr, gbn, impl=impl, faults=fp)
    zw = _zero_weight_plans(plans, dead)
    want_same_impl = eng.grouped_round(zw, gtr, gbn, impl=impl)
    _bit_equal_rounds(want_same_impl, got)
    oracle = ENG.make_engine("vmap").grouped_round(zw, gtr, gbn)
    _tree_close(oracle.trainable, got.trainable)
    _tree_close(oracle.bn_state, got.bn_state)


def test_faults_dropped_whole_group_passthrough(mixed_world):
    """Dropping an ENTIRE group reuses the kernels' zero-denominator→prev
    passthrough: the columns only that group trains (w[6:8] — group 2 is
    the sole full-width group) come back bit-equal to the round's input."""
    plans, gtr, gbn, _ = mixed_world
    fp = _plan_with({5: FLT.ClientFault("dropped"),
                     6: FLT.ClientFault("dropped")})
    got = ENG.make_engine("packed").grouped_round(plans, gtr, gbn, faults=fp)
    np.testing.assert_array_equal(np.asarray(got.trainable["w"][6:]),
                                  np.asarray(gtr["w"][6:]))
    # live columns still match the zero-weight oracle
    oracle = ENG.make_engine("vmap").grouped_round(
        _zero_weight_plans(plans, {5, 6}), gtr, gbn
    )
    _tree_close(oracle.trainable, got.trainable)


@pytest.mark.parametrize("sd", ("f32", "bf16"))
@pytest.mark.parametrize("mode", FLT.CORRUPT_MODES)
def test_faults_corrupt_quarantined_in_kernel(mode, sd, mixed_world):
    """A poisoned update (NaN / Inf / finite norm blowup) is zeroed by the
    in-kernel quarantine gate: the global params stay finite and match the
    vmap oracle WITHOUT that client at matrix tolerance.  NaN/Inf trip the
    finite check alone (``norm_bound=inf``); the finite blowup needs the
    configurable magnitude bound."""
    plans, gtr, gbn, _ = mixed_world
    kw = {"norm_bound": 1e6} if mode == "norm_blowup" else {}
    fp = _plan_with({3: FLT.ClientFault("corrupt", mode=mode)}, **kw)
    got = ENG.make_engine("packed", stream_dtype=sd).grouped_round(
        plans, gtr, gbn, agg="sharded", faults=fp
    )
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in jax.tree.leaves(got.trainable))
    zw = _zero_weight_plans(plans, {3})
    if sd == "f32":
        # the acceptance oracle: the vmap round without that client
        oracle = ENG.make_engine("vmap").grouped_round(zw, gtr, gbn)
    else:
        # under a quantized wire the good rows round too: the oracle is
        # the SAME-wire round without that client (the f32 comparison
        # lives in the sd="f32" cells)
        oracle = ENG.make_engine("packed", stream_dtype=sd).grouped_round(
            zw, gtr, gbn, agg="sharded"
        )
    _tree_close(oracle.trainable, got.trainable)
    _tree_close(oracle.bn_state, got.bn_state)


def test_faults_corrupt_int8_stays_finite(mixed_world):
    """Under the int8 wire a poisoned row also poisons the per-group bf16
    quantization base, so exact equivalence is out of scope — but the
    quarantine gate must still keep the aggregate finite."""
    plans, gtr, gbn, _ = mixed_world
    fp = _plan_with({3: FLT.ClientFault("corrupt", mode="nan")})
    got = ENG.make_engine("packed", stream_dtype="int8").grouped_round(
        plans, gtr, gbn, agg="sharded", faults=fp
    )
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in jax.tree.leaves(got.trainable))


def test_faults_straggler_parks_then_merges(mixed_world):
    """Round 1: the straggler contributes nothing (bit-equal to dropping
    it) and its CLEAN panel row parks in the engine staging buffer.  Round
    2: the row merges at the staleness-discounted weight ``w·beta**1`` —
    and the fused merge matches the serial host-side num/den reference
    (both feed ``_staged_side``, so one staleness semantics by
    construction).  The merge visibly moves the result."""
    plans, gtr, gbn, _ = mixed_world
    sp = _plan_with({2: FLT.ClientFault("straggler", delay=1)})
    eng_f = ENG.make_engine("packed")
    eng_s = ENG.make_engine("vmap")
    r1f = eng_f.grouped_round(plans, gtr, gbn, faults=sp)
    assert ENG.AGG_STATS["fault_staged_rows"] == 1
    r1s = eng_s.grouped_round(plans, gtr, gbn, faults=sp)
    r1d = ENG.make_engine("packed").grouped_round(
        plans, gtr, gbn, faults=_plan_with({2: FLT.ClientFault("dropped")})
    )
    _bit_equal_rounds(r1d, r1f)
    _tree_close(r1s.trainable, r1f.trainable)
    assert len(eng_f._staging) == 1 and len(eng_s._staging) == 1

    ok = FLT.all_ok(_K_MIXED)
    r2f = eng_f.grouped_round(plans, gtr, gbn, faults=ok)
    st = dict(ENG.AGG_STATS)
    assert st["fault_merged_rows"] == 1 and st["fault_staged_rows"] == 0
    assert not eng_f._staging
    r2s = eng_s.grouped_round(plans, gtr, gbn, faults=ok)
    _tree_close(r2s.trainable, r2f.trainable)
    _tree_close(r2s.bn_state, r2f.bn_state)
    # power: the merged round differs from the same round without the merge
    base2 = ENG.make_engine("packed").grouped_round(plans, gtr, gbn)
    assert not np.array_equal(np.asarray(r2f.packed),
                              np.asarray(base2.packed))


def test_faults_staging_buffer_bounded(mixed_world):
    """``max_staged`` caps what persists past the round, oldest first; an
    evicted straggler leaves no trace — the next fault-free round is
    bit-equal to ``faults=None`` again."""
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed")
    sp = _plan_with({2: FLT.ClientFault("straggler", delay=2)}, max_staged=0)
    eng.grouped_round(plans, gtr, gbn, faults=sp)
    st = dict(ENG.AGG_STATS)
    assert st["fault_evicted_rows"] == 1
    assert st["fault_staged_rows"] == 0 and not eng._staging
    base = ENG.make_engine("packed").grouped_round(plans, gtr, gbn)
    got = eng.grouped_round(plans, gtr, gbn, faults=FLT.all_ok(_K_MIXED))
    _bit_equal_rounds(base, got)


def test_faults_round_contracts_under_injection(mixed_world):
    """The amended round contracts: one logical ``fedavg_grouped`` dispatch
    and one ``block_until_ready`` — measured on a round that drops a
    client, parks a straggler, AND quarantines a poisoned row, and again on
    the following round that merges the parked panel."""
    plans, gtr, gbn, _ = mixed_world
    fp = _plan_with({
        1: FLT.ClientFault("dropped"),
        2: FLT.ClientFault("straggler", delay=1),
        4: FLT.ClientFault("corrupt", mode="norm_blowup"),
    }, norm_bound=1e6)
    ok = FLT.all_ok(_K_MIXED, norm_bound=1e6)
    eng = ENG.make_engine("packed")
    eng.grouped_round(plans, gtr, gbn, agg="sharded", faults=fp)   # warm
    eng.grouped_round(plans, gtr, gbn, agg="sharded", faults=ok)   # warm merge
    real = jax.block_until_ready
    calls = []

    def counting(x):
        calls.append(1)
        return real(x)

    jax.block_until_ready = counting
    try:
        for faults in (fp, ok):  # injection round, then merge round
            OPS.reset_dispatches()
            ENG.reset_syncs()
            calls.clear()
            eng.grouped_round(plans, gtr, gbn, agg="sharded", faults=faults)
            assert OPS.DISPATCHES["fedavg_grouped"] == 1
            assert len(calls) == 1, f"expected 1 host sync, saw {len(calls)}"
            assert ENG.SYNCS["aggregation_barrier"] == 1
    finally:
        jax.block_until_ready = real
    ENG.reset_syncs()
    OPS.reset_dispatches()


def test_faults_agg_stats_match_memory_model_twins(mixed_world):
    """The fault telemetry is metadata, never a sync — and it must equal
    the ``fl/memory_model.py`` twins EXACTLY: verdict counts via
    ``fault_counts``, staging occupancy via ``fault_staging_bytes``, and
    the staging term joining ``server_aggregation_peak_bytes``."""
    plans, gtr, gbn, _ = mixed_world
    fp = _plan_with({
        0: FLT.ClientFault("dropped"),
        2: FLT.ClientFault("straggler", delay=3),
        5: FLT.ClientFault("corrupt", mode="nan"),
    }, norm_bound=1e5)
    eng = ENG.make_engine("packed")
    eng.grouped_round(plans, gtr, gbn, faults=fp)
    st = dict(ENG.AGG_STATS)
    want = MM.fault_counts([v.kind for v in fp.verdicts])
    assert want == fp.counts()
    assert st["faults_armed"] and st["quarantine_bound"] == 1e5
    assert st["fault_ok"] == want["ok"]
    assert st["fault_dropped"] == want["dropped"]
    assert st["fault_stragglers"] == want["straggler"]
    assert st["fault_corrupt"] == want["corrupt"]
    widths = [int(e.vals.shape[0]) for e in eng._staging]
    assert st["fault_staged_rows"] == len(widths) == 1
    assert st["fault_staging_bytes"] == MM.fault_staging_bytes(widths)
    layout = ENG.make_group_layout(plans, gtr, gbn, force_index=True)
    base = MM.server_aggregation_peak_bytes(
        layout.k_total, layout.n, layout.n_groups
    )
    with_staging = MM.server_aggregation_peak_bytes(
        layout.k_total, layout.n, layout.n_groups,
        staging_bytes=st["fault_staging_bytes"],
    )
    assert with_staging == base + st["fault_staging_bytes"]
    eng.reset_faults()
    assert not eng._staging and eng._fault_round == 0
    # an unarmed round reports disarmed telemetry
    eng.grouped_round(plans, gtr, gbn)
    st0 = dict(ENG.AGG_STATS)
    assert not st0["faults_armed"] and st0["quarantine_bound"] is None
    assert st0["fault_staged_rows"] == 0 and st0["fault_staging_bytes"] == 0


def test_faults_knob_validation(mixed_world):
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed")
    with pytest.raises(TypeError):
        eng.grouped_round(plans, gtr, gbn, faults="dropped")
    with pytest.raises(ValueError):
        eng.grouped_round(plans, gtr, gbn, faults=FLT.all_ok(_K_MIXED - 1))
    # the masked kernel has no quarantine gate or merge side inputs:
    # fused_masked accepts dropped-only armed plans, nothing else
    with pytest.raises(ValueError):
        eng.grouped_round(
            plans, gtr, gbn, impl="fused_masked",
            faults=_plan_with({3: FLT.ClientFault("corrupt", mode="nan")}),
        )
    with pytest.raises(ValueError):
        eng.grouped_round(
            plans, gtr, gbn, impl="fused_masked",
            faults=_plan_with({2: FLT.ClientFault("straggler", delay=1)}),
        )
    with pytest.raises(ValueError):
        eng.grouped_round(plans, gtr, gbn, impl="fused_masked",
                          faults=FLT.all_ok(_K_MIXED, norm_bound=10.0))
    # a parked straggler blocks fused_masked on the NEXT round too (the
    # merge side inputs only exist on the grouped kernels)
    eng.grouped_round(
        plans, gtr, gbn,
        faults=_plan_with({2: FLT.ClientFault("straggler", delay=1)}),
    )
    with pytest.raises(ValueError):
        eng.grouped_round(plans, gtr, gbn, impl="fused_masked",
                          faults=FLT.all_ok(_K_MIXED))
    eng.reset_faults()


# ---------------------------------------------------------------------------
# async buffered aggregation (ISSUE 9): the sync round as a special case
# ---------------------------------------------------------------------------

# tier-1 allowlist for the sync-equivalence cells; the rest run slow
ASYNC_TIER1 = {
    ("vmap", "serial", "replicated"),
    ("packed", "serial", "replicated"),
    ("packed", "fused", "replicated"),
    ("packed", "fused", "sharded"),
    ("packed", "fused_masked", "replicated"),
    ("sharded", "fused", "sharded"),
}


def _async_matrix():
    for mode in MODES:
        for impl in IMPLS:
            for agg in AGGS:
                marks = ()
                if (mode, impl, agg) not in ASYNC_TIER1:
                    marks = (pytest.mark.slow,)
                yield pytest.param(mode, impl, agg, marks=marks,
                                   id=f"{mode}-{impl}-{agg}")


def _submit_cohort(srv, plans):
    for p in plans:
        srv.submit(p, srv.version)


@pytest.mark.parametrize("mode,impl,agg", list(_async_matrix()))
def test_async_sync_equivalence(mode, impl, agg, mixed_world):
    """THE load-bearing invariant: with staleness-0 scheduling and
    ``publish_at == cohort size``, the async server's publish IS the sync
    ``grouped_round`` — bit-equal in every matrix cell, because the server
    makes the verbatim call rather than reimplementing it."""
    plans, gtr, gbn, _ = mixed_world
    want = ENG.make_engine(mode).grouped_round(
        plans, gtr, gbn, impl=impl, agg=agg
    )
    srv = AS.AsyncAggServer(ENG.make_engine(mode), gtr, gbn,
                            publish_at=_K_MIXED, impl=impl, agg=agg)
    _submit_cohort(srv, plans)
    assert srv.ready()
    got = srv.publish()
    _bit_equal_rounds(want, got)
    assert srv.version == 1 and not srv.buffer


def test_async_sync_equivalence_frozen(mixed_frozen):
    """The sync-oracle contract holds under a frozen-column epoch (the
    frozen leaf passes through bit-equal on the async path too)."""
    plans, gtr, gbn, _, fro = mixed_frozen
    want = ENG.make_engine("packed").grouped_round(
        plans, gtr, gbn, agg="sharded", frozen=fro
    )
    srv = AS.AsyncAggServer(ENG.make_engine("packed"), gtr, gbn,
                            publish_at=_K_MIXED, agg="sharded", frozen=fro)
    _submit_cohort(srv, plans)
    got = srv.publish()
    _bit_equal_rounds(want, got)
    np.testing.assert_array_equal(
        np.asarray(got.trainable["blocks"][1]), np.asarray(gtr["blocks"][1])
    )


def test_async_sync_equivalence_faulted(mixed_world):
    """The sync-oracle contract holds under an armed FaultPlan: an async
    publish with the identical plan (drop + quarantine + parked straggler,
    then the merge publish) is bit-equal to the sync faulted rounds."""
    plans, gtr, gbn, _ = mixed_world
    fp = _plan_with({
        1: FLT.ClientFault("dropped"),
        2: FLT.ClientFault("straggler", delay=1),
        4: FLT.ClientFault("corrupt", mode="norm_blowup"),
    }, norm_bound=1e6)
    ok = FLT.all_ok(_K_MIXED, norm_bound=1e6)
    eng_sync = ENG.make_engine("packed")
    want1 = eng_sync.grouped_round(plans, gtr, gbn, faults=fp)
    want2 = eng_sync.grouped_round(plans, gtr, gbn, faults=ok)
    srv = AS.AsyncAggServer(ENG.make_engine("packed"), gtr, gbn,
                            publish_at=_K_MIXED, beta=fp.beta)
    _submit_cohort(srv, plans)
    got1 = srv.publish(faults=fp)
    _submit_cohort(srv, plans)
    got2 = srv.publish(faults=ok)
    _bit_equal_rounds(want1, got1)
    _bit_equal_rounds(want2, got2)


def test_async_sync_equivalence_int8_stream(mixed_world):
    """The sync-oracle contract holds on the quantized wire (fresh engines
    per side so the int8 error-feedback residuals start identical)."""
    plans, gtr, gbn, _ = mixed_world
    want = ENG.make_engine("packed", stream_dtype="int8").grouped_round(
        plans, gtr, gbn, agg="sharded"
    )
    srv = AS.AsyncAggServer(
        ENG.make_engine("packed", stream_dtype="int8"), gtr, gbn,
        publish_at=_K_MIXED, agg="sharded",
    )
    _submit_cohort(srv, plans)
    got = srv.publish()
    _bit_equal_rounds(want, got)


def _publish_with_stale(agg, plans, gtr, gbn):
    srv = AS.AsyncAggServer(ENG.make_engine("packed"), gtr, gbn,
                            publish_at=_K_MIXED, agg=agg, beta=0.5)
    _submit_cohort(srv, plans)
    srv.publish()
    srv.submit(plans[0], 0)  # stale: trained against v0, server is at v1
    _submit_cohort(srv, plans)
    return srv.publish()


def test_async_stale_replicated_vs_sharded_bit_equal(mixed_world):
    """A mixed fresh+stale publish preserves the exactness contract: the
    ``w·β^s`` side merge rides the column split bit-equally."""
    plans, gtr, gbn, _ = mixed_world
    got_r = _publish_with_stale("replicated", plans, gtr, gbn)
    got_s = _publish_with_stale("sharded", plans, gtr, gbn)
    _bit_equal_rounds(got_r, got_s)


def test_async_round_contracts_per_publish(mixed_world):
    """Every publish flavor — fresh-only, mixed fresh+stale, stale-only
    (the zero-weight carrier dispatch) — issues exactly one logical
    ``fedavg_grouped`` dispatch and one ``block_until_ready``."""
    plans, gtr, gbn, _ = mixed_world

    def drive(srv):
        # publish 1: fresh only; 2: fresh + stale; 3: stale only
        _submit_cohort(srv, plans)
        yield srv
        srv.submit(plans[0], 0)
        _submit_cohort(srv, plans)
        yield srv
        srv.submit(plans[1], 0)
        yield srv

    eng = ENG.make_engine("packed")
    for srv in drive(AS.AsyncAggServer(eng, gtr, gbn, publish_at=_K_MIXED,
                                       agg="sharded", beta=0.5)):
        srv.publish()  # warm the compiles
    real = jax.block_until_ready
    calls = []

    def counting(x):
        calls.append(1)
        return real(x)

    srv = AS.AsyncAggServer(eng, gtr, gbn, publish_at=_K_MIXED,
                            agg="sharded", beta=0.5)
    jax.block_until_ready = counting
    try:
        for srv in drive(srv):
            OPS.reset_dispatches()
            ENG.reset_syncs()
            calls.clear()
            srv.publish()
            assert OPS.DISPATCHES["fedavg_grouped"] == 1
            assert len(calls) == 1, f"expected 1 host sync, saw {len(calls)}"
            assert ENG.SYNCS["aggregation_barrier"] == 1
    finally:
        jax.block_until_ready = real
    ENG.reset_syncs()
    OPS.reset_dispatches()


def test_async_agg_stats_match_memory_model_twins(mixed_world):
    """The ``async_*`` telemetry is metadata, never a sync — and equals the
    ``fl/memory_model.py`` twins exactly: buffer bytes via
    ``async_buffer_bytes``, the bounded checkout table via
    ``async_version_table_bytes``, staleness via ``async_staleness_hist``."""
    plans, gtr, gbn, _ = mixed_world
    srv = AS.AsyncAggServer(ENG.make_engine("packed"), gtr, gbn,
                            publish_at=_K_MIXED, beta=0.5, max_versions=3)
    n = srv._n
    _submit_cohort(srv, plans)
    entries = [(e.k, e.n_cols) for e in srv.buffer]
    assert srv.buffer_bytes() == MM.async_buffer_bytes(entries)
    srv.publish()
    st = dict(ENG.AGG_STATS)
    assert st["async_buffer_bytes"] == MM.async_buffer_bytes(entries)
    assert st["async_buffer_rows"] == _K_MIXED
    assert st["async_published_rows"] == _K_MIXED
    assert st["async_fresh_rows"] == _K_MIXED and st["async_stale_rows"] == 0
    assert st["async_staleness_hist"] == MM.async_staleness_hist(
        [(0, _K_MIXED)]
    )
    assert st["async_versions_retained"] == 2
    assert st["async_version_table_bytes"] == MM.async_version_table_bytes(
        2, n
    )
    k0 = int(plans[0].xs.shape[0])
    srv.submit(plans[0], 0)  # stale at s=1
    _submit_cohort(srv, plans)
    srv.publish()
    st = dict(ENG.AGG_STATS)
    assert st["async_fresh_rows"] == _K_MIXED
    assert st["async_stale_rows"] == k0
    assert st["async_staleness_hist"] == MM.async_staleness_hist(
        [(0, _K_MIXED), (1, k0)]
    )
    assert st["async_versions_retained"] == 3
    assert st["async_version_table_bytes"] == MM.async_version_table_bytes(
        3, n
    )


# ---------------------------------------------------------------------------
# two-tier hierarchical aggregation (ISSUE 10): E edge folds + one carrier
# ---------------------------------------------------------------------------

# tier-1 allowlist for the edges=1-verbatim cells; the rest run slow.
# fused_masked appears with edges=1 only — the masked kernel has no side
# operands, so edges>1 rejects it (pinned in the knob-validation test).
HIER_TIER1 = {
    ("vmap", "serial", "replicated"),
    ("packed", "serial", "replicated"),
    ("packed", "fused", "replicated"),
    ("packed", "fused", "sharded"),
    ("packed", "fused_masked", "replicated"),
    ("sharded", "fused", "sharded"),
}


def _hier_matrix():
    for mode in MODES:
        for impl in IMPLS:
            for agg in AGGS:
                marks = ()
                if (mode, impl, agg) not in HIER_TIER1:
                    marks = (pytest.mark.slow,)
                yield pytest.param(mode, impl, agg, marks=marks,
                                   id=f"{mode}-{impl}-{agg}")


@pytest.mark.parametrize("mode,impl,agg", list(_hier_matrix()))
def test_hier_edges1_bit_equal(mode, impl, agg, mixed_world):
    """``edges=1`` routes VERBATIM to the flat round in every matrix cell —
    the single-edge hierarchy is the flat dispatch, bit-for-bit, the same
    way the async server's staleness-0 publish is the sync round."""
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine(mode)
    base = eng.grouped_round(plans, gtr, gbn, impl=impl, agg=agg)
    got = eng.grouped_round(plans, gtr, gbn, impl=impl, agg=agg, edges=1)
    _bit_equal_rounds(base, got)


def test_hier_edges1_bit_equal_frozen(mixed_frozen):
    """The edges=1-verbatim contract holds under a frozen-column epoch."""
    plans, gtr, gbn, _, fro = mixed_frozen
    eng = ENG.make_engine("packed")
    base = eng.grouped_round(plans, gtr, gbn, agg="sharded", frozen=fro)
    got = eng.grouped_round(plans, gtr, gbn, agg="sharded", frozen=fro,
                            edges=1)
    _bit_equal_rounds(base, got)


def test_hier_edges1_bit_equal_faulted(mixed_world):
    """The edges=1-verbatim contract holds under an armed FaultPlan (fresh
    engines per side so the straggler staging starts identical)."""
    plans, gtr, gbn, _ = mixed_world
    fp = _plan_with({
        1: FLT.ClientFault("dropped"),
        4: FLT.ClientFault("corrupt", mode="norm_blowup"),
    }, norm_bound=1e6)
    base = ENG.make_engine("packed").grouped_round(plans, gtr, gbn, faults=fp)
    got = ENG.make_engine("packed").grouped_round(plans, gtr, gbn, faults=fp,
                                                  edges=1)
    _bit_equal_rounds(base, got)


def test_hier_edges1_bit_equal_int8_stream(mixed_world):
    """The edges=1-verbatim contract holds on the quantized wire (fresh
    engines per side so the int8 EF residuals start identical)."""
    plans, gtr, gbn, _ = mixed_world
    base = ENG.make_engine("packed", stream_dtype="int8").grouped_round(
        plans, gtr, gbn, agg="sharded"
    )
    got = ENG.make_engine("packed", stream_dtype="int8").grouped_round(
        plans, gtr, gbn, agg="sharded", edges=1
    )
    _bit_equal_rounds(base, got)


@pytest.mark.parametrize("edges", (2, 4, _K_MIXED + 3))
@pytest.mark.parametrize("agg", AGGS)
def test_hier_matches_oracle(edges, agg, mixed_world):
    """A multi-edge round is the SAME weighted mean re-associated: per-edge
    (num, den) partials summed tree-wise equal the flat per-row sums up to
    fp associativity, so every edge count matches the vmap oracle at the
    matrix tolerance — including E > K, where only K edges carry rows."""
    plans, gtr, gbn, want = mixed_world
    got = ENG.make_engine("packed").grouped_round(
        plans, gtr, gbn, agg=agg, edges=edges
    )
    _grouped_close(want, got)
    st = dict(ENG.AGG_STATS)
    assert st["hier_edges"] == edges
    assert st["hier_edges_used"] == min(edges, _K_MIXED)


def test_hier_replicated_vs_sharded_bit_equal(mixed_world):
    """The per-column num/den ratio has no cross-column coupling, so the
    column split preserves the hierarchical result bit-for-bit, exactly as
    it does the flat round."""
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed")
    got_r = eng.grouped_round(plans, gtr, gbn, agg="replicated", edges=4)
    got_s = eng.grouped_round(plans, gtr, gbn, agg="sharded", edges=4)
    _bit_equal_rounds(got_r, got_s)


@pytest.mark.parametrize("edges", (2, 4))
def test_hier_round_contracts(edges, mixed_world):
    """The amended round contracts at E edges: E ``fedavg_grouped_edges``
    folds feed ONE logical ``fedavg_grouped`` carrier dispatch and one
    ``block_until_ready`` — the edge tier adds folds, never barriers."""
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed")
    eng.grouped_round(plans, gtr, gbn, agg="sharded", edges=edges)  # warm
    real = jax.block_until_ready
    calls = []

    def counting(x):
        calls.append(1)
        return real(x)

    jax.block_until_ready = counting
    try:
        OPS.reset_dispatches()
        ENG.reset_syncs()
        eng.grouped_round(plans, gtr, gbn, agg="sharded", edges=edges)
        assert OPS.DISPATCHES["fedavg_grouped"] == 1
        assert OPS.DISPATCHES["fedavg_grouped_edges"] == edges
        assert len(calls) == 1, f"expected 1 host sync, saw {len(calls)}"
        assert ENG.SYNCS["aggregation_barrier"] == 1
    finally:
        jax.block_until_ready = real
    ENG.reset_syncs()
    OPS.reset_dispatches()


@pytest.mark.parametrize("agg", AGGS)
def test_hier_agg_stats_match_memory_model_twins(agg, mixed_world):
    """The hier telemetry is plan metadata, never a sync — and equals the
    ``fl/memory_model.py`` twins EXACTLY: the per-edge partial pair via
    ``edge_partial_bytes`` and the server-side peak (E placed pairs + the
    reduced pair + carrier + gmask + prev) via ``hier_server_peak_bytes``,
    per aggregation placement."""
    plans, gtr, gbn, _ = mixed_world
    E = 3
    ENG.make_engine("packed").grouped_round(plans, gtr, gbn, agg=agg,
                                            edges=E)
    st = dict(ENG.AGG_STATS)
    layout = ENG.make_group_layout(plans, gtr, gbn, force_index=True)
    assert st["stream"] == "hier"
    assert st["hier_edges"] == E and st["hier_edges_used"] == E
    assert st["hier_edge_partial_bytes"] == MM.edge_partial_bytes(layout.n)
    assert st["hier_server_peak_bytes"] == MM.hier_server_peak_bytes(
        layout.n, E, n_devices=st["n_shards"], agg=agg
    )
    # the point of the tier: at the SAME placement the hier server only
    # keeps 2E+5 resident vectors where the flat round keeps K panel rows
    # plus its G+4 working vectors — fewer even in this tiny world
    flat_peak = MM.server_aggregation_peak_bytes(
        layout.k_total, layout.n, layout.n_groups,
        n_devices=st["n_shards"], agg=agg,
    )
    assert st["hier_server_peak_bytes"] < flat_peak


def test_hier_peak_independent_of_cohort_size():
    """The memory-wall claim in the model: the flat peak grows linearly in
    K while the hier peak depends only on (n, E) — for any fixed E the
    crossover is K ≈ 2E+5 rows, far below a production cohort."""
    n, G = 1000, 4
    for E in (2, 8, 32):
        hp = MM.hier_server_peak_bytes(n, E)
        assert hp == MM.hier_server_peak_bytes(n, E)  # pure
        assert MM.hier_server_peak_bytes(n, E + 1) > hp  # monotone in E
        assert hp < MM.server_aggregation_peak_bytes(512, n, G)
    with pytest.raises(ValueError):
        MM.hier_server_peak_bytes(n, -1)
    with pytest.raises(ValueError):
        MM.edge_partial_bytes(10, n_frozen=11)


def test_hier_edges_knob_validation(mixed_world):
    plans, gtr, gbn, _ = mixed_world
    eng = ENG.make_engine("packed")
    with pytest.raises(ValueError):
        eng.grouped_round(plans, gtr, gbn, edges=0)
    with pytest.raises(ValueError):
        eng.grouped_round(plans, gtr, gbn, edges=1.5)
    # the masked kernel has no side operands to carry the edge partials:
    # edges>1 rejects it, edges=1 routes flat and stays accepted
    with pytest.raises(ValueError):
        eng.grouped_round(plans, gtr, gbn, impl="fused_masked", edges=2)
    eng.grouped_round(plans, gtr, gbn, impl="fused_masked", edges=1)


def test_hier_faulted_matches_flat_faulted(mixed_world):
    """An armed FaultPlan (drop + quarantine) produces the same result
    through the two-tier fold as through the flat dispatch, to fp
    associativity tolerance — the per-row gate terms are folded per edge,
    not re-derived."""
    plans, gtr, gbn, _ = mixed_world
    fp = _plan_with({
        1: FLT.ClientFault("dropped"),
        4: FLT.ClientFault("corrupt", mode="norm_blowup"),
    }, norm_bound=1e6)
    want = ENG.make_engine("packed").grouped_round(plans, gtr, gbn,
                                                   faults=fp)
    got = ENG.make_engine("packed").grouped_round(plans, gtr, gbn,
                                                  faults=fp, edges=3)
    _grouped_close(want, got)


def test_hier_frozen_matches_flat_frozen(mixed_frozen):
    """A frozen-column epoch rides the edge tier: frozen columns leave the
    edge partials (``edge_partial_bytes(n, n_frozen)`` is the model) and
    the result matches the flat frozen round."""
    plans, gtr, gbn, _, fro = mixed_frozen
    want = ENG.make_engine("packed").grouped_round(plans, gtr, gbn,
                                                   frozen=fro)
    got = ENG.make_engine("packed").grouped_round(plans, gtr, gbn,
                                                  frozen=fro, edges=3)
    _grouped_close(want, got)
    np.testing.assert_array_equal(
        np.asarray(got.trainable["blocks"][1]), np.asarray(gtr["blocks"][1])
    )
    st = dict(ENG.AGG_STATS)
    assert st["hier_edge_partial_bytes"] == MM.edge_partial_bytes(
        st["n"], n_frozen=st["n_frozen"]
    )
