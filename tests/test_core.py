"""Unit tests for the ProFL core: block partitioning, effective movement /
freezing determination, output modules, progressive sub-model training."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import blocks as B
from repro.core import distill as DI
from repro.core import effective_movement as EM
from repro.core import output_module as OM
from repro.core import progressive as P
from repro.models import cnn as C
from repro.models import transformer as T
from repro.train.optimizer import AdamWCfg, adamw


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def test_group_boundaries_cover_exactly():
    for g, b in [(64, 4), (9, 3), (24, 4), (7, 4), (3, 4)]:
        bs = B.group_boundaries(g, b)
        assert bs[0] == 0 and bs[-1] == g
        assert all(b2 > b1 for b1, b2 in zip(bs, bs[1:]))


def test_split_merge_roundtrip():
    cfg = get_config("qwen3-8b").reduced(d_model=128, vocab=128).with_(n_prog_blocks=2)
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    for t in range(B.n_blocks(cfg)):
        frozen, active = B.split_model(cfg, params, t)
        # perturb active then merge back
        active2 = jax.tree.map(lambda x: x + 1.0, active)
        merged = B.merge_block_into(cfg, params, active2, t)
        frozen3, active3 = B.split_model(cfg, merged, t)
        for a, b in zip(jax.tree.leaves(active3), jax.tree.leaves(active2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    # block params partition the stack (plus stem in block 0)
    total = sum(x.size for x in jax.tree.leaves(params["layers"]))
    per_block = []
    for t in range(B.n_blocks(cfg)):
        _, act = B.split_model(cfg, params, t)
        per_block.append(sum(x.size for x in jax.tree.leaves(act["layers"])))
    assert sum(per_block) == total


def test_cnn_split_merge():
    cfg = C.CNNConfig("resnet18", width_mult=0.25, in_size=16)
    params, _ = C.init_cnn(cfg, jax.random.PRNGKey(0))
    frozen, active = B.cnn_split(params, 2)
    assert len(frozen["blocks"]) == 2 and len(active["blocks"]) == 1
    act2 = jax.tree.map(lambda x: x * 2.0, active)
    merged = B.cnn_merge(params, act2, 2)
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(merged["blocks"][2])[0]),
        np.asarray(jax.tree.leaves(act2["blocks"][0])[0]),
    )


# ---------------------------------------------------------------------------
# effective movement
# ---------------------------------------------------------------------------


def test_em_consistent_movement_is_one():
    """Scalars moving in a constant direction -> EM == 1."""
    cfg = EM.EMConfig(window_h=4)
    p = {"w": jnp.zeros((100,))}
    st = EM.em_init(p)
    vals = []
    for k in range(8):
        p = jax.tree.map(lambda x: x + 0.1, p)
        v = EM.em_update(cfg, st, p)
        if v is not None:
            vals.append(v)
    assert len(vals) == 2
    for v in vals:
        assert abs(v - 1.0) < 1e-5


def test_em_oscillation_is_near_zero():
    cfg = EM.EMConfig(window_h=4)
    st = EM.em_init({"w": jnp.zeros((100,))})
    vals = []
    for k in range(8):
        p = {"w": jnp.full((100,), 0.1 if k % 2 == 0 else 0.0)}
        v = EM.em_update(cfg, st, p)
        if v is not None:
            vals.append(v)
    for v in vals:
        assert v < 0.3


def test_freezing_fires_on_converged_series():
    cfg = EM.EMConfig(window_h=1, slope_phi=0.01, patience_w=3, fit_points=4,
                      em_level=0.5, min_rounds=2)
    st = EM.em_init({"w": jnp.zeros((10,))})
    st.history = [0.9, 0.7, 0.45, 0.2, 0.1]
    st.rounds = 10
    frozen = False
    for em in [0.09, 0.085, 0.083, 0.082, 0.081, 0.081]:
        st.history.append(em)
        if EM.should_freeze(cfg, st):
            frozen = True
            break
    assert frozen


def test_freezing_does_not_fire_while_improving():
    cfg = EM.EMConfig(window_h=1, slope_phi=0.01, patience_w=3, fit_points=4,
                      em_level=0.5, min_rounds=2)
    st = EM.em_init({"w": jnp.zeros((10,))})
    st.rounds = 100
    for em in np.linspace(0.95, 0.3, 12):  # still dropping fast
        st.history.append(float(em))
        assert not EM.should_freeze(cfg, st)


def test_em_mid_window_updates_issue_no_host_sync():
    """A mid-window em_update_flat performs no device↔host transfer in
    EITHER direction (path accumulates as a device scalar) and never calls
    block_until_ready; the one explicit device_get happens at window
    close."""
    cfg = EM.EMConfig(window_h=3)
    p = jnp.arange(64.0)
    ups = [p + float(k) for k in range(1, 4)]
    warm = EM.em_init(p)
    EM.em_update_flat(cfg, warm, ups[0])  # warm the fused EM kernel
    st = EM.em_init(p)
    real = jax.block_until_ready
    calls = []

    def counting(x):
        calls.append(1)
        return real(x)

    jax.block_until_ready = counting
    try:
        with jax.transfer_guard("disallow"):
            assert EM.em_update_flat(cfg, st, ups[0]) is None
            assert EM.em_update_flat(cfg, st, ups[1]) is None
    finally:
        jax.block_until_ready = real
    assert calls == []
    em = EM.em_update_flat(cfg, st, ups[2])  # window close: the one sync
    assert em is not None and abs(em - 1.0) < 1e-5


def test_em_history_is_bounded():
    """A long run cannot grow the EM history past what slope/should_freeze
    actually read: max(fit_points, 2) entries."""
    cfg = EM.EMConfig(window_h=1, fit_points=4)
    p = jnp.arange(6.0)
    st = EM.em_init(p)
    for k in range(1, 41):
        EM.em_update_flat(cfg, st, p + float(k))
    assert len(st.history) == max(cfg.fit_points, 2) == 4
    # the survivors are the LAST windows' values, in order
    assert st.history == pytest.approx([1.0] * 4)


def test_em_state_checkpoint_roundtrip(tmp_path):
    """below/history/k/prev survive a save/load, so a freeze decision with
    patience already accumulated continues where it left off instead of
    resetting — both replicas must freeze on the same later round."""
    from repro.train import checkpoint as CK

    cfg = EM.EMConfig(window_h=2, slope_phi=0.05, patience_w=3, fit_points=3,
                      em_level=0.5, min_rounds=2)
    n = 16
    st = EM.em_init({"w": jnp.zeros((n,))})

    def osc(r):  # oscillating updates: EM -> 0, slope flat
        return jnp.full((n,), 0.1 if r % 2 == 0 else 0.0)

    rounds = 0
    while st.below == 0:  # accumulate some patience, then checkpoint
        EM.em_update_flat(cfg, st, osc(rounds))
        if st.history and EM.should_freeze(cfg, st):
            pytest.fail("froze before the checkpoint point")
        rounds += 1
    CK.save(str(tmp_path / "em.npz"), EM.em_state_to_tree(st))
    st2 = EM.em_state_from_tree(CK.load(str(tmp_path / "em.npz")))
    assert st2.below == st.below > 0
    assert st2.k == st.k and st2.rounds == st.rounds
    assert st2.history == pytest.approx(st.history)
    np.testing.assert_array_equal(np.asarray(st2.prev), np.asarray(st.prev))
    # identical continuations freeze on the SAME round
    for r in range(rounds, rounds + 20):
        e1 = EM.em_update_flat(cfg, st, osc(r))
        e2 = EM.em_update_flat(cfg, st2, osc(r))
        assert (e1 is None) == (e2 is None)
        if e1 is not None:
            assert e1 == pytest.approx(e2)
            f1, f2 = EM.should_freeze(cfg, st), EM.should_freeze(cfg, st2)
            assert f1 == f2
            if f1:
                break
    else:
        pytest.fail("freeze never fired after restore")


def test_freeze_tracker_freezes_converged_block_only():
    """Per-block EM over stable packed column ids: the oscillating block
    freezes, the still-moving block does not, and the first update is a
    baseline only."""
    cfg = EM.EMConfig(window_h=2, slope_phi=0.05, patience_w=2, fit_points=3,
                      em_level=0.5, min_rounds=2)
    tracker = EM.FreezeTracker(cfg, {"a": np.arange(0, 4),
                                     "b": np.arange(4, 8)})
    newly = []
    for r in range(16):
        a = jnp.full((4,), 0.1 if r % 2 == 0 else 0.0)  # oscillates
        b = jnp.full((4,), float(r))  # moves steadily: EM == 1
        newly += tracker.update(jnp.concatenate([a, b]))
    assert newly == ["a"]
    assert tracker.frozen_names == ["a"]
    assert not tracker.frozen["b"]


# ---------------------------------------------------------------------------
# output modules
# ---------------------------------------------------------------------------


def test_cnn_output_module_shapes():
    cfg = C.CNNConfig("resnet18", width_mult=0.25, in_size=16)
    params, bn = C.init_cnn(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 3))
    for t in range(cfg.n_prog_blocks):
        feats, _ = C.forward_blocks(cfg, params, bn, x, n_blocks=t + 1)
        op = OM.init_cnn_output_module(
            cfg, jax.random.PRNGKey(2), t, params["head"]
        )
        logits = OM.apply_cnn_output_module(cfg, t, op, feats)
        assert logits.shape == (4, cfg.n_classes)
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_tf_output_module_head_count():
    cfg = get_config("qwen3-8b").reduced(d_model=128, vocab=128).with_(n_prog_blocks=2)
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    op0 = OM.init_tf_output_module(cfg, jax.random.PRNGKey(1), 0, params)
    op_last = OM.init_tf_output_module(
        cfg, jax.random.PRNGKey(1), B.n_blocks(cfg) - 1, params
    )
    assert len(op0["proxies"]) == B.n_blocks(cfg) - 1
    assert len(op_last["proxies"]) == 0  # last step uses the real head only


# ---------------------------------------------------------------------------
# progressive training
# ---------------------------------------------------------------------------


def test_progressive_grads_do_not_touch_frozen():
    cfg = get_config("qwen1.5-0.5b").reduced(d_model=128, vocab=128).with_(n_prog_blocks=2)
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    t = 1
    frozen, trainable = P.submodel_init(cfg, params, jax.random.PRNGKey(1), t)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)}
    loss_fn = P.make_progressive_loss(cfg, t)
    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        trainable, frozen, batch
    )
    assert bool(jnp.isfinite(loss))
    gnorms = [float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(grads)]
    assert max(gnorms) > 0


def test_progressive_step_trains_only_active():
    cfg = get_config("qwen1.5-0.5b").reduced(d_model=128, vocab=128).with_(n_prog_blocks=2)
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    t = 1
    frozen, trainable = P.submodel_init(cfg, params, jax.random.PRNGKey(1), t)
    frozen0 = copy.deepcopy(frozen)
    opt = adamw(AdamWCfg(lr=1e-3, warmup=1))
    step = P.make_progressive_train_step(cfg, opt, t)
    state = {"params": trainable, "opt": opt.init(trainable),
             "step": jnp.zeros((), jnp.int32)}
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)}
    state, metrics = jax.jit(step)(state, frozen, batch)
    # trainable moved
    moved = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(state["params"]), jax.tree.leaves(trainable))]
    assert max(moved) > 0
    # frozen is untouched by construction (never in the optimizer)
    for a, b in zip(jax.tree.leaves(frozen), jax.tree.leaves(frozen0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_progressive_loss_decreases_cnn():
    """A few ProFL steps on the active block reduce the sub-model loss."""
    cfg = C.CNNConfig("vgg11", width_mult=0.25, in_size=16)
    params, bn = C.init_cnn(cfg, jax.random.PRNGKey(0))
    frozen, active = B.cnn_split(params, 1)
    op = OM.init_cnn_output_module(cfg, jax.random.PRNGKey(1), 1, params["head"])
    trainable = {"active": active, "op": op}
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 16, 16, 3))
    y = jax.random.randint(jax.random.PRNGKey(3), (32,), 0, 10)
    loss_fn = P.cnn_submodel_loss(cfg, 1)

    @jax.jit
    def step(tr, bn):
        (l, new_bn), g = jax.value_and_grad(loss_fn, has_aux=True)(
            tr, frozen, bn, x, y)
        tr = jax.tree.map(lambda p, gg: p - 0.05 * gg, tr, g)
        return tr, new_bn, l

    losses = []
    for _ in range(15):
        trainable, bn, l = step(trainable, bn)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.8, losses


def test_distill_map_reduces_mse():
    cfg = C.CNNConfig("resnet18", width_mult=0.25, in_size=16)
    params, bn = C.init_cnn(cfg, jax.random.PRNGKey(0))
    t = 1
    frozen, teacher = B.cnn_split(params, t)
    proxy = OM.init_cnn_proxy(cfg, jax.random.PRNGKey(1), t)
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 16, 16, 3))
    loss_fn = DI.cnn_map_loss(cfg, t)

    @jax.jit
    def step(proxy):
        l, g = jax.value_and_grad(loss_fn)(proxy, frozen, teacher, bn, x)
        return jax.tree.map(lambda p, gg: p - 0.1 * gg, proxy, g), l

    l0 = None
    for i in range(20):
        proxy, l = step(proxy)
        l0 = l0 if l0 is not None else float(l)
    assert float(l) < l0
