"""Quickstart: ProFL progressive training of a small transformer, end to
end through every block — shrinking, growing, effective-movement freezing —
on synthetic tokens, single process.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import blocks as B
from repro.core import effective_movement as EM
from repro.core import progressive as P
from repro.models import transformer as T
from repro.train.optimizer import AdamWCfg, adamw


def main():
    cfg = get_config("qwen1.5-0.5b").reduced(d_model=128, vocab=256).with_(
        n_prog_blocks=2
    )
    rng = jax.random.PRNGKey(0)
    params = T.init_model(cfg, rng)
    opt = adamw(AdamWCfg(lr=2e-3, warmup=5, weight_decay=0.0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
    }
    em_cfg = EM.EMConfig(window_h=3, slope_phi=0.02, patience_w=2,
                         fit_points=4, em_level=0.9, min_rounds=6)

    print(f"model: {cfg.name}, {B.n_blocks(cfg)} progressive blocks")
    for stage, t in P.schedule(B.n_blocks(cfg), use_shrinking=True):
        frozen, trainable = P.submodel_init(cfg, params, jax.random.PRNGKey(t), t)
        n_train = sum(x.size for x in jax.tree.leaves(trainable))
        n_froz = sum(x.size for x in jax.tree.leaves(frozen))
        step = jax.jit(P.make_progressive_train_step(cfg, opt, t))
        state = {"params": trainable, "opt": opt.init(trainable),
                 "step": jnp.zeros((), jnp.int32)}
        em_state = EM.em_init(trainable)
        print(f"\n[{stage} t={t}] trainable={n_train/1e6:.2f}M "
              f"frozen={n_froz/1e6:.2f}M")
        for i in range(40):
            state, m = step(state, frozen, batch)
            em = EM.em_update(em_cfg, em_state, state["params"])
            if i % 10 == 0:
                print(f"  step {i:3d} loss={float(m['loss']):.3f}"
                      + (f" em={em:.3f}" if em is not None else ""))
            if em is not None and EM.should_freeze(em_cfg, em_state):
                print(f"  block froze at step {i} (effective movement)")
                break
        params = B.merge_block_into(cfg, params, state["params"]["active"], t)
        params["final_norm"] = state["params"]["op"]["final_norm"]
        if not cfg.tie_embeddings:
            params["head"] = state["params"]["op"]["head"]

    # final full-model loss
    from repro.train.train_step import make_loss_fn
    loss, _ = make_loss_fn(cfg, remat=False)(params, batch)
    print(f"\nfull-model loss after progressive training: {float(loss):.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
