"""End-to-end driver: progressively train a ~100M-parameter transformer on
synthetic next-token data for a few hundred steps (deliverable b).

Defaults are CPU-sized (--steps 40 per block); pass ``--steps 100`` and
``--blocks 4`` for the full run on real hardware.  On a mesh (TPU slice)
this uses the same pjit sharding env as the production launcher.

    PYTHONPATH=src python examples/train_100m.py [--steps N] [--full-model]
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import blocks as B
from repro.core import progressive as P
from repro.models import transformer as T
from repro.train.checkpoint import save
from repro.train.optimizer import AdamWCfg, adamw
from repro.train.train_step import init_train_state, make_train_step

CFG_100M = ArchConfig(
    name="repro-100m",
    family="dense",
    source="this repo",
    n_layers=8,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2304,
    vocab=32_768,
    n_prog_blocks=4,
)


def data_stream(cfg, batch, seq, seed=0):
    """Synthetic Zipf-ish token stream with local structure (learnable)."""
    key = jax.random.PRNGKey(seed)
    table = jax.random.randint(jax.random.fold_in(key, 1), (cfg.vocab,), 0,
                               cfg.vocab)
    while True:
        key, k1, k2 = jax.random.split(key, 3)
        start = jax.random.randint(k1, (batch, 1), 0, cfg.vocab)
        noise = jax.random.randint(k2, (batch, seq), 0, 17)
        toks = [start[:, 0]]
        for _ in range(seq - 1):
            toks.append((table[toks[-1]] + noise[:, len(toks) - 1]) % cfg.vocab)
        yield {"tokens": jnp.stack(toks, 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40, help="steps per block")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full-model", action="store_true",
                    help="train the full model instead of progressively")
    ap.add_argument("--ckpt", type=str, default=None)
    args = ap.parse_args()

    cfg = CFG_100M
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, {B.n_blocks(cfg)} blocks")
    opt = adamw(AdamWCfg(lr=3e-4, warmup=20))
    stream = data_stream(cfg, args.batch, args.seq)

    if args.full_model:
        state = init_train_state(cfg, params, opt)
        step = jax.jit(make_train_step(cfg, opt))
        for i in range(args.steps * B.n_blocks(cfg)):
            t0 = time.time()
            state, m = step(state, next(stream))
            if i % 10 == 0:
                print(f"step {i:4d} loss={float(m['loss']):.3f} "
                      f"({time.time()-t0:.2f}s/step)")
        params = state["params"]
    else:
        for stage, t in P.schedule(B.n_blocks(cfg), use_shrinking=False):
            frozen, trainable = P.submodel_init(
                cfg, params, jax.random.PRNGKey(100 + t), t)
            step = jax.jit(P.make_progressive_train_step(cfg, opt, t))
            st = {"params": trainable, "opt": opt.init(trainable),
                  "step": jnp.zeros((), jnp.int32)}
            nt = sum(x.size for x in jax.tree.leaves(trainable))
            print(f"\n[block {t}] trainable {nt/1e6:.1f}M / {n/1e6:.1f}M")
            for i in range(args.steps):
                t0 = time.time()
                st, m = step(st, frozen, next(stream))
                if i % 10 == 0:
                    print(f"  step {i:4d} loss={float(m['loss']):.3f} "
                          f"({time.time()-t0:.2f}s/step)")
            params = B.merge_block_into(cfg, params, st["params"]["active"], t)
            params["final_norm"] = st["params"]["op"]["final_norm"]
            if not cfg.tie_embeddings:
                params["head"] = st["params"]["op"]["head"]

    if args.ckpt:
        save(args.ckpt, params)
        print(f"saved checkpoint to {args.ckpt}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
