"""The paper's headline experiment at CPU scale: ProFL vs the baselines on
a memory-heterogeneous federation of 100 clients training ResNet18 on a
synthetic CIFAR-like task (no dataset downloads in this container).

    PYTHONPATH=src python examples/federated_resnet.py [--rounds 20]
"""
import argparse
import sys

import jax
import numpy as np

from repro.core.effective_movement import EMConfig
from repro.fl import baselines as BL
from repro.fl import data as D
from repro.fl import memory_model as MM
from repro.fl.server import FLConfig, ProFLServer
from repro.models.cnn import CNNConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8,
                    help="max FL rounds per ProFL step / per baseline")
    ap.add_argument("--non-iid", action="store_true")
    args = ap.parse_args()

    rng = jax.random.PRNGKey(0)
    xtr, ytr, xte, yte = D.make_synthetic(rng, n_train=2000, n_test=500,
                                          size=16)
    if args.non_iid:
        parts = D.partition_dirichlet(jax.random.PRNGKey(1), ytr, 100, 1.0)
    else:
        parts = D.partition_iid(jax.random.PRNGKey(1), len(xtr), 100)
    budgets = MM.assign_budgets_mb(np.random.default_rng(0), 100)
    cfg = CNNConfig("resnet18", width_mult=0.25, in_size=16)
    # FLConfig.engine defaults to "auto": packed Pallas aggregation on a
    # single device, shard_map across a `clients` mesh axis on multi-device.
    # Set engine="vmap" to force the reference oracle path.
    fl = FLConfig(
        clients_per_round=10, local_steps=4, batch_size=16, n_local_fixed=32,
        max_rounds_per_step=args.rounds, distill_rounds=2, eval_every=4,
        em=EMConfig(window_h=2, slope_phi=0.03, patience_w=2, fit_points=4,
                    em_level=0.92, min_rounds=4),
    )

    print(f"cohort engine: {fl.engine} "
          f"({len(jax.devices())} device(s) visible)")

    print(f"ResNet18 paper-scale training memory: "
          f"{MM.full_train_memory_mb(CNNConfig('resnet18')):.0f} MB; "
          f"client budgets 100-900 MB")
    print("\n=== ProFL ===")
    srv = ProFLServer(cfg, fl, xtr, ytr, xte, yte, parts, budgets)
    res = srv.run()
    for s in res["steps"]:
        print(f"  {s['stage']:6s} block {s['t']}: {s['rounds']} rounds, "
              f"PR={s['pr']:.0%}")
    print(f"  final accuracy: {res['final_acc']:.3f} (PR=100%)")

    print("\n=== Baselines ===")
    for name, fn in [("AllSmall", BL.run_allsmall),
                     ("ExclusiveFL", BL.run_exclusivefl),
                     ("HeteroFL", BL.run_heterofl),
                     ("DepthFL", BL.run_depthfl)]:
        r = fn(cfg, fl, xtr, ytr, xte, yte, parts, budgets, 2 * args.rounds)
        acc = "NA (no client fits)" if r["acc"] is None else f"{r['acc']:.3f}"
        print(f"  {name:12s} acc={acc} PR={r['pr']:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
