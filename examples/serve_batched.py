"""Batched serving example: prefill a batch of prompts, then greedy-decode
continuations with the rotating-window KV cache — the same serve_step the
decode dry-run shapes lower.

    PYTHONPATH=src python examples/serve_batched.py [--arch qwen3-8b]
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.train import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    Bz, S, N = args.batch, args.prompt_len, args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1), (Bz, S), 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.frontend is not None:
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (Bz, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (Bz, cfg.encoder.n_frames, cfg.d_model))

    t0 = time.time()
    logits, cache, pos = serve.prefill(cfg, params, batch, cache_len=S + N + 8)
    print(f"prefill: {Bz}×{S} tokens in {time.time()-t0:.2f}s "
          f"({args.arch} reduced)")

    dstep = jax.jit(
        lambda c, t, p: serve.decode_step(cfg, params, c, t, p))
    cur = jnp.argmax(logits, -1)
    out = [cur]
    t0 = time.time()
    npre = cfg.frontend.n_tokens if cfg.frontend else 0
    for i in range(N - 1):
        logits, cache = dstep(cache, cur, jnp.int32(npre + S + i))
        cur = jnp.argmax(logits, -1)
        out.append(cur)
    dt = time.time() - t0
    gen = jnp.stack(out, 1)
    print(f"decoded {Bz}×{N} tokens in {dt:.2f}s "
          f"({Bz*N/max(dt,1e-9):.1f} tok/s)")
    for b in range(min(Bz, 2)):
        print(f"  seq {b}: prompt[-6:]={prompts[b,-6:].tolist()} "
              f"-> gen[:10]={gen[b,:10].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
